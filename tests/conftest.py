"""Shared test fixtures: the production-sim builders previously copy-pasted
across test_data.py, test_streaming.py, test_scan_plan.py (and now used by
test_chaos.py). Importable both as fixtures and directly
(``from conftest import make_sim``)."""
import pytest

from repro.core import events as ev
from repro.core.simulation import ProductionSim, SimConfig


def make_sim(users=6, days=2, seed=0, req=3, mode="vlm", pin=False,
             capture_reference=True, stripe_len=16, events_mean=25.0,
             n_items=1_500, extra_days=2, nodes=0, replication=1, hedge=0.0):
    """One standard traffic sim: ``days`` full production days of ``users``
    users at ``req`` requests/user/day (the event stream covers
    ``days + extra_days`` so later test-driven days have traffic to ingest).
    ``pin`` enables bifurcated-protocol generation pinning (streaming);
    ``capture_reference`` keeps the inference-time ground truth for audits.
    ``nodes > 0`` runs the immutable tier as a disaggregated
    ``ShardedUIHStore`` over that many store nodes (0 = monolith);
    ``replication``/``hedge`` configure the replicated tier's r-way
    replication and hedged-read quantile (ignored by the monolith)."""
    cfg = SimConfig(
        stream=ev.StreamConfig(n_users=users, n_items=n_items,
                               days=days + extra_days,
                               events_per_user_day_mean=events_mean,
                               seed=seed),
        stripe_len=stripe_len,
        requests_per_user_day=req,
        mode=mode,
        seed=seed,
        pin_generations=pin,
        n_store_nodes=nodes,
        replication_factor=replication,
        hedge_quantile=hedge,
    )
    sim = ProductionSim(cfg)
    if days:
        sim.run_days(days, capture_reference=capture_reference)
    return sim


def refs_by_id(sim):
    """request_id -> inference-time ground-truth UIH (streaming audits pair
    by id: stream consumption interleaves users)."""
    return {e.request_id: r for e, r in zip(sim.examples, sim.references)}


@pytest.fixture(scope="session")
def sim_factory():
    return make_sim


@pytest.fixture(scope="module")
def planned_sim():
    """The heavier module-scoped sim the scan-plan tests share (more users,
    days, and events so batched plans have real dedupe/fanout structure)."""
    return make_sim(users=8, days=3, seed=2, req=4, events_mean=40.0,
                    n_items=1_000, extra_days=1)
