"""Seeded deterministic retry backoff (shared by store failover + DPP heal).

Chaos runs in this repo are *reproducible*: the fault schedule is a seeded
plan (``repro.testing.faults``), and the output is asserted byte-identical to
a fault-free run. Retry timing must not reintroduce nondeterminism, so jitter
is not drawn from a global RNG — ``delay(attempt, token)`` is a pure function
of ``(seed, attempt, token)``. Two retry streams (e.g. two store node groups,
or two DPP work items) decorrelate by ``token`` while each stream's schedule
stays bitwise stable across runs.

The shape is classic capped exponential backoff with *decrease-only* jitter:
``raw = min(base * multiplier**attempt, max)`` and the jittered delay lands in
``[raw * (1 - jitter), raw]`` — jitter desynchronizes retriers without ever
exceeding the cap.
"""
from __future__ import annotations

import time

_M64 = 0xFFFFFFFFFFFFFFFF


def _mix64(x: int) -> int:
    """splitmix64 finalizer: a well-mixed 64-bit hash of ``x``."""
    x &= _M64
    x = (x ^ (x >> 30)) * 0xBF58476D1CE4E5B9 & _M64
    x = (x ^ (x >> 27)) * 0x94D049BB133111EB & _M64
    return x ^ (x >> 31)


class Backoff:
    """Deterministic capped exponential backoff with seeded jitter."""

    def __init__(self, base_s: float = 0.002, multiplier: float = 2.0,
                 max_s: float = 0.25, jitter: float = 0.5, seed: int = 0):
        if base_s < 0 or max_s < 0:
            raise ValueError("backoff delays must be >= 0")
        if multiplier < 1.0:
            raise ValueError(f"multiplier must be >= 1, got {multiplier}")
        if not 0.0 <= jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {jitter}")
        self.base_s = base_s
        self.multiplier = multiplier
        self.max_s = max_s
        self.jitter = jitter
        self.seed = seed

    def delay(self, attempt: int, token: int = 0) -> float:
        """Delay before retry number ``attempt`` (0-based) of the retry
        stream identified by ``token``. Pure: same (seed, attempt, token)
        always yields the same float."""
        raw = min(self.base_s * self.multiplier ** max(attempt, 0), self.max_s)
        if self.jitter == 0.0 or raw == 0.0:
            return raw
        h = _mix64(_mix64(self.seed * 0x9E3779B97F4A7C15 ^ token) + attempt)
        u = h / float(1 << 64)          # uniform in [0, 1)
        return raw * (1.0 - self.jitter * u)

    def sleep(self, attempt: int, token: int = 0) -> float:
        d = self.delay(attempt, token)
        if d > 0:
            time.sleep(d)
        return d

    def __repr__(self) -> str:
        return (f"Backoff(base_s={self.base_s}, multiplier={self.multiplier},"
                f" max_s={self.max_s}, jitter={self.jitter}, seed={self.seed})")
