"""AdamW + schedules as pure pytree transforms (no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    m: Any                   # pytree like params
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def lr_schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    params, grads, state: AdamWState, cfg: AdamWConfig,
    decay_mask: Optional[Any] = None,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_, wd_on):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * wd_on * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    if decay_mask is None:
        # decay matrices only (ndim >= 2), not norms/biases — standard practice
        decay_mask = jax.tree.map(lambda p: jnp.float32(p.ndim >= 2), params)
    new_params = jax.tree.map(upd, params, m, v, decay_mask)
    return new_params, AdamWState(step=step, m=m, v=v), {
        "grad_norm": gnorm, "lr": lr,
    }


def make_train_step(
    loss_fn: Callable[..., jax.Array],
    cfg: AdamWConfig,
    compress: Optional[Callable] = None,
):
    """Generic train step: value_and_grad + optional gradient compression +
    AdamW. ``loss_fn(params, batch) -> scalar``."""

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress is not None:
            grads = compress(grads)
        params, opt_state, stats = adamw_update(params, grads, opt_state, cfg)
        return params, opt_state, {"loss": loss, **stats}

    return train_step
