"""UIH event model: trait schema, feature groups, and a synthetic event-stream generator.

A user's interaction history (UIH) is a *columnar* batch of events: a dict of
equal-length numpy arrays ("traits"), always sorted by ``timestamp`` ascending.
Events are append-only and immutable once written (the structural invariant the
paper's protocol exploits, §3.1).

Traits carry density/encoding hints so the trait-aware columnar codec (§4.1.2)
can pick delta / bitmap / dictionary / bit-width encodings per column.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Trait schema
# ---------------------------------------------------------------------------

# Encoding classes understood by repro.storage.columnar
DENSE_MONOTONE = "dense_monotone"   # e.g. timestamps: delta + bit-width packing
DENSE_ID = "dense_id"               # e.g. item ids: bit-width packing
SPARSE_FLAG = "sparse_flag"         # e.g. like/share: presence bitmap
CATEGORICAL = "categorical"         # e.g. event type: dictionary + bit-width
DENSE_VALUE = "dense_value"         # e.g. watch time: bit-width packing


@dataclasses.dataclass(frozen=True)
class TraitSpec:
    name: str
    dtype: np.dtype
    encoding: str  # one of the classes above

    def empty(self, n: int = 0) -> np.ndarray:
        return np.zeros(n, dtype=self.dtype)


@dataclasses.dataclass(frozen=True)
class TraitSchema:
    """Full trait schema + feature-group partition of the traits.

    ``feature_groups`` maps group name -> tuple of trait names. ``timestamp``
    is implicitly a member of every group (it is the versioning key).
    """

    traits: Tuple[TraitSpec, ...]
    feature_groups: Mapping[str, Tuple[str, ...]]

    def __post_init__(self):
        names = {t.name for t in self.traits}
        assert "timestamp" in names, "schema must include a timestamp trait"
        for g, cols in self.feature_groups.items():
            missing = set(cols) - names
            assert not missing, f"group {g} references unknown traits {missing}"

    @property
    def trait_names(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.traits)

    def spec(self, name: str) -> TraitSpec:
        for t in self.traits:
            if t.name == name:
                return t
        raise KeyError(name)

    def group_traits(self, group: str) -> Tuple[str, ...]:
        cols = self.feature_groups[group]
        if "timestamp" in cols:
            return cols
        return ("timestamp",) + tuple(cols)

    def with_traits(
        self,
        add: Sequence[TraitSpec] = (),
        drop: Sequence[str] = (),
        feature_groups: Optional[Mapping[str, Tuple[str, ...]]] = None,
    ) -> "TraitSchema":
        """Schema evolution (§4.3): add new SideInfo traits / deprecate old ones."""
        drop_set = set(drop)
        assert "timestamp" not in drop_set
        kept = tuple(t for t in self.traits if t.name not in drop_set) + tuple(add)
        if feature_groups is None:
            kept_names = {t.name for t in kept}
            feature_groups = {
                g: tuple(c for c in cols if c in kept_names)
                for g, cols in self.feature_groups.items()
            }
        return TraitSchema(traits=kept, feature_groups=dict(feature_groups))


def default_schema() -> TraitSchema:
    """Production-flavoured schema: dense core traits, sparse engagement traits,
    dictionary-encodable SideInfo."""
    traits = (
        TraitSpec("timestamp", np.dtype(np.int64), DENSE_MONOTONE),
        TraitSpec("item_id", np.dtype(np.int64), DENSE_ID),
        TraitSpec("action_type", np.dtype(np.int32), CATEGORICAL),
        TraitSpec("surface", np.dtype(np.int32), CATEGORICAL),
        TraitSpec("watch_time_ms", np.dtype(np.int32), DENSE_VALUE),
        TraitSpec("like", np.dtype(np.int8), SPARSE_FLAG),
        TraitSpec("comment", np.dtype(np.int8), SPARSE_FLAG),
        TraitSpec("share", np.dtype(np.int8), SPARSE_FLAG),
        TraitSpec("category", np.dtype(np.int32), CATEGORICAL),
        TraitSpec("creator_id", np.dtype(np.int64), DENSE_ID),
    )
    groups = {
        "core": ("timestamp", "item_id", "action_type"),
        "engagement": ("like", "comment", "share", "watch_time_ms"),
        "sideinfo": ("category", "creator_id", "surface"),
    }
    return TraitSchema(traits=traits, feature_groups=groups)


# ---------------------------------------------------------------------------
# Columnar event batches
# ---------------------------------------------------------------------------

EventBatch = Dict[str, np.ndarray]  # trait name -> column, sorted by timestamp


def empty_batch(schema: TraitSchema, traits: Optional[Sequence[str]] = None) -> EventBatch:
    names = traits if traits is not None else schema.trait_names
    return {n: schema.spec(n).empty() for n in names}


def batch_len(batch: EventBatch) -> int:
    if not batch:
        return 0
    return len(next(iter(batch.values())))


def validate_batch(batch: EventBatch, schema: Optional[TraitSchema] = None) -> None:
    n = batch_len(batch)
    for k, v in batch.items():
        assert v.ndim == 1 and len(v) == n, f"trait {k} ragged: {len(v)} != {n}"
        if schema is not None:
            assert v.dtype == schema.spec(k).dtype, (k, v.dtype)
    ts = batch.get("timestamp")
    if ts is not None and len(ts) > 1:
        assert np.all(np.diff(ts) >= 0), "events must be time-ordered"


def concat_batches(batches: Sequence[EventBatch]) -> EventBatch:
    batches = [b for b in batches if batch_len(b) > 0]
    if not batches:
        return {}
    keys = batches[0].keys()
    return {k: np.concatenate([b[k] for b in batches]) for k in keys}


def slice_batch(batch: EventBatch, lo: int, hi: int) -> EventBatch:
    return {k: v[lo:hi] for k, v in batch.items()}


def take_batch(batch: EventBatch, idx: np.ndarray) -> EventBatch:
    return {k: v[idx] for k, v in batch.items()}


def time_slice(batch: EventBatch, t_lo: int, t_hi: int) -> EventBatch:
    """Events with t_lo <= timestamp <= t_hi (the temporal predicate of §3.1)."""
    ts = batch["timestamp"]
    lo = int(np.searchsorted(ts, t_lo, side="left"))
    hi = int(np.searchsorted(ts, t_hi, side="right"))
    return slice_batch(batch, lo, hi)


def project_traits(batch: EventBatch, traits: Sequence[str]) -> EventBatch:
    return {k: batch[k] for k in traits}


def tail_view(batch: EventBatch, max_events: int,
              traits: Optional[Sequence[str]] = None) -> EventBatch:
    """THE carve rule of the multi-dimensional projection (§4.1.2): keep the
    most recent ``max_events`` events (-1 = all), then project to the given
    ``traits`` that are present (in that order).

    Shared by scan trimming (``_scan_into``), plan subsumption
    (``ImmutableUIHStore._carve``) and union-window tenant views
    (``projection.project_view``) — one implementation is what makes the
    "carved view == solo scan" byte-identity hold by construction."""
    n = batch_len(batch)
    if max_events >= 0 and n > max_events:
        batch = slice_batch(batch, n - max_events, n)
    if traits is not None:
        batch = project_traits(batch, [t for t in traits if t in batch])
    return batch


def merge_sorted(batches: Sequence[EventBatch]) -> EventBatch:
    """k-way merge by timestamp (stable). Used by mutable-store merge-on-read and
    by compaction. Inputs may individually be unsorted (blind-write appends)."""
    cat = concat_batches(batches)
    if not cat:
        return cat
    order = np.argsort(cat["timestamp"], kind="stable")
    return take_batch(cat, order)


# ---------------------------------------------------------------------------
# Synthetic event-stream generator
# ---------------------------------------------------------------------------

MS_PER_DAY = 86_400_000


@dataclasses.dataclass
class StreamConfig:
    n_users: int = 64
    n_items: int = 50_000
    n_creators: int = 5_000
    n_categories: int = 64
    n_action_types: int = 8
    n_surfaces: int = 4
    days: int = 8
    events_per_user_day_mean: float = 40.0
    like_rate: float = 0.06
    comment_rate: float = 0.015
    share_rate: float = 0.008
    seed: int = 0


class SyntheticEventStream:
    """Deterministic synthetic UIH generator.

    Item popularity is Zipfian, engagement flags are sparse (matching the density
    assumptions behind the trait-aware codec), timestamps arrive in bursty
    sessions within each day.
    """

    def __init__(self, cfg: StreamConfig, schema: Optional[TraitSchema] = None):
        self.cfg = cfg
        self.schema = schema or default_schema()
        self._rng = np.random.default_rng(cfg.seed)
        # Zipf item weights
        ranks = np.arange(1, cfg.n_items + 1, dtype=np.float64)
        w = 1.0 / ranks**1.1
        self._item_p = w / w.sum()
        self._item_creator = self._rng.integers(0, cfg.n_creators, size=cfg.n_items)
        self._item_category = self._rng.integers(0, cfg.n_categories, size=cfg.n_items)

    def day_events(self, user_id: int, day: int) -> EventBatch:
        """All events of ``user_id`` during ``day`` (timestamps in ms)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, user_id, day))
        n = int(rng.poisson(cfg.events_per_user_day_mean))
        if n == 0:
            return empty_batch(self.schema)
        # bursty sessions: a few session starts, events clustered after them
        n_sessions = max(1, int(rng.integers(1, 5)))
        starts = np.sort(rng.integers(0, MS_PER_DAY - 3_600_000, size=n_sessions))
        sess = rng.integers(0, n_sessions, size=n)
        ts = day * MS_PER_DAY + starts[sess] + rng.integers(0, 3_600_000, size=n)
        ts = np.sort(ts).astype(np.int64)
        items = rng.choice(cfg.n_items, size=n, p=self._item_p).astype(np.int64)
        batch: EventBatch = {
            "timestamp": ts,
            "item_id": items,
            "action_type": rng.integers(0, cfg.n_action_types, size=n).astype(np.int32),
            "surface": rng.integers(0, cfg.n_surfaces, size=n).astype(np.int32),
            "watch_time_ms": np.maximum(
                0, (rng.gamma(2.0, 8_000.0, size=n)).astype(np.int32)
            ),
            "like": (rng.random(n) < cfg.like_rate).astype(np.int8),
            "comment": (rng.random(n) < cfg.comment_rate).astype(np.int8),
            "share": (rng.random(n) < cfg.share_rate).astype(np.int8),
            "category": self._item_category[items].astype(np.int32),
            "creator_id": self._item_creator[items].astype(np.int64),
        }
        return {k: batch[k] for k in self.schema.trait_names}

    def history_until(self, user_id: int, t: int, start_day: int = 0) -> EventBatch:
        """Full canonical history of ``user_id`` with timestamp <= t."""
        last_day = min(self.cfg.days - 1, t // MS_PER_DAY)
        days = [self.day_events(user_id, d) for d in range(start_day, last_day + 1)]
        return time_slice(merge_sorted(days), 0, t) if days else empty_batch(self.schema)
