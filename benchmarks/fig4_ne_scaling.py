"""Figure 4 reproduction: NE improves monotonically with UIH sequence length,
and VLM matches Fat Row NE exactly in the overlapping range.

Synthetic task with genuine long-range signal: the click label depends on how
often the candidate's category appears in the user's FULL history (older
events carry real information), so models fed longer reconstructed sequences
achieve lower NE. The data path is the real one end-to-end:
events -> mutable/immutable tiers -> snapshot -> warehouse -> DPP
materialization (per-length projection pushdown) -> DLRM-UIH training.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult, standard_sim
from repro.core import events as ev
from repro.core.projection import TenantProjection
from repro.dpp.featurize import FeatureSpec, merge_base_batches
from repro.dpp.worker import DPPWorker
from repro.models.recsys import (
    DLRMUIHConfig,
    dlrm_uih_loss,
    dlrm_uih_forward,
    normalized_entropy,
)
from repro.models import recsys as R
from repro.train.optimizer import AdamWConfig, adamw_init, make_train_step

SEQ_LENS = [4, 16, 64, 192]
STEPS = 250
BATCH = 64
# --quick smoke overrides (not meaningful measurements)
QUICK_SEQ_LENS = [4, 16]
QUICK_STEPS = 30
QUICK_BATCH = 16


LOOKBACK_EVENTS = 128


def _label_fn(uih, candidate, rng):
    """P(click) depends on whether the candidate's category appears in the
    user's last LOOKBACK_EVENTS events — long-range *presence* signal: windows
    shorter than the lookback physically cannot see most matches."""
    n = ev.batch_len(uih)
    if n == 0:
        return {"click": float(rng.random() < 0.08)}
    recent = uih["category"][-LOOKBACK_EVENTS:]
    match = bool(np.any(recent == candidate["category"]))
    p = 0.75 if match else 0.08
    return {"click": float(rng.random() < p)}


def _make_batches(sim, seq_len: int, seed: int, batch: int = BATCH):
    tenant = TenantProjection(
        f"len{seq_len}", seq_len=seq_len,
        feature_groups=("core", "sideinfo"),
        traits_per_group={"core": ("timestamp", "item_id", "action_type"),
                          "sideinfo": ("category",)},
    )
    spec = FeatureSpec(seq_len=seq_len,
                       uih_traits=("item_id", "action_type", "category"),
                       candidate_fields=("item_id", "category"),
                       label_fields=("click",))
    worker = DPPWorker(sim.materializer(validate_checksum=False), tenant,
                       spec, sim.schema)
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(sim.examples))
    examples = [sim.examples[i] for i in order]
    batches = []
    for lo in range(0, len(examples) - batch + 1, batch):
        batches.append(worker.process(examples[lo : lo + batch]))
    return batches


def _prep(batch, cfg):
    """The long-range signal lives in the category trait (fetched through the
    sideinfo feature-group projection): the sequence encoder consumes category
    ids directly, so the task isolates *window length* rather than item-to-
    category association learning (which the CPU step budget cannot afford)."""
    b = len(batch["user_id"])
    return {
        "uih_item_id": jnp.asarray(batch["uih_category"] % cfg.item_vocab, jnp.int32),
        "uih_action_type": jnp.asarray(batch["uih_action_type"] % 16, jnp.int32),
        "uih_mask": jnp.asarray(batch["uih_mask"]),
        "cand_item_id": jnp.asarray(batch["cand_category"] % cfg.item_vocab, jnp.int32),
        "sparse_ids": jnp.asarray(
            np.stack([batch["cand_category"] % cfg.field_vocab,
                      batch["user_id"] % cfg.field_vocab], 1), jnp.int32),
        "dense": jnp.asarray(
            np.stack([batch["uih_mask"].sum(1)] * 4, 1), jnp.float32) / 100.0,
        "label": jnp.asarray(batch["label_click"], jnp.float32),
    }


def _train_ne(sim, seq_len: int, seed: int = 0, steps: int = STEPS,
              batch: int = BATCH) -> float:
    cfg = DLRMUIHConfig(
        name="fig4", seq_len=seq_len, d_seq=16, n_seq_layers=2, n_heads=2,
        n_dense=4, n_sparse=2, embed_dim=8, item_vocab=5_000, field_vocab=1_000,
        compute_dtype=jnp.float32, remat=False,
    )
    batches = [_prep(b, cfg) for b in _make_batches(sim, seq_len, seed, batch)]
    n_eval = max(2, len(batches) // 4)
    train, test = batches[n_eval:], batches[:n_eval]
    params = R.init_dlrm_uih(jax.random.PRNGKey(seed), cfg)
    opt_cfg = AdamWConfig(lr=2e-3, warmup_steps=15, total_steps=steps,
                          weight_decay=0.01)
    step = jax.jit(make_train_step(lambda p, b: dlrm_uih_loss(p, b, cfg),
                                   opt_cfg))
    fwd = jax.jit(lambda p, b: dlrm_uih_forward(p, b, cfg))
    opt = adamw_init(params)
    best = float("inf")
    for i in range(steps):
        params, opt, _ = step(params, opt, train[i % len(train)])
        if (i + 1) % min(25, steps) == 0:  # early-stopping eval on held-out batches
            ne = float(np.mean([
                float(normalized_entropy(fwd(params, b), b["label"]))
                for b in test]))
            best = min(best, ne)
    return best


def _sim(mode, quick: bool = False):
    from repro.core.simulation import ProductionSim, SimConfig

    users, days = (24, 3) if quick else (256, 6)
    cfg = SimConfig(
        stream=ev.StreamConfig(n_users=users, n_items=5_000, n_categories=256,
                               days=days, events_per_user_day_mean=50.0, seed=42),
        stripe_len=32, requests_per_user_day=6,
        lookback_ms=(days - 1) * ev.MS_PER_DAY, n_shards=8, mode=mode, seed=42)
    s = ProductionSim(cfg)
    s.label_fn = _label_fn
    s.run_days(days - 1, capture_reference=False)
    return s


def run(quick: bool = False) -> List[BenchResult]:
    seq_lens = QUICK_SEQ_LENS if quick else SEQ_LENS
    steps = QUICK_STEPS if quick else STEPS
    batch = QUICK_BATCH if quick else BATCH
    sim = _sim("vlm", quick)
    out: List[BenchResult] = []
    nes = {}
    for sl in seq_lens:
        nes[sl] = _train_ne(sim, sl, steps=steps, batch=batch)
        out.append(BenchResult(f"fig4/ne_seq_{sl}", 0.0,
                               {"ne": round(nes[sl], 4)}))
    gain = 100.0 * (nes[seq_lens[0]] - nes[seq_lens[-1]]) / nes[seq_lens[0]]
    improving = sum(
        nes[a] > nes[b] for a, b in zip(seq_lens, seq_lens[1:]))
    out.append(BenchResult(
        "fig4/scaling", 0.0,
        {"ne_gain_short_to_long_pct": round(gain, 2),
         "monotone_improvements": f"{improving}/{len(seq_lens) - 1}",
         "paper": "platform A >5% cumulative NE gain 256->64K"},
    ))

    # VLM == Fat Row parity: identical NE because materialization is exact
    fat = _sim("fatrow", quick)
    sl = seq_lens[1]
    ne_fat = _train_ne(fat, sl, steps=steps, batch=batch)
    out.append(BenchResult(
        "fig4/vlm_vs_fatrow_parity", 0.0,
        {"seq_len": sl, "ne_vlm": round(nes[sl], 4),
         "ne_fatrow": round(ne_fat, 4),
         "abs_diff": round(abs(nes[sl] - ne_fat), 6),
         "paper": "NE parity in the 256-4K overlap"},
    ))
    return out


if __name__ == "__main__":
    for r in run():
        print(r.csv())
