"""Public wrapper: pads ragged shapes to block multiples, picks interpret
mode automatically off-TPU, and keeps int64 timestamp arenas exact.

The kernel accumulates its carry in int32 (the only integer width the VMEM
scan tiles natively), but the host arena stores epoch-millisecond
timestamps as int64 (``featurize._EMPTY_I64``) — far above 2^31. Feeding
those through the old ``astype(int32)`` cast silently wrapped every value.
The fix decodes **relative to the per-row window base**: deltas within one
materialization window span at most the window's duration (the codec
contract — stripes are bounded time windows), so the int32 carry only ever
holds window-relative offsets; the int64 base is re-added on the host where
int64 arithmetic is exact. int32 inputs take the original single-kernel
path unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import runtime
from repro.kernels.delta_decode.delta_decode import delta_decode_kernel

# max within-window delta span the int32 carry can hold; epoch-ms deltas in
# one stripe are window-duration-bounded (days ~ 1e8 ms), far below this
_I32_MAX = np.int64(2**31 - 1)


def _decode_i32(deltas: jax.Array, bases: jax.Array,
                block_b: int, block_n: int) -> jax.Array:
    """The padded int32 kernel call (both dtype paths bottom out here)."""
    b, n = deltas.shape
    bb = min(block_b, max(1, b))
    pb = (bb - b % bb) % bb
    pn = (block_n - n % block_n) % block_n
    d = jnp.pad(deltas.astype(jnp.int32), ((0, pb), (0, pn)))
    bs = jnp.pad(bases.astype(jnp.int32), (0, pb))
    out = delta_decode_kernel(d, bs, block_b=bb, block_n=block_n,
                              interpret=runtime.interpret_default())
    return out[:b, :n]


def delta_decode(deltas: jax.Array, bases: jax.Array,
                 block_b: int = 8, block_n: int = 128):
    """Batched stripe timestamp decode; auto-pads to VMEM block multiples.

    int32 inputs: decoded on-device, returns a (B, N) int32 jax array.
    int64 inputs (epoch-ms arenas): the kernel decodes the window-relative
    prefix sums in int32 and the per-row int64 base is re-added host-side —
    returns a (B, N) int64 **numpy** array, exact for timestamps > 2^31.
    """
    d = np.asarray(deltas)
    bs = np.asarray(bases)
    b, n = d.shape
    if b == 0 or n == 0:
        wide = d.dtype == np.int64 or bs.dtype == np.int64
        return np.zeros((b, n), np.int64 if wide else np.int32)
    if d.dtype != np.int64 and bs.dtype != np.int64:
        return _decode_i32(jnp.asarray(deltas), jnp.asarray(bases),
                           block_b, block_n)
    d64 = d.astype(np.int64, copy=False)
    span = np.cumsum(d64, axis=1, dtype=np.int64)
    if np.abs(d64).max(initial=0) > _I32_MAX or \
            np.abs(span).max(initial=0) > _I32_MAX:
        # window span exceeds the carry width: the codec contract is broken;
        # decode exactly on the host rather than wrap on device
        return span + bs.astype(np.int64)[:, None]
    rel = _decode_i32(jnp.asarray(d64.astype(np.int32)),
                      jnp.zeros(b, jnp.int32), block_b, block_n)
    return np.asarray(rel).astype(np.int64) + bs.astype(np.int64)[:, None]
