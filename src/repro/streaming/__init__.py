"""Real-time streaming training subsystem (paper §3.2): the "O" in O2O.

Closes the loop from event arrival to gradient on top of the batch data
plane — micro-batching ``StreamingSource``, batch→stream ``BackfillCoordinator``
with an exactly-once request_id watermark, and the ``StreamingSession`` that
wires them into ``DPPWorkerPool``/``RebatchingClient``/``DevicePrefetcher``
with generation-lease release and event→gradient freshness metrics. The
storage-side halves of the protocol live in
``repro.storage.immutable_store`` (generation leases) and
``repro.core.materialize`` (stale-generation remediation).
"""
from repro.streaming.backfill import BackfillCoordinator, BackfillStats, ReplayFilter
from repro.streaming.session import FreshnessStats, StreamingSession
from repro.streaming.source import MicroBatchConfig, SourceStats, StreamingSource

__all__ = [
    "BackfillCoordinator",
    "BackfillStats",
    "ReplayFilter",
    "FreshnessStats",
    "MicroBatchConfig",
    "SourceStats",
    "StreamingSession",
    "StreamingSource",
]
