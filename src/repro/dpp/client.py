"""Trainer-side DPP client (paper §4.2.1): rebatching.

DPP workers emit *base batches* sized to their memory budget; the trainer-side
client asynchronously buffers, merges, and reshuffles them into the model's
full batch. This decouples worker memory pressure from the GPU's large-batch
requirement and raises worker thread concurrency.

Also hosts the GPU-starvation accounting the elastic controller consumes.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.dpp.featurize import merge_base_batches, reshuffle


@dataclasses.dataclass
class ClientStats:
    full_batches: int = 0
    starved_time_s: float = 0.0    # trainer waited on data (GPU idle)
    train_time_s: float = 0.0      # trainer consumed data (GPU busy)

    @property
    def starvation_pct(self) -> float:
        total = self.starved_time_s + self.train_time_s
        if total <= 0:
            return 0.0
        return 100.0 * self.starved_time_s / total


class RebatchingClient:
    """Merges base batches of size b into full batches of size B = k*b.

    ``put`` is called by DPP worker threads; ``get_full_batch`` by the trainer.
    """

    def __init__(
        self,
        full_batch_size: int,
        buffer_batches: int = 8,
        shuffle_seed: Optional[int] = 0,
    ):
        self.full_batch_size = full_batch_size
        self._q: "queue.Queue" = queue.Queue(maxsize=buffer_batches)
        self._pending: List[Dict[str, np.ndarray]] = []
        self._pending_rows = 0
        self._lock = threading.Lock()
        self._closed = threading.Event()
        self.shuffle_seed = shuffle_seed
        # producer-side emit counter: the reshuffle seed must NOT depend on
        # stats.full_batches (incremented by the CONSUMER), else the shuffle
        # of batch k varies with trainer timing and runs aren't reproducible
        self._emit_seq = 0
        self.stats = ClientStats()

    # -- producer side (DPP workers) --------------------------------------------
    def put(self, base_batch: Dict[str, np.ndarray]) -> None:
        rows = len(next(iter(base_batch.values())))
        with self._lock:
            self._pending.append(base_batch)
            self._pending_rows += rows
            if self._pending_rows >= self.full_batch_size:
                merged = merge_base_batches(self._pending)
                self._pending = []
                self._pending_rows = 0
            else:
                return
        # emit exact-size full batches; spill remainder back to pending
        n = len(next(iter(merged.values())))
        emitted = 0
        while n - emitted >= self.full_batch_size:
            full = {k: v[emitted : emitted + self.full_batch_size]
                    for k, v in merged.items()}
            self._emit(full)
            emitted += self.full_batch_size
        if emitted < n:
            rest = {k: v[emitted:] for k, v in merged.items()}
            with self._lock:
                self._pending.insert(0, rest)
                self._pending_rows += n - emitted

    def _emit(self, full: Dict[str, np.ndarray]) -> None:
        if self.shuffle_seed is not None:
            with self._lock:
                seq = self._emit_seq
                self._emit_seq += 1
            full = reshuffle(full, self.shuffle_seed + seq)
        self._q.put(full)

    def close(self) -> None:
        """Flush the pending remainder as a final short batch, then signal end
        of stream (the tail of an epoch must not be silently dropped)."""
        self._closed.set()
        with self._lock:
            pending, self._pending = self._pending, []
            self._pending_rows = 0
        if pending:
            self._emit(merge_base_batches(pending))
        self._q.put(None)

    # -- consumer side (trainer loop) --------------------------------------------
    def get_full_batch(self, timeout: Optional[float] = None):
        t0 = time.perf_counter()
        try:
            out = self._q.get(timeout=timeout)
        except queue.Empty:
            out = None
        self.stats.starved_time_s += time.perf_counter() - t0
        if out is not None:
            self.stats.full_batches += 1
        return out

    def record_train_step(self, seconds: float) -> None:
        self.stats.train_time_s += seconds

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.get_full_batch()
            if b is None:
                return
            yield b
