"""Planned multi-range scan vs per-example scans (§4.1.2, §4.2.3).

Duplicate-heavy workload: user-bucketed batches where many same-user, same-day
examples share one immutable window. The planned path must (a) execute fewer
scans (dedupe), (b) decode fewer stripes (decode LRU), and (c) overlap shard
I/O (per-shard latency instead of summed) — byte-identical outputs are proven
in tests/test_scan_plan.py.
"""
from __future__ import annotations

import time
from collections import defaultdict
from typing import List

from benchmarks.common import BenchResult, standard_sim
from repro.core.projection import TenantProjection
from repro.storage import columnar

TENANT = TenantProjection("t", seq_len=256,
                          feature_groups=("core", "engagement"))

# remote-storage latency model: per-seek + per-byte + per-shard-hop
LATENCY = (lambda seeks, nbytes, fanout:
           2e-4 * seeks + nbytes / 2e9 + 5e-4 * max(fanout - 1, 0))


def _user_bucketed_batches(sim, base: int = 16) -> List[list]:
    by_user = defaultdict(list)
    for e in sim.examples:
        if e.version is not None:
            by_user[e.user_id].append(e)
    batches, cur = [], []
    for u in sorted(by_user):
        for e in by_user[u]:
            cur.append(e)
            if len(cur) == base:
                batches.append(cur)
                cur = []
    if cur:
        batches.append(cur)
    return batches


def _run(sim, batches, planned: bool, decode_cache: bool):
    store = sim.immutable
    saved = store.decode_cache
    store.decode_cache = columnar.StripeDecodeCache(256) if decode_cache else None
    mat = sim.materializer(validate_checksum=False)
    store.latency_model = LATENCY
    before = store.stats.snapshot()
    t0 = time.perf_counter()
    n = 0
    for b in batches:
        if planned:
            mat.materialize_batch(b, TENANT)
        else:
            for e in b:
                mat.materialize(e, TENANT)
        n += len(b)
    wall = time.perf_counter() - t0
    store.latency_model = None
    d = store.stats.delta(before)
    store.decode_cache = saved
    return d, n / wall, wall


def run(quick: bool = False) -> List[BenchResult]:
    sim = standard_sim("vlm", users=8, days=2, req_per_day=4) if quick \
        else standard_sim("vlm", users=24, days=6, req_per_day=8)
    batches = _user_bucketed_batches(sim, base=16)

    # per-example baseline: one multi_range_scan per example, no decode cache
    # (the seed read path); planned: one deduped shard-parallel plan per batch
    d_pe, thr_pe, wall_pe = _run(sim, batches, planned=False, decode_cache=False)
    d_pl, thr_pl, wall_pl = _run(sim, batches, planned=True, decode_cache=True)

    decodes_pe = d_pe.stripes_read - d_pe.decode_cache_hits
    decodes_pl = d_pl.stripes_read - d_pl.decode_cache_hits
    return [
        BenchResult(
            "scan_plan/io_work", wall_pl * 1e6 / max(len(batches), 1),
            {
                "per_example_seeks": d_pe.seeks,
                "planned_seeks": d_pl.seeks,
                "per_example_decodes": decodes_pe,
                "planned_decodes": decodes_pl,
                "dedup_hits": d_pl.dedup_hits,
                "decode_cache_hits": d_pl.decode_cache_hits,
                "parallel_shards": d_pl.parallel_shards,
                "fewer_seeks": d_pl.seeks < d_pe.seeks,
                "fewer_decodes": decodes_pl < decodes_pe,
            },
        ),
        BenchResult(
            "scan_plan/throughput", 0.0,
            {
                "per_example_ex_per_s": round(thr_pe, 1),
                "planned_ex_per_s": round(thr_pl, 1),
                "speedup_pct": round(100.0 * (thr_pl - thr_pe) / thr_pe, 1),
                "per_example_bytes": d_pe.bytes_scanned,
                "planned_bytes": d_pl.bytes_scanned,
            },
        ),
    ]


if __name__ == "__main__":
    for r in run():
        print(r.csv())
