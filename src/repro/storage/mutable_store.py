"""Real-time mutable UIH store (paper §4.1.1).

Captures the most recent engagements with second-level freshness. To support
high-frequency updates without a Read-Modify-Write penalty, writes are
*blind-write appends* (unsorted chunks per user); state resolution (sort +
merge) is deferred to read time or to background compaction. A write-through
cache co-located with the ranking service serves the read path.

Retention is coupled to the immutable store's compaction cadence: events must
stay in the mutable tier until the next compaction cycle has consolidated them
into the immutable tier (``evict_until``)."""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core import events as ev


class MutableUIHStore:
    def __init__(self, schema: Optional[ev.TraitSchema] = None):
        self.schema = schema or ev.default_schema()
        self._chunks: Dict[int, List[ev.EventBatch]] = {}
        # write-through cache of the merged view, invalidated on append
        self._cache: Dict[int, ev.EventBatch] = {}
        # accounting for benchmarks
        self.bytes_written = 0
        self.bytes_read = 0
        self.appends = 0

    # -- write path ---------------------------------------------------------
    def append(self, user_id: int, batch: ev.EventBatch) -> None:
        """Blind-write append: no read, no sort, O(1) amortized."""
        if ev.batch_len(batch) == 0:
            return
        self._chunks.setdefault(user_id, []).append(batch)
        self._cache.pop(user_id, None)
        self.appends += 1
        self.bytes_written += sum(v.nbytes for v in batch.values())

    # -- read path ----------------------------------------------------------
    def read(self, user_id: int, t_lo: int, t_hi: int) -> ev.EventBatch:
        """Merged, time-ordered view of recent events in (t_lo, t_hi].

        Merge-on-read resolves the unsorted blind-write chunks; the merged view
        is cached (write-through cache) until the next append."""
        merged = self._cache.get(user_id)
        if merged is None:
            merged = ev.merge_sorted(self._chunks.get(user_id, []))
            if not merged:
                merged = ev.empty_batch(self.schema)
            self._cache[user_id] = merged
        out = ev.time_slice(merged, t_lo + 1, t_hi)
        self.bytes_read += sum(v.nbytes for v in out.values())
        return out

    # -- retention ----------------------------------------------------------
    def evict_until(self, user_id: int, watermark_ts: int) -> None:
        """Drop events with timestamp <= watermark (already compacted into the
        immutable tier). Called after each compaction cycle."""
        chunks = self._chunks.get(user_id)
        if not chunks:
            return
        merged = ev.merge_sorted(chunks)
        ts = merged["timestamp"]
        keep_from = int(np.searchsorted(ts, watermark_ts, side="right"))
        kept = ev.slice_batch(merged, keep_from, len(ts))
        if ev.batch_len(kept) == 0:
            self._chunks.pop(user_id, None)
        else:
            self._chunks[user_id] = [kept]
        self._cache.pop(user_id, None)

    def evict_all_until(self, watermark_ts: int) -> None:
        for uid in list(self._chunks.keys()):
            self.evict_until(uid, watermark_ts)

    def user_ids(self):
        return list(self._chunks.keys())

    def resident_events(self, user_id: int) -> int:
        return sum(ev.batch_len(c) for c in self._chunks.get(user_id, []))
