"""Data-affinity planning for batch training (paper §4.2.3).

Two complementary strategies:
  1. user bucketing at warehouse-ingestion time (see ``storage.stream.Warehouse``)
     groups a user's temporally-adjacent examples so one immutable lookup is
     amortized across them (``Materializer.materialize_batch`` exploits it);
  2. symmetric sharding: the warehouse bucket key equals the immutable store's
     partition key, so a bucket's lookups hit exactly one shard (zero fanout).

This module plans DPP work assignments honoring both. With the immutable tier
disaggregated (``storage.sharded_store``), the plan additionally honors the
generation's **placement map**: items are clustered by the (node, shard) the
store will actually route to — including the heavy-tail overrides that move
ultra-long users off their hash node — so every work item's lookups land on
exactly one store NODE (zero cross-node network fanout), not just one logical
shard.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.versioning import TrainingExample
from repro.storage.sharding import PlacementMap, shard_of


@dataclasses.dataclass
class AffinityPlan:
    # work items: each is a list of examples a single DPP worker processes
    items: List[List[TrainingExample]]
    expected_fanout: float            # avg distinct shards per item
    amortizable_pairs: int            # adjacent same-(user,window) example pairs
    expected_node_fanout: float = 1.0  # avg distinct store NODES per item
    # replica-aware affinity tags: per work item, the ORDERED store-node
    # chain that can serve it shard-locally — the primary the item was
    # clustered on first, then the placement's round-robin replicas. A
    # dispatcher can keep an item node-local THROUGH a node outage by
    # falling down the chain instead of scattering the item. [(0,)] per item
    # without a placement map (monolith) or at r=1.
    item_replicas: List[Tuple[int, ...]] = dataclasses.field(
        default_factory=list)


def _tag_of(
    e: TrainingExample, n_shards: int, placement: Optional[PlacementMap]
) -> Tuple[int, int]:
    """(node, shard) routing tag — computed ONCE per example and threaded
    through sort, cut and fanout accounting. Without a placement map the node
    is a constant 0, so the monolith plan (and its item order) is unchanged."""
    shard = shard_of(e.user_id, n_shards)
    node = placement.node_of(e.user_id) if placement is not None else 0
    return (node, shard)


def plan_affine(
    examples: Sequence[TrainingExample],
    n_shards: int,
    base_batch_size: int,
    placement: Optional[PlacementMap] = None,
) -> AffinityPlan:
    """User-clustered plan: sort by (node, shard, user, request_ts,
    request_id) — a TOTAL order, so the plan is invariant under input
    permutation — and cut into base batches at (node, shard) boundaries. All
    lookups in an item target exactly ONE shard on ONE store node (zero
    cross-node fanout, the §4.2.3 symmetric-sharding goal); same-user
    adjacency maximizes window-cache hits."""
    tagged = [(_tag_of(e, n_shards, placement), e) for e in examples]
    tagged.sort(key=lambda te: (te[0], te[1].user_id, te[1].request_ts,
                                te[1].request_id))
    items: List[List[TrainingExample]] = []
    tags: List[List[Tuple[int, int]]] = []
    run: List[TrainingExample] = []
    run_tags: List[Tuple[int, int]] = []
    run_tag = None
    for tag, e in tagged:
        if run and (tag != run_tag or len(run) >= base_batch_size):
            items.append(run)
            tags.append(run_tags)
            run, run_tags = [], []
        run_tag = tag
        run.append(e)
        run_tags.append(tag)
    if run:
        items.append(run)
        tags.append(run_tags)
    return _plan(items, tags, placement)


def plan_arrival_order(
    examples: Sequence[TrainingExample],
    n_shards: int,
    base_batch_size: int,
    placement: Optional[PlacementMap] = None,
) -> AffinityPlan:
    """Baseline plan: arrival order (no clustering) — what a Fat-Row-era
    pipeline does; used as the benchmark control."""
    order = list(examples)
    items = [
        order[i : i + base_batch_size]
        for i in range(0, len(order), base_batch_size)
    ]
    tags = [[_tag_of(e, n_shards, placement) for e in item] for item in items]
    return _plan(items, tags, placement)


def _replica_chain(
    node: int, placement: Optional[PlacementMap]
) -> Tuple[int, ...]:
    """The ordered store-node chain serving a node-affine item: the same
    round-robin anti-affinity rule ``PlacementMap.replicas_of`` uses, so the
    chain names exactly the nodes that hold the item's bytes."""
    if placement is None:
        return (node,)
    r = max(1, min(placement.replication_factor, placement.n_nodes))
    return tuple((node + k) % placement.n_nodes for k in range(r))


def _plan(
    items: List[List[TrainingExample]],
    tags: List[List[Tuple[int, int]]],
    placement: Optional[PlacementMap] = None,
) -> AffinityPlan:
    fanouts = []
    node_fanouts = []
    amortizable = 0
    for item, item_tags in zip(items, tags):
        fanouts.append(len({t[1] for t in item_tags}))
        node_fanouts.append(len({t[0] for t in item_tags}))
        for a, b in zip(item, item[1:]):
            same_window = (
                not a.is_fat
                and not b.is_fat
                and a.user_id == b.user_id
                and a.version is not None
                and b.version is not None
                and (a.version.start_ts, a.version.end_ts)
                == (b.version.start_ts, b.version.end_ts)
            )
            amortizable += int(same_window)
    return AffinityPlan(
        items=items,
        expected_fanout=sum(fanouts) / max(len(fanouts), 1),
        amortizable_pairs=amortizable,
        expected_node_fanout=sum(node_fanouts) / max(len(node_fanouts), 1),
        # the chain keys off the item's clustering tag (arrival-order items
        # mixing nodes use their first example's node: fanout already >1)
        item_replicas=[_replica_chain(item_tags[0][0], placement)
                       for item_tags in tags],
    )
