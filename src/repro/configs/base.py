"""Architecture registry plumbing: ArchSpec + the per-family shape tables.

Every assigned (arch × shape) cell is defined here; the launch layer turns a
(family, config, shape) triple into a step function + input ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                     # "lm" | "gnn" | "recsys"
    full: Any                       # full-size config (dry-run only)
    smoke: Any                      # reduced config (CPU smoke tests)
    shapes: Mapping[str, Mapping[str, Any]]
    notes: str = ""


# -- LM family: seq_len x global_batch; decode_*/long_* lower serve_step -----
LM_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k":    {"kind": "train",   "seq_len": 4_096,   "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32_768,  "batch": 32},
    "decode_32k":  {"kind": "decode",  "seq_len": 32_768,  "batch": 128},
    "long_500k":   {"kind": "decode",  "seq_len": 524_288, "batch": 1},
}

# -- GNN (meshgraphnet) -------------------------------------------------------
GNN_SHAPES: Dict[str, Dict[str, Any]] = {
    "full_graph_sm": {
        "kind": "train", "n_nodes": 2_708, "n_edges": 10_556, "d_feat": 1_433,
    },
    "minibatch_lg": {
        # reddit-scale parent graph; the *lowered* shapes are the padded
        # fanout-(15,10) sampled subgraph for 1024 seed nodes
        "kind": "train_sampled", "parent_nodes": 232_965,
        "parent_edges": 114_615_892, "batch_nodes": 1_024,
        "fanouts": (15, 10), "d_feat": 602,
        "n_nodes": 1_024 + 1_024 * 15 + 1_024 * 15 * 10,   # padded: 180,224
        "n_edges": 1_024 * 15 + 1_024 * 15 * 10,           # padded: 168,960
    },
    "ogb_products": {
        "kind": "train", "n_nodes": 2_449_029, "n_edges": 61_859_140,
        "d_feat": 100,
    },
    "molecule": {
        # 128 disjoint 30-node molecules flattened into one block-diagonal graph
        "kind": "train", "n_nodes": 30 * 128, "n_edges": 64 * 128, "d_feat": 16,
        "graphs": 128,
    },
}

# -- RecSys -------------------------------------------------------------------
RECSYS_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_batch":    {"kind": "train", "batch": 65_536},
    "serve_p99":      {"kind": "serve", "batch": 512},
    "serve_bulk":     {"kind": "serve", "batch": 262_144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1, "n_candidates": 1_000_000},
}
