"""Real-time training-example stream + hourly warehouse ingestion (paper §3.2).

Online streaming training consumes a real-time messaging stream; the same
stream is persisted into hourly warehouse partitions for batch training. During
warehouse ingestion, examples are clustered into **user-keyed buckets** inside
each hourly partition (data-affinity optimization, §4.2.3) so that DPP workers
can amortize one immutable-sequence lookup across a user's temporally-adjacent
examples.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, Dict, Iterator, List, Optional, Sequence

from repro.core import events as ev
from repro.core.versioning import TrainingExample
from repro.storage.sharding import shard_of

MS_PER_HOUR = 3_600_000


class StreamDisconnect(ConnectionError):
    """Transient consumer-side stream failure (broker hiccup, network blip).

    The broker retains unacked messages across a disconnect, so consumers
    recover by reconnecting and re-polling — nothing is lost or duplicated.
    ``StreamingSource`` heals this in place (``SourceStats.reconnects``);
    ``repro.testing.FaultyStream`` injects it deterministically."""


class TrainingExampleStream:
    """Bounded in-memory FIFO modelling the distributed messaging stream.

    Thread-safe: the ingestion service publishes, streaming DPP workers consume.
    Byte accounting measures the stream write bandwidth (Table 1 'primary
    write').

    **Generation pinning** (bifurcated protocol, §3.2): when constructed with a
    ``lease_manager`` (the ``ImmutableUIHStore``), every published VLM example
    acquires a refcounted lease on the generation its version metadata
    references, so daily compaction cannot GC that generation while the
    example is in flight. The consumer releases the lease via ``ack()`` once
    the example has been materialized (drained). An acquire that races a
    compaction losing the generation is counted in ``lease_misses`` — the
    materializer's stale-generation remediation covers that example instead.
    """

    def __init__(self, schema: ev.TraitSchema, capacity: int = 1 << 16,
                 lease_manager=None):
        self.schema = schema
        self._q: Deque[TrainingExample] = collections.deque()
        self._cv = threading.Condition()
        self.capacity = capacity
        self.bytes_published = 0
        self.examples_published = 0
        self._closed = False
        # generation pinning + publish-time wall clocks (freshness metrics)
        self.lease_manager = lease_manager
        self._leases: Dict[int, object] = {}      # request_id -> GenerationLease
        self._pub_wall: Dict[int, float] = {}     # request_id -> publish wall time
        # flipped on by an attaching StreamingSource: publish-time clocks are
        # only recorded (and popped) when a streaming consumer exists — a
        # batch-only publisher must not accrete them
        self.track_freshness = False
        self.leases_acquired = 0
        self.lease_misses = 0
        self.acked = 0

    def publish(self, example: TrainingExample) -> None:
        blob_len = example.payload_bytes(self.schema)
        lease = None
        if (self.lease_manager is not None and example.version is not None
                and example.version.generation >= 0):
            try:
                lease = self.lease_manager.acquire_lease(
                    example.version.generation)
            except KeyError:       # gen GC'd between snapshot and publish:
                self.lease_misses += 1  # remediation re-resolves downstream
        with self._cv:
            while len(self._q) >= self.capacity and not self._closed:
                self._cv.wait()
            if self._closed:
                if lease is not None:
                    lease.release()
                raise RuntimeError("stream closed")
            self._q.append(example)
            if lease is not None:
                self._leases[example.request_id] = lease
                self.leases_acquired += 1
            if self.track_freshness:
                self._pub_wall[example.request_id] = time.perf_counter()
            self.bytes_published += blob_len
            self.examples_published += 1
            self._cv.notify_all()

    def consume(self, timeout: Optional[float] = None) -> Optional[TrainingExample]:
        """Next example, or ``None`` — which means EITHER the wait timed out OR
        the stream is closed and fully drained; disambiguate via ``drained``."""
        with self._cv:
            while not self._q and not self._closed:
                if not self._cv.wait(timeout=timeout):
                    return None
            if not self._q:
                return None
            out = self._q.popleft()
            self._cv.notify_all()
            return out

    @property
    def drained(self) -> bool:
        """True iff the stream is closed AND every example has been consumed —
        the unambiguous end-of-stream signal (``consume`` returning ``None``
        alone cannot distinguish a timeout from exhaustion)."""
        with self._cv:
            return self._closed and not self._q

    @property
    def closed(self) -> bool:
        with self._cv:
            return self._closed

    def lag(self) -> int:
        """Examples published but not yet consumed (stream backlog)."""
        with self._cv:
            return len(self._q)

    def publish_wall(self, request_id: int) -> Optional[float]:
        """Pop the wall-clock publish time of a consumed example (freshness)."""
        return self._pub_wall.pop(request_id, None)

    def ack(self, example) -> None:
        """Release the generation lease of a drained example (id or example)."""
        rid = getattr(example, "request_id", example)
        lease = self._leases.pop(rid, None)
        if lease is not None:
            lease.release()
            self.acked += 1

    def pending_leases(self) -> int:
        return len(self._leases)

    def release_leases(self) -> int:
        """Drop every outstanding lease (shutdown path). Returns the count."""
        n = 0
        while self._leases:
            try:
                _, lease = self._leases.popitem()
            except KeyError:
                break
            lease.release()
            n += 1
        return n

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def __iter__(self) -> Iterator[TrainingExample]:
        while True:
            ex = self.consume()
            if ex is None:
                return
            yield ex


@dataclasses.dataclass
class WarehousePartition:
    hour: int
    # bucket id -> serialized examples (user-clustered)
    buckets: Dict[int, List[bytes]]

    def examples_bytes(self) -> int:
        return sum(len(b) for blobs in self.buckets.values() for b in blobs)


class Warehouse:
    """Hourly-partitioned batch training tables with user bucketing.

    ``n_buckets`` buckets per partition, bucket key = the SAME hash partition
    function used by the immutable UIH store (symmetric sharding): a bucket's
    lookups all route to one storage shard."""

    def __init__(self, schema: ev.TraitSchema, n_buckets: int = 8,
                 cluster_by_user: bool = True):
        self.schema = schema
        self.n_buckets = n_buckets
        self.cluster_by_user = cluster_by_user
        self._partitions: Dict[int, WarehousePartition] = {}
        self.bytes_written = 0
        self.bytes_read = 0

    def ingest(self, examples: Sequence[TrainingExample]) -> None:
        staged: Dict[int, Dict[int, List[TrainingExample]]] = {}
        for exm in examples:
            hour = exm.request_ts // MS_PER_HOUR
            if self.cluster_by_user:
                bucket = shard_of(exm.user_id, self.n_buckets)
            else:
                bucket = exm.request_id % self.n_buckets  # arrival order spray
            staged.setdefault(hour, {}).setdefault(bucket, []).append(exm)
        for hour, buckets in staged.items():
            part = self._partitions.setdefault(
                hour, WarehousePartition(hour=hour, buckets={})
            )
            for bucket, exs in buckets.items():
                if self.cluster_by_user:
                    # cluster a user's temporally-adjacent examples together
                    exs = sorted(exs, key=lambda e: (e.user_id, e.request_ts))
                blobs = [e.to_bytes(self.schema) for e in exs]
                part.buckets.setdefault(bucket, []).extend(blobs)
                self.bytes_written += sum(len(b) for b in blobs)

    def hours(self) -> List[int]:
        return sorted(self._partitions)

    def read_partition(self, hour: int) -> List[TrainingExample]:
        """All examples of one hour; an hour with no data reads as empty (a
        backfill sweep over a contiguous hour range must not trip on gaps)."""
        part = self._partitions.get(hour)
        if part is None:
            return []
        out: List[TrainingExample] = []
        for bucket in sorted(part.buckets):
            for blob in part.buckets[bucket]:
                self.bytes_read += len(blob)
                out.append(TrainingExample.from_bytes(blob, self.schema))
        return out

    def iter_bucketed(self, hour: int) -> Iterator[List[TrainingExample]]:
        """Yield one user-clustered bucket at a time (the batch-training unit of
        work handed to a DPP worker); an empty hour yields nothing."""
        part = self._partitions.get(hour)
        if part is None:
            return
        for bucket in sorted(part.buckets):
            blobs = part.buckets[bucket]
            self.bytes_read += sum(len(b) for b in blobs)
            yield [TrainingExample.from_bytes(b, self.schema) for b in blobs]

    def hour_rows(self, hour: int) -> int:
        """Row count of one hour's partition WITHOUT reading it (no byte
        accounting) — feed checkpoint cursors are metadata-only."""
        part = self._partitions.get(hour)
        if part is None:
            return 0
        return sum(len(blobs) for blobs in part.buckets.values())

    def total_bytes(self) -> int:
        return sum(p.examples_bytes() for p in self._partitions.values())
