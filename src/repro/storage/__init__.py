"""Storage tier: mutable + immutable UIH stores, trait-aware columnar codec,
offloaded compaction, symmetric sharding, warehouse/stream ingestion."""
