"""Pallas TPU kernels for the materialization + recsys hot paths.

Each kernel directory holds <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper; interpret=True on CPU), and ref.py
(pure-jnp oracle used by the allclose test sweeps)."""
