"""End-to-end training driver: the complete stack, one process.

  synthetic traffic -> mutable/immutable tiers -> VLM snapshots -> warehouse
  -> declarative read path: DatasetSpec -> open_feed (elastic DPP pool,
     vectorized featurize, slot-based rebatching, device prefetch)
  -> DLRM-UIH trainer (AdamW, grad accumulation, crash-safe checkpointing).

Run:  PYTHONPATH=src python examples/train_seqrec.py [--steps 200] [--resume]
The model is the paper's flagship tenant (DLRM + UIH transformer encoder) at a
CPU-sized config; the same driver drives pod-scale meshes via --arch configs.
The feed is ONE DatasetSpec — adding a tenant means writing another spec, not
another pipeline.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core.projection import TenantProjection
from repro.core.simulation import ProductionSim, SimConfig
from repro.data import DatasetSpec, SimSource, open_feed
from repro.dpp.elastic import ElasticConfig, ElasticController
from repro.dpp.featurize import FeatureSpec
from repro.models import recsys as R
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import Trainer, TrainerConfig

SEQ_LEN = 48
BATCH = 32
BASE_BATCH = 8


def build_sim(seed: int = 0) -> ProductionSim:
    sim = ProductionSim(SimConfig(
        stream=ev.StreamConfig(n_users=32, n_items=4_000, days=7,
                               events_per_user_day_mean=40.0, seed=seed),
        stripe_len=32, requests_per_user_day=6, seed=seed,
    ))
    sim.run_days(6, capture_reference=False)
    return sim


def dataset_spec(steps: int, prefetch: bool) -> DatasetSpec:
    """The whole feed, declaratively: tenant projection + source + knobs."""
    tenant = TenantProjection(
        "dlrm-uih", seq_len=SEQ_LEN,
        feature_groups=("core", "sideinfo"),
        traits_per_group={"core": ("timestamp", "item_id", "action_type"),
                          "sideinfo": ("category",)})
    features = FeatureSpec(seq_len=SEQ_LEN,
                           uih_traits=("item_id", "action_type", "category"),
                           candidate_fields=("item_id",),
                           label_fields=("click",))
    return DatasetSpec(
        tenant=tenant,
        source=SimSource(min_rows=steps * BATCH + BATCH),  # cover the run
        batch_size=BATCH, base_batch_size=BASE_BATCH,
        prefetch_depth=2 if prefetch else 0,
        n_workers=2, window_cache_size=256, features=features,
    )


def prep(b, cfg):
    return {
        "uih_item_id": (b["uih_item_id"] % cfg.item_vocab).astype(np.int32),
        "uih_action_type": (b["uih_action_type"] % 16).astype(np.int32),
        "uih_mask": b["uih_mask"],
        "cand_item_id": (b["cand_item_id"] % cfg.item_vocab).astype(np.int32),
        "sparse_ids": np.stack([b["user_id"] % cfg.field_vocab,
                                b["cand_item_id"] % cfg.field_vocab],
                               1).astype(np.int32),
        "dense": np.stack([b["uih_mask"].sum(1)] * 4, 1).astype(np.float32)
        / SEQ_LEN,
        "label": b["label_click"].astype(np.float32),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_seqrec_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="host-only feed (seed-style sync device transfer)")
    args = ap.parse_args()

    cfg = R.DLRMUIHConfig(
        name="seqrec", seq_len=SEQ_LEN, d_seq=32, n_seq_layers=2, n_heads=4,
        n_dense=4, n_sparse=2, embed_dim=16, item_vocab=4_096,
        field_vocab=4_096, compute_dtype=jnp.float32, remat=False)
    params = R.init_dlrm_uih(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"DLRM-UIH: {n_params/1e6:.2f}M params, seq_len={SEQ_LEN}")

    sim = build_sim()
    trainer = Trainer(
        lambda p, b: R.dlrm_uih_loss(p, b, cfg), params,
        TrainerConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=20,
                                      total_steps=args.steps),
                      ckpt_dir=args.ckpt_dir, ckpt_every=50, grad_accum=2,
                      log_every=20))
    if args.resume and trainer.try_resume():
        print(f"resumed from step {trainer.step}")

    # ONE declarative call replaces the old hand-wired client/pool/prefetcher
    feed = open_feed(
        dataset_spec(args.steps, prefetch=not args.no_prefetch), sim,
        prep_fn=lambda b: prep(b, cfg),
        controller=ElasticController(ElasticConfig(min_workers=1,
                                                   max_workers=8)))
    t0 = time.perf_counter()
    trainer.fit(feed, max_steps=args.steps)
    dt = time.perf_counter() - t0
    feed.close(timeout=10.0)   # drain leftover items so workers exit cleanly
    first = np.mean([h["loss"] for h in trainer.history[:10]])
    last = np.mean([h["loss"] for h in trainer.history[-10:]])
    st = feed.stats()
    cs, ws = st.client, st.workers
    print(f"\ntrained {trainer.step} steps in {dt:.1f}s "
          f"({trainer.step / dt:.1f} steps/s)")
    print(f"loss {first:.4f} -> {last:.4f}")
    print(f"feed: starvation {cs.starvation_pct:.1f}% "
          f"(host {cs.starved_host_s*1e3:.0f}ms, h2d {cs.starved_h2d_s*1e3:.0f}ms), "
          f"h2d total {cs.h2d_time_s*1e3:.0f}ms, slot reuses {cs.slot_reuses}, "
          f"peak workers {st.peak_workers}, worker waste {ws.waste_pct:.1f}%")
    print(f"featurize {ws.featurize_time_s*1e3:.0f}ms over "
          f"{ws.examples} examples ({ws.base_batches} base batches)")


if __name__ == "__main__":
    main()
