"""Shared benchmark plumbing: result records + the standard traffic sim."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core import events as ev
from repro.core.simulation import ProductionSim, SimConfig


@dataclasses.dataclass
class BenchResult:
    name: str
    us_per_call: float
    derived: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def csv(self) -> str:
        derived = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.us_per_call:.2f},{derived}"


def timeit(fn: Callable[[], Any], repeats: int = 3) -> float:
    """Median wall time in microseconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def standard_sim(mode: str, users: int = 24, days: int = 6,
                 req_per_day: int = 6, events_mean: float = 60.0,
                 seed: int = 42, label_fn=None) -> ProductionSim:
    cfg = SimConfig(
        stream=ev.StreamConfig(
            n_users=users, n_items=5_000, days=days + 1,
            events_per_user_day_mean=events_mean, seed=seed,
        ),
        stripe_len=32,
        requests_per_user_day=req_per_day,
        lookback_ms=days * ev.MS_PER_DAY,
        n_shards=8,
        mode=mode,
        seed=seed,
    )
    sim = ProductionSim(cfg)
    if label_fn is not None:
        sim.label_fn = label_fn
    sim.run_days(days, capture_reference=False)
    return sim
