"""Trait-aware columnar encoding for UIH stripes (paper §4.1.2).

A stripe is a column-oriented matrix: rows = chronologically ordered events,
columns = typed traits. Encodings exploit per-trait density/value structure:

  * ``dense_monotone`` (timestamps): delta encoding + minimal bit-width packing
  * ``dense_id`` / ``dense_value``: frame-of-reference (min-offset) + bit-width
  * ``sparse_flag`` (like/comment/share): presence bitmap (packbits); raw int8
    fallback if the column is actually dense
  * ``categorical``: dictionary (unique values) + bit-width-packed codes

The serialized layout stores a msgpack header with *per-column byte offsets*, so
**selective decoding** (§4.1.2 "secondary-level projection") skips irrelevant
columns entirely at the byte level. An optional zstd pass compresses the column
payloads (off by default: the bit-level codecs already dominate, and benchmarks
measure both).

``StripeDecodeCache`` is the store-side block-cache analogue (§4.2.3) for the
batched read path: a bounded, thread-safe LRU of *decoded* stripes keyed on
``(blob identity, traits)``, so a hot stripe touched by many requests of one
batch (same-user, same-day traffic) is decoded once and shared.
"""
from __future__ import annotations

import struct
import threading
import zlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import msgpack
import numpy as np

from repro.core import events as ev

MAGIC = b"UIHC"
VERSION = 1

_WIDTHS = (np.uint8, np.uint16, np.uint32, np.uint64)


def _pack_unsigned(arr: np.ndarray) -> Tuple[bytes, dict]:
    """Frame-of-reference + minimal byte-width packing of an integer column."""
    assert arr.ndim == 1
    if arr.size == 0:
        return b"", {"codec": "empty", "n": 0}
    lo = int(arr.min())
    shifted = (arr.astype(np.int64) - lo).astype(np.uint64)
    hi = int(shifted.max())
    for w in _WIDTHS:
        if hi <= np.iinfo(w).max:
            payload = shifted.astype(w).tobytes()
            return payload, {"codec": "for", "n": int(arr.size), "lo": lo,
                             "w": int(np.dtype(w).itemsize)}
    raise AssertionError("unreachable")


def _unpack_unsigned(payload: bytes, meta: dict, dtype: np.dtype) -> np.ndarray:
    if meta["codec"] == "empty":
        return np.zeros(0, dtype=dtype)
    w = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[meta["w"]]
    arr = np.frombuffer(payload, dtype=w).astype(np.int64) + meta["lo"]
    return arr.astype(dtype)


def encode_column(arr: np.ndarray, encoding: str) -> Tuple[bytes, dict]:
    n = int(arr.size)
    if n == 0:
        return b"", {"codec": "empty", "n": 0, "enc": encoding}

    if encoding == ev.DENSE_MONOTONE:
        base = int(arr[0])
        deltas = np.diff(arr.astype(np.int64), prepend=arr[0])  # deltas[0]=0
        payload, meta = _pack_unsigned(deltas)
        meta.update(enc=encoding, codec="delta", base=base, inner=meta["codec"])
        return payload, meta

    if encoding == ev.SPARSE_FLAG:
        nz = int(np.count_nonzero(arr))
        if nz * 8 < n:  # sparse enough for a presence bitmap to pay off
            bits = np.packbits(arr.astype(bool))
            return bits.tobytes(), {"codec": "bitmap", "n": n, "enc": encoding}
        return arr.astype(np.int8).tobytes(), {"codec": "raw8", "n": n, "enc": encoding}

    if encoding == ev.CATEGORICAL:
        uniq, codes = np.unique(arr, return_inverse=True)
        if uniq.size <= max(2, n // 4):  # dictionary pays off
            code_payload, code_meta = _pack_unsigned(codes.astype(np.int64))
            dict_payload, dict_meta = _pack_unsigned(uniq.astype(np.int64))
            header = {"codec": "dict", "n": n, "enc": encoding,
                      "codes": code_meta, "dict": dict_meta,
                      "split": len(code_payload)}
            return code_payload + dict_payload, header
        payload, meta = _pack_unsigned(arr.astype(np.int64))
        meta.update(enc=encoding)
        return payload, meta

    # DENSE_ID / DENSE_VALUE and any unknown encoding: frame-of-reference pack
    payload, meta = _pack_unsigned(arr.astype(np.int64))
    meta.update(enc=encoding)
    return payload, meta


def decode_column(payload: bytes, meta: dict, dtype: np.dtype) -> np.ndarray:
    codec = meta["codec"]
    if codec == "empty":
        return np.zeros(0, dtype=dtype)
    if codec == "delta":
        inner = dict(meta)
        inner["codec"] = meta["inner"]
        deltas = _unpack_unsigned(payload, inner, np.int64)
        out = np.cumsum(deltas) + meta["base"]
        # cumsum includes deltas[0]=0 so out[0]=base
        return out.astype(dtype)
    if codec == "bitmap":
        n = meta["n"]
        bits = np.unpackbits(np.frombuffer(payload, dtype=np.uint8), count=n)
        return bits.astype(dtype)
    if codec == "raw8":
        return np.frombuffer(payload, dtype=np.int8).astype(dtype)
    if codec == "dict":
        split = meta["split"]
        codes = _unpack_unsigned(payload[:split], meta["codes"], np.int64)
        dictionary = _unpack_unsigned(payload[split:], meta["dict"], np.int64)
        return dictionary[codes].astype(dtype)
    if codec == "for":
        return _unpack_unsigned(payload, meta, dtype)
    raise ValueError(f"unknown codec {codec}")


# ---------------------------------------------------------------------------
# Stripe-level encode/decode
# ---------------------------------------------------------------------------

def stripe_checksum(batch: ev.EventBatch) -> int:
    """Order-sensitive checksum over all columns (used for O2O validation)."""
    crc = 0
    for name in sorted(batch.keys()):
        crc = zlib.crc32(name.encode(), crc)
        crc = zlib.crc32(np.ascontiguousarray(batch[name]).tobytes(), crc)
    return crc & 0xFFFFFFFF


def encode_stripe(
    batch: ev.EventBatch,
    schema: ev.TraitSchema,
    compress: bool = False,
) -> bytes:
    """Encode an event batch into a self-describing stripe blob."""
    n = ev.batch_len(batch)
    cols: List[dict] = []
    payloads: List[bytes] = []
    offset = 0
    for name in batch.keys():
        spec = schema.spec(name)
        payload, meta = encode_column(batch[name], spec.encoding)
        meta["name"] = name
        meta["dtype"] = np.dtype(spec.dtype).str
        meta["off"] = offset
        meta["len"] = len(payload)
        offset += len(payload)
        cols.append(meta)
        payloads.append(payload)
    body = b"".join(payloads)
    if compress:
        import zstandard as zstd

        body = zstd.ZstdCompressor(level=3).compress(body)
    header = msgpack.packb(
        {"n": n, "cols": cols, "zstd": bool(compress),
         "crc": stripe_checksum(batch)},
        use_bin_type=True,
    )
    return MAGIC + struct.pack("<HI", VERSION, len(header)) + header + body


def _read_header(blob: bytes) -> Tuple[dict, int]:
    assert blob[:4] == MAGIC, "bad stripe magic"
    version, hlen = struct.unpack_from("<HI", blob, 4)
    assert version == VERSION
    header = msgpack.unpackb(blob[10 : 10 + hlen], raw=False)
    return header, 10 + hlen


def stripe_num_events(blob: bytes) -> int:
    header, _ = _read_header(blob)
    return header["n"]


def decode_stripe(
    blob: bytes,
    schema: ev.TraitSchema,
    traits: Optional[Sequence[str]] = None,
) -> ev.EventBatch:
    """Decode a stripe; ``traits`` enables byte-level selective decoding."""
    header, body_off = _read_header(blob)
    body = blob[body_off:]
    if header["zstd"]:
        import zstandard as zstd

        body = zstd.ZstdDecompressor().decompress(body)
    want = set(traits) if traits is not None else None
    out: ev.EventBatch = {}
    for meta in header["cols"]:
        name = meta["name"]
        if want is not None and name not in want:
            continue  # selective decode: skip at byte level
        payload = body[meta["off"] : meta["off"] + meta["len"]]
        out[name] = decode_column(payload, meta, np.dtype(meta["dtype"]))
    if want is not None:
        missing = want - set(out)
        assert not missing, f"stripe missing traits {missing}"
    return out


class StripeDecodeCache:
    """Bounded LRU of decoded stripes keyed on ``(blob identity, traits)``.

    The cache holds a reference to each cached blob, so ``id(blob)`` stays
    unique among live keys (an evicted entry drops its reference and the key
    with it). Hits return a shallow copy of the column dict — the arrays are
    shared read-only, the dict is caller-private. Thread-safe: the batched
    executor decodes from several shard threads concurrently.
    """

    def __init__(self, max_entries: int = 256):
        assert max_entries > 0
        self.max_entries = max_entries
        # key -> (blob ref, decoded batch)
        self._entries: "OrderedDict[Tuple[int, Optional[Tuple[str, ...]]], Tuple[bytes, ev.EventBatch]]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(
        self,
        blob: bytes,
        schema: ev.TraitSchema,
        traits: Optional[Sequence[str]] = None,
    ) -> Tuple[ev.EventBatch, bool]:
        """Decoded stripe + whether it was served from cache."""
        key = (id(blob), tuple(traits) if traits is not None else None)
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and entry[0] is blob:
                self._entries.move_to_end(key)
                self.hits += 1
                return dict(entry[1]), True
        batch = decode_stripe(blob, schema, traits)
        for arr in batch.values():  # shared across callers: freeze, don't corrupt
            arr.flags.writeable = False
        with self._lock:
            self.misses += 1
            self._entries[key] = (blob, batch)
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
        return dict(batch), False

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)


def decoded_bytes_for(blob: bytes, traits: Optional[Sequence[str]] = None) -> int:
    """How many payload bytes a (possibly projected) decode touches.

    Used by the benchmarks to account selective-decoding I/O savings without
    relying on wall-clock noise.
    """
    header, _ = _read_header(blob)
    want = set(traits) if traits is not None else None
    total = 0
    for meta in header["cols"]:
        if want is None or meta["name"] in want:
            total += meta["len"]
    return total
