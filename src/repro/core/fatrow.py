"""Fat Row problem formalization (paper §2).

Analytic cost model used by ``benchmarks/fig2_cost_wall.py`` to reproduce the
storage/IO-wall estimation (Figure 2) and the "Fat Row Wall" definition of §5.2
(wall = sequence length where data-service : GPU-power ratio exceeds 0.75).

The measured counterpart (actual bytes through our stores) lives in
``benchmarks/table1_system_efficiency.py``; this module is the closed-form
K-fold amplification model.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class WorkloadModel:
    """Per-user-day workload constants (order-of-magnitude production-like).

    The GPU term models a production DLRM: compute is dominated by the dense
    interaction stack (``gpu_flops_fixed`` per example) with only a weak
    per-event term (embedding pooling / lightweight sequence encoders), while
    the DATA payload is strictly linear in sequence length — this asymmetry is
    exactly why a storage/IO wall appears as sequences scale (paper §2.2)."""

    requests_per_user_day: float = 24.0        # K: ranking requests / user / day
    bytes_per_event: float = 24.0              # encoded UIH bytes per event
    nonseq_bytes_per_example: float = 8_192.0  # labels + scalar/dense features
    replay_factor: float = 3.0                 # each example trained this often
    gpu_flops_fixed: float = 5.0e9             # dense stack, per example
    gpu_flops_per_token: float = 2.0e4         # per UIH event (pool/encode)
    gpu_cost_per_flop: float = 5.6e-14         # relative cost units
    storage_cost_per_byte_day: float = 2.0e-9
    io_cost_per_byte: float = 1.0e-9
    lookup_cache_hit: float = 0.8              # immutable-store block cache


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    storage: float
    write_io: float
    read_io: float
    gpu: float

    @property
    def data_services(self) -> float:
        return self.storage + self.write_io + self.read_io

    @property
    def ratio(self) -> float:
        return self.data_services / max(self.gpu, 1e-30)


def _gpu_cost(seq_len: int, m: WorkloadModel) -> float:
    flops = m.gpu_flops_fixed + seq_len * m.gpu_flops_per_token
    return m.requests_per_user_day * m.replay_factor * flops * m.gpu_cost_per_flop


def fat_row_cost(seq_len: int, m: WorkloadModel = WorkloadModel()) -> CostBreakdown:
    """Fat Row: every one of the K daily requests materializes the full
    sequence -> K-fold duplication of the (seq_len * bytes_per_event) payload."""
    k = m.requests_per_user_day
    seq_bytes = seq_len * m.bytes_per_event
    example_bytes = seq_bytes + m.nonseq_bytes_per_example
    written = k * example_bytes                       # per user-day
    stored = written                                  # retained 1 day-equivalent
    read = written * m.replay_factor
    return CostBreakdown(
        storage=stored * m.storage_cost_per_byte_day,
        write_io=written * m.io_cost_per_byte,
        read_io=read * m.io_cost_per_byte,
        gpu=_gpu_cost(seq_len, m),
    )


def vlm_cost(
    seq_len: int,
    m: WorkloadModel = WorkloadModel(),
    mutable_fraction: float = 0.02,
    version_metadata_bytes: float = 40.0,
    lookup_efficiency: float = 3.4,   # single-level store read throughput per
                                      # host resource vs the primary store (§5.1)
) -> CostBreakdown:
    """Versioned late materialization: sequences stored once (normalized tier),
    examples carry only the mutable slice + O(1) version metadata; training
    re-reads the canonical copy through the read-optimized immutable store."""
    k = m.requests_per_user_day
    seq_bytes = seq_len * m.bytes_per_event
    mutable_bytes = mutable_fraction * seq_bytes
    example_bytes = mutable_bytes + version_metadata_bytes + m.nonseq_bytes_per_example
    written = k * example_bytes + seq_bytes           # canonical copy written once
    stored = written
    primary_read = k * example_bytes * m.replay_factor
    # sequence lookups hit the immutable tier: block cache absorbs most of the
    # (streaming-dominated) traffic, the single-level layout serves misses
    # `lookup_efficiency`x cheaper per byte in host resources
    lookup_read = (k * seq_bytes * m.replay_factor
                   * (1.0 - m.lookup_cache_hit) / lookup_efficiency)
    return CostBreakdown(
        storage=stored * m.storage_cost_per_byte_day,
        write_io=written * m.io_cost_per_byte,
        read_io=(primary_read + lookup_read) * m.io_cost_per_byte,
        gpu=_gpu_cost(seq_len, m),
    )


def fat_row_wall(
    threshold: float = 0.75,
    m: WorkloadModel = WorkloadModel(),
    max_len: int = 1 << 20,
) -> int:
    """Smallest sequence length where Fat Row data-services/GPU ratio > threshold."""
    lo, hi = 1, max_len
    if fat_row_cost(hi, m).ratio <= threshold:
        return hi
    while lo < hi:
        mid = (lo + hi) // 2
        if fat_row_cost(mid, m).ratio > threshold:
            hi = mid
        else:
            lo = mid + 1
    return lo
