"""Read-optimized immutable UIH store (paper §4.1.2).

Single-level layout: each user's long-term history is partitioned into
fixed-length temporal *stripes* keyed by the multi-dimensional composite key
``(user_id, feature_group, subsequence_start_ts)``. Stripes are produced
pre-sorted by the offloaded compaction pipeline and **bulk-loaded** as a whole
generation — there is no write path other than ``bulk_load``, hence no LSM
multi-level read amplification and no compaction-induced write amplification.

The read path is a bounded *multi-range scan*: for each request the store
locates the stripe run overlapping ``[start_ts, end_ts]`` (one "seek") and then
reads stripes sequentially. Projection pushdown happens server-side in three
dimensions (§4.1.2):

  1. sequence-length projection — scan only as many stripes (from the most
     recent backwards) as needed for the tenant's ``max_events``;
  2. feature-group projection — the composite key isolates groups physically;
  3. trait projection — selective byte-level decoding inside a stripe.

Batched reads are *planned* (§4.2.3, "optimized multi-range scan with parallel
I/O"). ``plan()`` dedupes identical ``(user_id, group, bounds, max_events,
traits)`` requests and groups the survivors by shard; ``execute_plan()`` then
runs the shard groups concurrently on a thread pool, charging the
``latency_model`` once per shard (parallel remote I/O) instead of once for the
whole batch, and decoding each stripe blob at most once per batch via the
``columnar.StripeDecodeCache`` LRU. ``IOStats`` exposes the plan's work
savings: ``dedup_hits`` (requests answered by an identical in-batch twin),
``decode_cache_hits`` (stripe decodes skipped), and ``parallel_shards``
(cumulative shard fanout executed concurrently by batched scans).

**Generation leases** (bifurcated O2O protocol, §3.2): streaming training has
examples in flight that reference the generation observed at T_request; daily
compaction must not yank that generation out from under them. A publisher
acquires a refcounted ``GenerationLease`` per in-flight example; ``bulk_load``
then *retains* a superseded generation while leases on it remain, and a
``ScanRequest`` carrying ``generation >= 0`` is served from the retained
table — the exact event set the ranking model saw, even if the new generation
scrubbed or re-cut history. Once the last lease is released (the example has
been materialized/trained) the retained generation is garbage-collected.
Scanning a generation that is neither live nor retained raises
``GenerationUnavailable``; the ``Materializer`` remediates by re-resolving
against the live generation with the version's ``end_ts`` clamp plus checksum
revalidation.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import events as ev
from repro.storage import columnar
from repro.storage.sharding import ShardRouter


@dataclasses.dataclass(frozen=True)
class Stripe:
    start_ts: int
    end_ts: int
    n_events: int
    blob: bytes


@dataclasses.dataclass(frozen=True)
class ScanRequest:
    user_id: int
    group: str
    start_ts: int            # inclusive temporal lower bound (version metadata)
    end_ts: int              # inclusive temporal upper bound (version metadata)
    max_events: int = -1     # sequence-length projection (-1 = unbounded)
    traits: Optional[Tuple[str, ...]] = None  # trait projection (None = group's all)
    generation: int = -1     # -1 = live; >= 0 = pinned (leased) generation

    def __post_init__(self):
        """Validate at the API boundary, not deep inside ``_scan_into``.

        ``start_ts > end_ts`` is NOT rejected: inverted bounds are a
        legitimate empty-window request the snapshotter produces routinely —
        a negative ``end_ts`` is the "nothing consolidated yet" watermark
        (examples logged before the first compaction), and a user returning
        after idling longer than the lookback window yields
        ``end_ts = min(watermark, request_ts) < start_ts``. Both scan empty."""
        if self.max_events < -1:
            raise ValueError(
                f"max_events must be >= -1 (-1 = unbounded), got {self.max_events}")
        if self.generation < -1:
            raise ValueError(
                f"generation must be >= -1 (-1 = live), got {self.generation}")


class GenerationUnavailable(KeyError):
    """The requested generation is neither live nor retained by a lease."""


class GenerationLease:
    """Refcounted pin on one immutable generation (context-manager friendly).

    ``release()`` is idempotent; dropping the last lease on a superseded
    generation garbage-collects its tables."""

    __slots__ = ("generation", "_store", "_released")

    def __init__(self, store: "ImmutableUIHStore", generation: int):
        self.generation = generation
        self._store = store
        self._released = False

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._store._release_lease(self.generation)

    def __enter__(self) -> "GenerationLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


@dataclasses.dataclass
class LeaseStats:
    acquired: int = 0
    released: int = 0
    generations_retained: int = 0   # superseded generations kept for leases
    generations_gc: int = 0         # retained generations dropped at last release
    lease_recoveries: int = 0       # node leases reconciled after a node death
    #                                 (release fanned in while the node was
    #                                 down; settled by ``recover()``)


@dataclasses.dataclass
class _GenTable:
    """One bulk-loaded generation: shard tables + lease refcount."""

    gen: int
    shards: List[Dict[Tuple[int, str], Tuple[List[int], List["Stripe"]]]]
    refs: int = 0


@dataclasses.dataclass
class IOStats:
    seeks: int = 0
    stripes_read: int = 0
    bytes_scanned: int = 0    # stripe blob bytes touched (I/O)
    bytes_decoded: int = 0    # payload bytes actually decoded (selective decode)
    requests: int = 0         # scans actually executed (post-dedupe)
    batched_requests: int = 0
    dedup_hits: int = 0         # requests answered by an identical in-plan twin
    decode_cache_hits: int = 0  # stripe decodes served from the decode LRU
    parallel_shards: int = 0    # cumulative shard fanout of batched executions
    pinned_scans: int = 0       # scans served from a retained (leased) generation
    subsumed_hits: int = 0      # requests carved from a wider in-plan request
    #                             (union-projection planning, §2.3/§4.2.2)
    # -- replicated-tier health counters (sharded client only, DESIGN.md §12) --
    failovers: int = 0          # reads re-routed off their primary to a replica
    hedged_reads: int = 0       # speculative replica reads fired on a slow node
    hedge_wins: int = 0         # hedges that beat the primary round-trip
    breaker_opens: int = 0      # circuit-breaker CLOSED/HALF_OPEN -> OPEN flips
    degraded_scans: int = 0     # reads that failed on EVERY replica (retryable)
    partial_reissues: int = 0   # failed node groups re-issued while completed
    #                             sibling groups of the same plan were retained

    def snapshot(self) -> "IOStats":
        return dataclasses.replace(self)

    def delta(self, since: "IOStats") -> "IOStats":
        return IOStats(*(getattr(self, f.name) - getattr(since, f.name)
                         for f in dataclasses.fields(IOStats)))

    def merge(self, other: "IOStats") -> None:
        for f in dataclasses.fields(IOStats):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass
class ScanPlan:
    """Deduped, shard-grouped execution plan for a batch of scan requests.

    **Union-projection planning** (§2.3, §4.2.2): beyond exact-duplicate
    dedupe, a request whose (user, group, bounds, generation) matches a wider
    in-plan request with a superset of traits and an equal-or-larger
    ``max_events`` budget never hits storage — it is *derived* by carving the
    wider result (tail-slice to the narrower sequence budget + trait
    projection). ``shard_groups`` only dispatches the covering requests;
    ``derived`` maps each subsumed unique index to its covering unique index.

    The grouping key is the executor's concurrency domain: the monolith keys
    by shard, the disaggregated ``ShardedUIHStore`` keys by store node.
    """

    unique: List[ScanRequest]          # deduped requests, first-seen order
    assignment: List[int]              # original request idx -> unique idx
    shard_groups: Dict[int, List[int]]  # shard/node -> indices into ``unique``
    derived: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def dedup_hits(self) -> int:
        return len(self.assignment) - len(self.unique)

    @property
    def subsumed(self) -> int:
        return len(self.derived)

    @property
    def fanout(self) -> int:
        return len(self.shard_groups)


def build_scan_plan(reqs, route, effective_traits) -> ScanPlan:
    """Shared planner behind every store implementation's ``plan()``:
    dedupe identical requests, subsume projection-contained ones
    (union-projection planning), group the surviving roots by ``route(req)``
    — the executor's concurrency domain (shard for the monolith, node for
    the sharded client).

    ``effective_traits(req)`` resolves a request's trait set (None = the
    group's full schema) so subsumption compares real column sets."""
    index: Dict[ScanRequest, int] = {}
    unique: List[ScanRequest] = []
    assignment: List[int] = []
    by_window: Dict[tuple, List[int]] = {}
    for r in reqs:
        j = index.get(r)
        if j is None:
            j = index[r] = len(unique)
            unique.append(r)
            by_window.setdefault(
                (r.user_id, r.group, r.start_ts, r.end_ts, r.generation),
                []).append(j)
        assignment.append(j)

    derived: Dict[int, int] = {}
    inf = float("inf")
    for js in by_window.values():
        if len(js) < 2:
            continue
        info = {
            j: (unique[j].max_events if unique[j].max_events >= 0 else inf,
                frozenset(effective_traits(unique[j])))
            for j in js
        }
        # widest first: a later (narrower) request can only be covered by
        # an already-accepted root
        roots: List[int] = []
        for j in sorted(js, key=lambda j: (info[j][0], len(info[j][1])),
                        reverse=True):
            me_j, tr_j = info[j]
            cover = next(
                (k for k in roots
                 if info[k][0] >= me_j and info[k][1] >= tr_j), None)
            if cover is None:
                roots.append(j)
            else:
                derived[j] = cover

    shard_groups: Dict[int, List[int]] = {}
    for j, r in enumerate(unique):
        if j in derived:
            continue
        shard_groups.setdefault(route(r), []).append(j)
    return ScanPlan(unique=unique, assignment=assignment,
                    shard_groups=shard_groups, derived=derived)


class ImmutableUIHStore:
    # Optional per-run telemetry (repro.obs.Telemetry) attached by
    # ``open_feed``; every hook below degrades to one is-None check.
    # Sharded tiers attach to the tier object only — member StoreNodes stay
    # untelemetered so flips/leases are not double-counted.
    telemetry = None

    def __init__(
        self,
        schema: Optional[ev.TraitSchema] = None,
        n_shards: int = 8,
        decode_cache_size: int = 256,
    ):
        self.schema = schema or ev.default_schema()
        self.router = ShardRouter(n_shards)
        self.n_shards = n_shards
        # live generation: shard -> (user_id, group) -> (start_ts list, stripes)
        self._live = _GenTable(gen=-1, shards=[{} for _ in range(n_shards)])
        # superseded generations pinned by outstanding leases (gen -> table)
        self._retained: Dict[int, _GenTable] = {}
        self._gen_lock = threading.Lock()
        self.lease_stats = LeaseStats()
        self.generation = -1
        self.stats = IOStats()
        self.bulk_load_bytes = 0
        # Optional remote-I/O latency emulation for DPP benchmarks:
        # callable(seeks, bytes_scanned, shard_fanout) -> seconds to sleep.
        # Batched execution charges it once per shard group (parallel I/O).
        self.latency_model = None
        self.decode_cache = (
            columnar.StripeDecodeCache(decode_cache_size)
            if decode_cache_size > 0 else None
        )
        self._stats_lock = threading.Lock()
        # eager: an idle executor spawns no threads until first submit, and
        # eager construction avoids double-create races on first batched scan
        self._pool = ThreadPoolExecutor(
            max_workers=min(n_shards, 16), thread_name_prefix="uih-scan"
        )

    # -- compat: the live generation's shard tables --------------------------
    @property
    def _shards(self) -> List[Dict[Tuple[int, str], Tuple[List[int], List[Stripe]]]]:
        return self._live.shards

    # -- bulk load (write path) ---------------------------------------------
    def bulk_load(
        self,
        tables: Dict[Tuple[int, str], List[Stripe]],
        generation: int,
    ) -> None:
        """Install a new compaction generation as the live read target.

        ``tables`` maps (user_id, group) -> chronologically ordered stripes.
        Pre-sorted input is *required* (compaction guarantees it); the store
        only verifies and installs — mirroring a bulk file ingest.

        The superseded generation is dropped immediately UNLESS leases pin it
        (in-flight streaming examples still reference it) — then it is
        retained until the last lease is released. In-flight scans are safe
        either way: they resolve their shard tables once, up front."""
        new_shards: List[Dict[Tuple[int, str], Tuple[List[int], List[Stripe]]]] = [
            {} for _ in range(self.n_shards)
        ]
        load_bytes = 0
        for (user_id, group), stripes in tables.items():
            starts = [s.start_ts for s in stripes]
            assert starts == sorted(starts), "compaction must emit sorted stripes"
            shard = self.router.route(user_id)
            new_shards[shard][(user_id, group)] = (starts, list(stripes))
            load_bytes += sum(len(s.blob) for s in stripes)
        with self._gen_lock:
            old = self._live
            if generation in self._retained or (
                    old.gen == generation and old.gen >= 0 and old.refs > 0):
                # a leased generation's bytes must never change: silently
                # replacing its tables would swap content under leaseholders
                # (and strand their refcounts on the new table)
                refs = (self._retained[generation].refs
                        if generation in self._retained else old.refs)
                raise ValueError(
                    f"generation id {generation} is still leased "
                    f"(refs={refs}); ids must not be reused while leased")
            if old.refs > 0 and old.gen >= 0 and old.gen != generation:
                self._retained[old.gen] = old
                self.lease_stats.generations_retained += 1
            self._live = _GenTable(gen=generation, shards=new_shards)
            self.generation = generation
        self.bulk_load_bytes += load_bytes
        self._emit("generation_flip", store="immutable",
                   generation=generation, tables=len(tables))

    def _emit(self, kind: str, **fields) -> None:
        tel = self.telemetry
        if tel is not None:
            tel.events.emit(kind, **fields)

    def publish_telemetry(self) -> None:
        """Flush the store's cumulative counters into the attached telemetry
        registry (idempotent; adapters take monotone maxima)."""
        tel = self.telemetry
        if tel is None:
            return
        tel.publish_stats(self.stats, "io", store="immutable")
        tel.publish_stats(self.lease_stats, "lease", store="immutable")

    # -- generation leases ----------------------------------------------------
    def acquire_lease(self, generation: Optional[int] = None) -> GenerationLease:
        """Pin ``generation`` (default: live) against GC by future bulk loads.

        Raises ``GenerationUnavailable`` if the generation has already been
        superseded AND garbage-collected."""
        with self._gen_lock:
            live = self._live
            if generation is None or generation < 0 or generation == live.gen:
                live.refs += 1
                target = live.gen
            else:
                g = self._retained.get(generation)
                if g is None:
                    raise GenerationUnavailable(
                        f"generation {generation} is gone (live={live.gen}, "
                        f"retained={sorted(self._retained)})")
                g.refs += 1
                target = generation
            self.lease_stats.acquired += 1
        self._emit("lease_acquire", store="immutable", generation=target)
        return GenerationLease(self, target)

    def _release_lease(self, generation: int) -> None:
        with self._gen_lock:
            self.lease_stats.released += 1
            if generation == self._live.gen:
                self._live.refs = max(0, self._live.refs - 1)
            else:
                g = self._retained.get(generation)
                if g is not None:
                    g.refs -= 1
                    if g.refs <= 0:
                        del self._retained[generation]
                        self.lease_stats.generations_gc += 1
        self._emit("lease_release", store="immutable", generation=generation)

    def has_generation(self, generation: int) -> bool:
        """True iff a ``ScanRequest(generation=...)`` would be servable now."""
        return generation == self._live.gen or generation in self._retained

    def leased_generations(self) -> Dict[int, int]:
        """generation -> outstanding lease refcount (live included if leased)."""
        with self._gen_lock:
            out = {g.gen: g.refs for g in self._retained.values()}
            if self._live.refs > 0:
                out[self._live.gen] = self._live.refs
            return out

    def retained_generations(self) -> List[int]:
        with self._gen_lock:
            return sorted(self._retained)

    # -- read path ------------------------------------------------------------
    def _table_for(self, generation: int):
        """Shard tables serving ``generation`` (-1 = live). Lock-free: a single
        attribute/dict read suffices, and holding the returned reference keeps
        the tables alive even if the generation is GC'd mid-scan."""
        live = self._live
        if generation < 0 or generation == live.gen:
            return live.shards
        g = self._retained.get(generation)
        if g is not None:
            return g.shards
        raise GenerationUnavailable(
            f"generation {generation} is gone (live={live.gen})")

    def _locate(self, user_id: int, group: str, generation: int = -1):
        shard = self.router.route(user_id)
        return shard, self._table_for(generation)[shard].get((user_id, group))

    def _decode(self, s: Stripe, traits, stats: IOStats) -> ev.EventBatch:
        if self.decode_cache is None:
            stats.bytes_decoded += columnar.decoded_bytes_for(s.blob, traits)
            return columnar.decode_stripe(s.blob, self.schema, traits)
        batch, hit = self.decode_cache.get(s.blob, self.schema, traits)
        if hit:
            stats.decode_cache_hits += 1
        else:
            stats.bytes_decoded += columnar.decoded_bytes_for(s.blob, traits)
        return batch

    def _select_stripes(self, req: ScanRequest, entry) -> List[Stripe]:
        """The stripe run a request reads: overlap [start_ts, end_ts], walked
        backwards from the most recent stripe until the sequence-length budget
        is met (shared by the scan itself and ``estimate_scan``)."""
        starts, stripes = entry
        lo = bisect.bisect_right(starts, req.start_ts) - 1
        lo = max(lo, 0)
        hi = bisect.bisect_right(starts, req.end_ts)  # stripes[lo:hi] may overlap
        if lo >= hi:
            return []
        chosen: List[Stripe] = []
        have = 0
        for i in range(hi - 1, lo - 1, -1):
            s = stripes[i]
            if s.end_ts < req.start_ts:
                break
            chosen.append(s)
            # conservative count: events in stripe within bound (upper estimate)
            have += s.n_events
            if req.max_events >= 0 and have >= req.max_events + s.n_events:
                # we may overshoot by up to one stripe at each temporal edge;
                # an extra stripe guards against end_ts trimming removing events
                break
        chosen.reverse()
        return chosen

    def estimate_scan(self, req: ScanRequest) -> Tuple[int, int]:
        """Metadata-only cost of one scan: ``(stripes, blob_bytes)`` the
        request would read right now. Walks the same stripe-selection logic as
        the scan itself — the estimate matches ``IOStats.stripes_read`` /
        ``bytes_scanned`` exactly — but touches no blobs: no decode, no
        latency charge, no stats. Raises ``GenerationUnavailable`` like a real
        scan would (callers doing best-effort accounting should catch it)."""
        _, entry = self._locate(req.user_id, req.group, req.generation)
        if entry is None:
            return 0, 0
        chosen = self._select_stripes(req, entry)
        return len(chosen), sum(len(s.blob) for s in chosen)

    def _scan_into(self, req: ScanRequest, stats: IOStats) -> ev.EventBatch:
        """Execute one range scan, accounting I/O into ``stats`` (the batched
        executor passes per-shard accumulators so shard threads don't race)."""
        stats.requests += 1
        traits = req.traits or self.schema.group_traits(req.group)
        if req.generation >= 0 and req.generation != self.generation:
            stats.pinned_scans += 1
        shard, entry = self._locate(req.user_id, req.group, req.generation)
        if entry is None:
            return ev.empty_batch(self.schema, traits)
        stats.seeks += 1  # single-level layout: one seek per (user,group) run
        chosen = self._select_stripes(req, entry)
        if not chosen:
            return ev.empty_batch(self.schema, traits)

        parts: List[ev.EventBatch] = []
        for s in chosen:
            stats.stripes_read += 1
            stats.bytes_scanned += len(s.blob)
            parts.append(self._decode(s, traits, stats))
        out = ev.concat_batches(parts)
        if not out:
            return ev.empty_batch(self.schema, traits)
        out = ev.time_slice(out, req.start_ts, req.end_ts)
        # keep the most recent max_events (tenant sequence-length budget)
        return ev.tail_view(out, req.max_events)

    def scan(self, req: ScanRequest) -> ev.EventBatch:
        """Bounded range scan with 3-dimensional projection pushdown."""
        return self._scan_into(req, self.stats)

    # -- planned batch execution ----------------------------------------------
    def _effective_traits(self, req: ScanRequest) -> Tuple[str, ...]:
        return req.traits or self.schema.group_traits(req.group)

    def plan(self, reqs: Sequence[ScanRequest]) -> ScanPlan:
        """Dedupe identical requests, subsume projection-contained ones, and
        group the surviving root requests by shard.

        Subsumption (union-projection planning): among requests sharing
        (user, group, bounds, generation), one whose traits are a subset and
        whose ``max_events`` budget is no larger than another's is marked
        *derived* — the executor serves it by carving the wider result instead
        of scanning (``IOStats.subsumed_hits``). This is what lets N tenant
        projections over the same window cost ONE storage scan."""
        return build_scan_plan(
            reqs, lambda r: self.router.route(r.user_id),
            self._effective_traits)

    def _carve(self, req: ScanRequest, wide: ev.EventBatch) -> ev.EventBatch:
        """Serve a subsumed request from its covering request's result:
        tail-slice to the narrower sequence budget, project to the narrower
        traits — byte-identical to executing the narrow scan directly (same
        bounds => the wide result's most-recent tail IS the narrow event
        set; trait decode is column-independent)."""
        return ev.tail_view(wide, req.max_events, self._effective_traits(req))

    def close(self) -> None:
        """Shut down the shard-scan thread pool (idempotent). Long-lived
        processes that churn through stores should close them (or use the
        store as a context manager); short-lived ones can rely on interpreter
        exit — an unused pool never spawns threads."""
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "ImmutableUIHStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def execute_plan(
        self, plan: ScanPlan, out_stats: Optional[IOStats] = None
    ) -> List[ev.EventBatch]:
        """Run a plan's shard groups concurrently; results in original request
        order (deduped requests share one execution).

        ``out_stats``: optional caller-owned accumulator that receives this
        call's delta as well — the global ``self.stats`` is shared across all
        callers, so a concurrent caller cannot attribute snapshot/delta
        windows of it to its own traffic."""
        results: List[Optional[ev.EventBatch]] = [None] * len(plan.unique)

        def run_shard(group: List[int]) -> IOStats:
            local = IOStats()
            for j in group:
                results[j] = self._scan_into(plan.unique[j], local)
            if self.latency_model is not None:
                # each shard pays its own I/O latency (plus the batch's
                # cross-shard coordination term); shards overlap, so the
                # batch's wall time is the max over shards, not the sum
                delay = self.latency_model(local.seeks, local.bytes_scanned,
                                           plan.fanout)
                if delay > 0:
                    time.sleep(delay)
            return local

        groups = list(plan.shard_groups.values())
        if len(groups) <= 1:
            shard_stats = [run_shard(g) for g in groups]
        else:
            shard_stats = list(self._pool.map(run_shard, groups))
        # subsumed requests: carve the narrower view out of the covering
        # result — no storage I/O, no decode (union-projection planning)
        for j, k in plan.derived.items():
            results[j] = self._carve(plan.unique[j], results[k])
        call = IOStats(batched_requests=1, dedup_hits=plan.dedup_hits,
                       parallel_shards=plan.fanout,
                       subsumed_hits=plan.subsumed)
        for local in shard_stats:
            call.merge(local)
        with self._stats_lock:
            self.stats.merge(call)
        if out_stats is not None:
            out_stats.merge(call)
        return [results[j] for j in plan.assignment]

    def multi_range_scan(
        self,
        reqs: Sequence[ScanRequest],
        out_stats: Optional[IOStats] = None,
    ) -> List[ev.EventBatch]:
        """Batched scan (paper: 'optimized multi-range scan with parallel I/O'):
        plans (dedupe + shard grouping), then executes shards concurrently —
        see ``plan()`` / ``execute_plan()``."""
        return self.execute_plan(self.plan(reqs), out_stats)

    # -- introspection ---------------------------------------------------------
    def live_placement(self):
        """User -> node placement of the live generation. The monolith has no
        node topology — every consumer treating ``None`` as "single node"
        (e.g. ``plan_affine``) behaves exactly as before disaggregation."""
        return None

    def fanout(self, reqs: Sequence[ScanRequest]) -> int:
        return len({self.router.route(r.user_id) for r in reqs})

    def stored_bytes(self) -> int:
        return sum(
            len(s.blob)
            for shard in self._shards
            for _, stripes in shard.values()
            for s in stripes
        )

    def retained_bytes(self) -> int:
        """Extra bytes held alive by generation leases (retention cost)."""
        with self._gen_lock:
            gens = list(self._retained.values())
        return sum(
            len(s.blob)
            for g in gens
            for shard in g.shards
            for _, stripes in shard.values()
            for s in stripes
        )

    def stored_events(self, user_id: int, group: str) -> int:
        _, entry = self._locate(user_id, group)
        if entry is None:
            return 0
        return sum(s.n_events for s in entry[1])

    def watermark(self, user_id: int, group: str = "core",
                  generation: int = -1) -> int:
        """Largest timestamp consolidated into the immutable tier for a user
        (as of ``generation``; -1 = live)."""
        _, entry = self._locate(user_id, group, generation)
        if entry is None or not entry[1]:
            return -1
        return entry[1][-1].end_ts
