"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from dryrun_results.json.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--mesh pod]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "dryrun_results.json"


def fmt_t(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}B"


def load(mesh: str):
    res = json.loads(RESULTS.read_text())
    rows = []
    for key, v in sorted(res.items()):
        if not v.get("ok") or v["mesh"] != mesh:
            continue
        r = v.get("roofline_calibrated") or v["roofline"]
        rows.append((v, r))
    return rows


def dryrun_table(mesh: str) -> str:
    rows = load(mesh)
    out = ["| arch | shape | kind | compile | HBM/chip (args) | temp/chip | "
           "collectives (per step) |",
           "|---|---|---|---|---|---|---|"]
    for v, r in rows:
        mem = v["memory"]
        args_b = mem.get("argument_size_in_bytes",
                         mem.get("args_logical_bytes_per_chip", 0))
        temp_b = mem.get("temp_size_in_bytes", 0)
        cc = ", ".join(f"{k}x{c}" for k, c in
                       sorted(v.get("calibration", v["collectives"])
                              .get("counts", {}).items()))
        out.append(
            f"| {v['arch']} | {v['shape']} | {v['kind']} | "
            f"{v['t_compile_s']}s | {fmt_b(args_b)} | {fmt_b(temp_b)} | {cc} |")
    return "\n".join(out)


def roofline_table(mesh: str) -> str:
    rows = load(mesh)
    out = ["| arch | shape | t_compute | t_memory | t_collective | bottleneck |"
           " MODEL_FLOPS | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for v, r in rows:
        out.append(
            f"| {v['arch']} | {v['shape']} | {fmt_t(r['t_compute_s'])} | "
            f"{fmt_t(r['t_memory_s'])} | {fmt_t(r['t_collective_s'])} | "
            f"**{r['bottleneck']}** | {r['model_flops_total']:.3g} | "
            f"{r['model_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def pick_hillclimb(mesh: str = "pod"):
    """worst roofline fraction / most collective-bound / most paper-representative"""
    rows = load(mesh)
    scored = [(v, r) for v, r in rows]
    worst = min(scored, key=lambda x: x[1]["roofline_fraction"])
    coll = max(scored, key=lambda x: (x[1]["t_collective_s"]
                                      / max(x[1]["t_compute_s"]
                                            + x[1]["t_memory_s"], 1e-30)))
    paper = next((v, r) for v, r in rows
                 if v["arch"] == "dlrm-uih" and v["shape"] == "train_batch")
    return {"worst_fraction": f"{worst[0]['arch']}|{worst[0]['shape']}",
            "most_collective_bound": f"{coll[0]['arch']}|{coll[0]['shape']}",
            "paper_representative": f"{paper[0]['arch']}|{paper[0]['shape']}"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    args = ap.parse_args()
    print(f"## Dry-run ({args.mesh})\n")
    print(dryrun_table(args.mesh))
    print(f"\n## Roofline ({args.mesh})\n")
    print(roofline_table(args.mesh))
    print("\n## Hillclimb candidates\n")
    print(json.dumps(pick_hillclimb(args.mesh), indent=1))


if __name__ == "__main__":
    main()
