"""Symmetric hash partitioning (paper §4.2.3).

The primary training data and the immutable UIH store use the *identical* hash
partitioning scheme with a shared partition key (user_id), so that all UIH
lookups issued while loading one data batch map to the same storage shard —
eliminating cross-shard network fanout on the high-concurrency read path.
"""
from __future__ import annotations

import zlib


def shard_of(user_id: int, n_shards: int) -> int:
    """Deterministic, stable hash partition. Shared by trainer-data placement
    and by the immutable store so sharding stays *symmetric*."""
    # splitmix64-style mix; stable across processes (unlike hash()).
    x = (user_id & 0xFFFFFFFFFFFFFFFF) * 0x9E3779B97F4A7C15 & 0xFFFFFFFFFFFFFFFF
    x ^= x >> 32
    x = x * 0xBF58476D1CE4E5B9 & 0xFFFFFFFFFFFFFFFF
    x ^= x >> 29
    return int(x % n_shards)


class ShardRouter:
    def __init__(self, n_shards: int):
        assert n_shards >= 1
        self.n_shards = n_shards

    def route(self, user_id: int) -> int:
        return shard_of(user_id, self.n_shards)

    def fanout(self, user_ids) -> int:
        """Number of distinct shards touched by a batch of lookups."""
        return len({self.route(int(u)) for u in user_ids})
