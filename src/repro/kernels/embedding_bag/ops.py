"""Public jit'd wrapper for the fused EmbeddingBag kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def embedding_bag(table: jax.Array, ids: jax.Array, mask: jax.Array,
                  combiner: str = "sum") -> jax.Array:
    """(V, D) table, (B, L) ids/mask -> (B, D). Lane-pads D to 128."""
    v, d = table.shape
    dp = (128 - d % 128) % 128
    t = jnp.pad(table, ((0, 0), (0, dp)))
    out = embedding_bag_kernel(
        t, ids.astype(jnp.int32), mask.astype(t.dtype), bag_len=ids.shape[1],
        interpret=not _on_tpu(),
    )[:, :d]
    if combiner == "mean":
        denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1).astype(out.dtype)
        out = out / denom
    return out
