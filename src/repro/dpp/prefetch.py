"""Double-buffered device feed (paper §4.2): overlap host->device transfer for
batch N+1 with the train step for batch N.

The seed trainer called ``jax.device_put`` (implicitly, via jit argument
transfer) synchronously inside the step loop, so every step paid the full
featurize-tail + H2D latency on the critical path. ``DevicePrefetcher`` sits
between a host-batch source (typically a ``RebatchingClient``) and the
``Trainer``: a background thread pulls the next host batch, applies an
optional ``prep_fn`` (model-specific host transforms), issues the device
transfer, and blocks until the buffers are resident — all while the previous
step computes. ``depth`` bounds how many device batches may be in flight
(2 = classic double buffering).

Starvation attribution: the prefetch thread runs a state clock (host-fetch vs
H2D-copy); when the consumer blocks, the wait is split into
``ClientStats.starved_host_s`` vs ``starved_h2d_s`` proportionally to what the
prefetcher was actually doing during the wait window — the counter split the
elastic controller needs to distinguish "provision more DPP workers" from
"the interconnect is the bottleneck".

Slot recycling: when the source exposes ``recycle`` and ``recycle_host=True``,
the host storage of a transferred batch is returned to the source's slot pool
right after the device copy completes. Only enable this when the transfer is
a true copy (discrete accelerators); on CPU backends ``device_put`` may alias
the host buffer, in which case recycling would corrupt in-flight batches —
hence the conservative default.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

from repro.dpp.client import ClientStats

HostBatch = Dict[str, np.ndarray]


class _StateClock:
    """Cumulative time-in-state tracker readable mid-state from other threads."""

    def __init__(self):
        self._lock = threading.Lock()
        self._acc: Dict[str, float] = {}
        self._state: Optional[str] = None
        self._since = 0.0

    def enter(self, state: Optional[str]) -> None:
        now = time.perf_counter()
        with self._lock:
            if self._state is not None:
                self._acc[self._state] = (
                    self._acc.get(self._state, 0.0) + now - self._since)
            self._state = state
            self._since = now

    def snapshot(self) -> Dict[str, float]:
        now = time.perf_counter()
        with self._lock:
            out = dict(self._acc)
            if self._state is not None:
                out[self._state] = out.get(self._state, 0.0) + now - self._since
            return out


class _SourceError:
    def __init__(self, exc: BaseException):
        self.exc = exc


class DevicePrefetcher:
    """Pull host batches from ``source``, transfer to device in a background
    thread, yield ready device batches.

    ``source`` is either a ``RebatchingClient``-like object (``get_full_batch``
    returning ``None`` at end of stream) or any iterable of host batches.
    """

    def __init__(
        self,
        source: Any,
        depth: int = 2,
        device: Any = None,
        sharding: Any = None,
        prep_fn: Optional[Callable[[HostBatch], Any]] = None,
        stats: Optional[ClientStats] = None,
        recycle_host: bool = False,
        materialize: Any = None,
    ):
        assert depth >= 1
        self.source = source
        self.device = device
        self.sharding = sharding
        self.prep_fn = prep_fn
        self.recycle_host = recycle_host
        # device-side late materialization (DESIGN §3): a DeviceMaterializer
        # that turns compact jagged payloads (arena + offsets) into dense
        # device batches by running the kernels/fused pipeline on-device —
        # dense batches (or a None materializer) take the plain path below
        self.materialize = materialize
        self.stats = stats if stats is not None else (
            getattr(source, "stats", None) or ClientStats())
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._clock = _StateClock()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._started = False
        self._telemetry = None
        self._h2d_hist = None
        # end-of-stream sentinel observed by the consumer (vs a get timeout)
        self.ended = False

    # -- telemetry ----------------------------------------------------------------
    @property
    def telemetry(self):
        return self._telemetry

    @telemetry.setter
    def telemetry(self, tel) -> None:
        """Attach a ``repro.obs.Telemetry``. Must happen BEFORE ``start()``:
        the span tracker's delivery FIFO switches to the H2D-done lane
        (``has_h2d``) and emitted/consumed counts must match."""
        self._telemetry = tel
        if tel is not None:
            tel.spans.has_h2d = True
            self._h2d_hist = tel.registry.histogram(
                "repro_h2d_seconds",
                help="host->device transfer time per full batch")

    # -- producer (background transfer thread) -----------------------------------
    def _pull(self):
        get = getattr(self.source, "get_full_batch", None)
        if get is not None:
            # record=False: the PREFETCH thread's wait on host data is not GPU
            # starvation — only the consumer-side wait below is
            try:
                return get(record=False)
            except TypeError:
                return get()
        it = getattr(self, "_source_iter", None)
        if it is None:
            it = self._source_iter = iter(self.source)
        return next(it, None)

    def _transfer(self, host_batch: HostBatch):
        import jax

        if self.materialize is not None and isinstance(host_batch, dict) \
                and "_seq_len" in host_batch:
            # compact jagged payload: upload arena+offsets only, densify and
            # delta-decode ON DEVICE (kernels/fused); the [B, L] zero padding
            # never crosses the link
            dev = self.materialize(host_batch)
            self.stats.h2d_bytes += self.materialize.last_h2d_bytes
            jax.block_until_ready(dev)
            return dev
        prepped = self.prep_fn(host_batch) if self.prep_fn else host_batch
        target = self.sharding if self.sharding is not None else self.device
        if target is not None:
            dev = jax.device_put(prepped, target)
        else:
            dev = jax.device_put(prepped)
        if isinstance(prepped, dict):
            self.stats.h2d_bytes += sum(
                getattr(v, "nbytes", 0) for v in prepped.values())
        # block in THIS thread so the consumer receives resident buffers and
        # the H2D cost lands in the prefetcher's clock, not the train step
        jax.block_until_ready(dev)
        return dev

    def _loop(self) -> None:
        try:
            while not self._stop.is_set():
                self._clock.enter("host")
                host_batch = self._pull()
                if host_batch is None:
                    break
                tel = self._telemetry
                bs = tel.spans.pop_emitted() if tel is not None else None
                self._clock.enter("h2d")
                t0 = time.perf_counter()
                dev = self._transfer(host_batch)
                t1 = time.perf_counter()
                self.stats.h2d_time_s += t1 - t0
                if tel is not None:
                    if bs is not None:
                        bs.stage("h2d", t0, t1)
                        tel.spans.push_h2d_done(bs)
                    self._h2d_hist.observe(t1 - t0)
                if self.recycle_host:
                    rec = getattr(self.source, "recycle", None)
                    if rec is not None:
                        rec(host_batch)
                self._clock.enter("idle")
                if not self._offer(dev):
                    return     # stopped while the queue was full
        except BaseException as e:  # propagate to the consumer
            self._clock.enter("idle")
            self._offer(_SourceError(e))
            return
        self._clock.enter(None)
        self._offer(None)

    def _offer(self, item) -> bool:
        """put that re-checks stop: a consumer that walked away (e.g. fit hit
        max_steps) must not leave this thread parked on a full queue pinning
        device buffers forever."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    # -- consumer (trainer loop) --------------------------------------------------
    def start(self) -> "DevicePrefetcher":
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def get(self, timeout: Optional[float] = None, record: bool = True):
        """Next device-resident batch, or ``None`` at end of stream.

        ``record=False`` suppresses the starvation/full-batch accounting —
        for pulls that are NOT the trainer's critical path (e.g. a stacked
        stage draining this one)."""
        self.start()
        before = self._clock.snapshot()
        t0 = time.perf_counter()
        try:
            out = self._q.get(timeout=timeout)
            if out is None:
                self.ended = True
        except queue.Empty:
            return None
        dt = time.perf_counter() - t0
        if isinstance(out, _SourceError):
            self.stop()
            raise RuntimeError("device prefetch source failed") from out.exc
        if out is not None and record:
            # split the consumer's wait by what the prefetcher was doing
            after = self._clock.snapshot()
            d_host = after.get("host", 0.0) - before.get("host", 0.0)
            d_h2d = after.get("h2d", 0.0) - before.get("h2d", 0.0)
            busy = d_host + d_h2d
            host_share = dt * (d_host / busy) if busy > 0 else dt
            self.stats.starved_time_s += dt
            self.stats.starved_host_s += host_share
            self.stats.starved_h2d_s += dt - host_share
            self.stats.full_batches += 1
        return out

    def record_train_step(self, seconds: float) -> None:
        rec = getattr(self.source, "record_train_step", None)
        if rec is not None and getattr(self.source, "stats", None) is self.stats:
            # the source owns the shared ClientStats: DELEGATE instead of
            # recording here — train time is a single global clock, and the
            # source may have step-completion side effects of its own (e.g.
            # StreamingSession settles event->gradient freshness samples)
            rec(seconds)
            return
        self.stats.train_time_s += seconds
        if rec is not None:
            rec(seconds)

    def _drain(self) -> None:
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    def stop(self, timeout: float = 5.0) -> None:
        """Abandon the stream: stop the transfer thread and release queued
        device batches (safe to call from the consumer at any point).

        Drains AFTER the thread exits — a drain racing a producer parked in
        ``_q.put`` would free a queue slot, let that put land, and strand one
        device-resident batch forever. If the thread is stuck in a host
        source that never yields, it parks as a daemon on an empty queue."""
        self._stop.set()
        if self._started:
            deadline = time.monotonic() + timeout
            while self._thread.is_alive() and time.monotonic() < deadline:
                self._drain()
                self._thread.join(timeout=0.05)
        self._drain()

    def __iter__(self) -> Iterator[Any]:
        while True:
            b = self.get()
            if b is None:
                return
            yield b
