"""Pallas TPU kernel: batched delta-decode (prefix sum) of columnar stripes.

The trait-aware codec (paper §4.1.2) stores timestamps as deltas; training-time
materialization decodes whole batches of stripes at once. TPU mapping: grid =
(B, N/block_n); the N axis is innermost, and the TPU grid executes sequentially,
so a VMEM carry holds the running sum across column blocks of the same row
(classic sequential-grid scan). Block shapes are (block_b, block_n) in VMEM,
lane-aligned to 128.

Carry-width contract: the scan accumulates in int32, so the kernel decodes
**window-relative** offsets only — callers with int64 arenas (epoch-ms
timestamps) must pass window-relative deltas with ``bases=0`` and re-add the
per-row int64 base host-side (``ops.delta_decode`` does exactly this; see the
regression test with timestamps > 2^31 in tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(deltas_ref, bases_ref, out_ref, carry_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    block = deltas_ref[...]                          # (block_b, block_n)
    csum = jnp.cumsum(block, axis=1, dtype=jnp.int32)
    out_ref[...] = csum + carry_ref[...] + bases_ref[...]
    carry_ref[...] = carry_ref[...] + csum[:, -1:]


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def delta_decode_kernel(
    deltas: jax.Array,      # (B, N) int32
    bases: jax.Array,       # (B,) int32
    block_b: int = 8,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, n = deltas.shape
    assert b % block_b == 0 and n % block_n == 0, (b, n, block_b, block_n)
    bases2d = bases[:, None]                         # (B, 1)
    grid = (b // block_b, n // block_n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, 1), jnp.int32)],
        interpret=interpret,
    )(deltas, bases2d)
