"""Public jit'd wrapper for the fused EmbeddingBag kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.embedding_bag.embedding_bag import embedding_bag_kernel


def embedding_bag(table: jax.Array, ids: jax.Array, mask: jax.Array,
                  combiner: str = "sum") -> jax.Array:
    """(V, D) table, (B, L) ids/mask -> (B, D). Lane-pads D to 128.

    ids are clamped into [0, V) inside the kernel before the row DMA — the
    featurizer's zero-padded (and any sentinel-poisoned) lanes ride through
    under mask==0 without ever addressing HBM out of bounds."""
    v, d = table.shape
    b, l = ids.shape
    if b == 0 or l == 0:
        # degenerate bags: a zero-step grid (or zero-trip DMA loop) is not a
        # valid pallas_call — the masked reduction is identically zero
        out = jnp.zeros((b, d), table.dtype)
    else:
        dp = (128 - d % 128) % 128
        t = jnp.pad(table, ((0, 0), (0, dp)))
        out = embedding_bag_kernel(
            t, ids.astype(jnp.int32), mask.astype(t.dtype), bag_len=l,
            interpret=runtime.interpret_default(),
        )[:, :d]
    if combiner == "mean":
        denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1).astype(out.dtype)
        out = out / denom
    return out
