"""Replicated store tier under failure (DESIGN.md §12).

Three claims, on the same heavy-tailed population as ``bench_sharded_store``:
  * availability — with one of four nodes down, an r=2 tier keeps serving
    every read at throughput close to healthy (acceptance: within ~25%),
    while r=1 can only surface the outage as retryable ``NodeUnavailable``
    (reported as the unavailable-batch rate, never hidden);
  * tail latency — quantile-triggered hedged reads cut p99 against an
    injected-slow node, at the cost of duplicate I/O (``hedged_reads`` /
    ``hedge_wins`` reported);
  * recovery — time from ``recover()`` on a flapped node (missed-generation
    replay + orphan-lease settlement) back to the primary serving reads.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.bench_sharded_store import LATENCY, _population
from benchmarks.common import BenchResult
from repro.core import events as ev
from repro.storage.compaction import CompactionConfig, CompactionPipeline
from repro.storage.failover import CLOSED
from repro.storage.immutable_store import ScanRequest
from repro.storage.sharded_store import NodeUnavailable, ShardedUIHStore

SCHEMA = ev.default_schema()
N_NODES = 4
DOWN_NODE = 1


def _build(events: Dict[int, ev.EventBatch], replication: int,
           generation: int = 0, store: ShardedUIHStore = None,
           **kw) -> ShardedUIHStore:
    if store is None:
        store = ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=N_NODES,
                                replication_factor=replication, **kw)
    pipe = CompactionPipeline(SCHEMA, CompactionConfig(stripe_len=64))
    pipe.run(lambda uid, lo, hi: ev.time_slice(events[uid], lo, hi),
             list(events), 1_000_000, store, generation=generation)
    return store


def _scan_sweep(store: ShardedUIHStore, users: List[int], batch: int,
                repeats: int):
    """Batched scans over the population; a batch whose node group is fully
    unavailable counts as failed (r=1 with a node down) instead of aborting
    the sweep. Returns (wall_s, rows_ok, batches_failed)."""
    rows_ok, failed = 0, 0
    t0 = time.perf_counter()
    for _ in range(repeats):
        for lo in range(0, len(users), batch):
            chunk = users[lo:lo + batch]
            reqs = [ScanRequest(u, "core", 0, 10**9) for u in chunk]
            try:
                store.multi_range_scan(reqs)
                rows_ok += len(chunk)
            except NodeUnavailable:
                failed += 1
    return time.perf_counter() - t0, rows_ok, failed


def run(quick: bool = False) -> List[BenchResult]:
    n_users, mean_events, batch, repeats = \
        (24, 30, 8, 2) if quick else (128, 80, 16, 4)
    events = _population(n_users, mean_events)
    users = list(events)
    results: List[BenchResult] = []

    # -- availability: rows/s with 0 vs 1 node down, r in {1, 2} -------------
    thr = {}
    for repl in (1, 2):
        for down in (False, True):
            store = _build(events, repl)
            store.latency_model = LATENCY
            if down:
                store.set_node_down(DOWN_NODE)
            wall, rows_ok, failed = _scan_sweep(store, users, batch, repeats)
            n_batches = repeats * ((len(users) + batch - 1) // batch)
            thr[(repl, down)] = {
                "rows_per_s": round(rows_ok / wall, 1),
                "unavailable_batch_rate": round(failed / n_batches, 3),
                "failovers": store.stats.failovers,
                "breaker_opens": store.stats.breaker_opens,
            }
            store.close()
    healthy = thr[(2, False)]["rows_per_s"]
    degraded = thr[(2, True)]["rows_per_s"]
    results.append(BenchResult(
        "failover/throughput_one_node_down", 0.0,
        {"r1_healthy_rows_per_s": thr[(1, False)]["rows_per_s"],
         "r1_down_rows_per_s": thr[(1, True)]["rows_per_s"],
         # r=1 cannot mask the outage: the rate is the honest signal
         "r1_down_unavailable_rate": thr[(1, True)]["unavailable_batch_rate"],
         "r2_healthy_rows_per_s": healthy,
         "r2_down_rows_per_s": degraded,
         "r2_down_vs_healthy": round(degraded / healthy, 3),
         "r2_down_failovers": thr[(2, True)]["failovers"],
         "r2_down_breaker_opens": thr[(2, True)]["breaker_opens"]},
    ))

    # -- tail latency: hedging off vs on against one slow node ---------------
    slow_factor = 8.0
    n_probe = 40 if quick else 160
    lat = {}
    for hedge in (0.0, 0.7):
        store = _build(events, 2, hedge_quantile=hedge)
        store.latency_model = LATENCY
        warm = [ScanRequest(u, "core", 0, 10**9) for u in users[:20]]
        for r in warm:                       # warm the tier latency tracker
            store.scan(r)
        store.set_node_slow(0, slow_factor)
        samples = []
        for i in range(n_probe):
            req = ScanRequest(users[i % len(users)], "core", 0, 10**9)
            t0 = time.perf_counter()
            store.scan(req)
            samples.append(time.perf_counter() - t0)
        s = store.stats
        lat[hedge] = {
            "p50_ms": round(float(np.percentile(samples, 50)) * 1e3, 3),
            "p99_ms": round(float(np.percentile(samples, 99)) * 1e3, 3),
            "hedged_reads": s.hedged_reads,
            "hedge_wins": s.hedge_wins,
        }
        store.close()
    results.append(BenchResult(
        "failover/hedged_read_tail_latency",
        lat[0.7]["p99_ms"] * 1e3,
        {"slow_factor": slow_factor,
         "p99_ms_no_hedge": lat[0.0]["p99_ms"],
         "p99_ms_hedged": lat[0.7]["p99_ms"],
         "p50_ms_no_hedge": lat[0.0]["p50_ms"],
         "p50_ms_hedged": lat[0.7]["p50_ms"],
         "hedged_reads": lat[0.7]["hedged_reads"],
         "hedge_wins": lat[0.7]["hedge_wins"]},
    ))

    # -- recovery: flapped node back to serving reads ------------------------
    store = _build(events, 2)
    store.set_node_down(DOWN_NODE)
    _scan_sweep(store, users, batch, 1)      # outage traffic: breaker trips
    _build(events, 2, generation=1, store=store)   # missed load -> replay
    assert store.node_stats().pending_replays[DOWN_NODE] == 1
    t0 = time.perf_counter()
    replayed = store.recover(DOWN_NODE)
    recover_ms = (time.perf_counter() - t0) * 1e3
    # ...to healthy: the primary serves again and its breaker is closed
    probe_user = next(u for u in users
                      if store._node_of(u) == DOWN_NODE)
    scans_to_healthy = 0
    base = store.nodes[DOWN_NODE].stats.requests
    while (store.nodes[DOWN_NODE].stats.requests == base
           or store.node_stats().breaker[DOWN_NODE] != CLOSED):
        store.scan(ScanRequest(probe_user, "core", 0, 10**9))
        scans_to_healthy += 1
    healthy_ms = (time.perf_counter() - t0) * 1e3
    results.append(BenchResult(
        "failover/recovery_time_to_healthy", recover_ms * 1e3,
        {"recover_ms": round(recover_ms, 3),
         "time_to_healthy_ms": round(healthy_ms, 3),
         "generations_replayed": replayed,
         "rereplicated_bytes": store.rereplicated_bytes,
         "scans_to_healthy": scans_to_healthy},
    ))
    store.close()
    return results


if __name__ == "__main__":
    for r in run():
        print(r.csv())
