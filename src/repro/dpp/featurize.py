"""Featurization: materialized UIH event batches -> fixed-shape training arrays.

Pads/truncates the jagged per-example sequences into dense [B, L] arrays with a
validity mask (host-side numpy mirror of the ``repro.kernels.jagged`` Pallas
device kernel — see DESIGN.md §3 on where the device path takes over).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import events as ev
from repro.core.versioning import TrainingExample


@dataclasses.dataclass
class FeatureSpec:
    seq_len: int                       # padded UIH length
    uih_traits: Sequence[str]          # traits to lift into [B, L] arrays
    candidate_fields: Sequence[str] = ("item_id",)
    label_fields: Sequence[str] = ("click",)


def pad_sequences(
    seqs: Sequence[np.ndarray], seq_len: int, dtype=None, left_align: bool = False
) -> np.ndarray:
    """Right-aligned (most-recent-last) pad/truncate to [B, seq_len]."""
    b = len(seqs)
    dtype = dtype or (seqs[0].dtype if b else np.int64)
    out = np.zeros((b, seq_len), dtype=dtype)
    for i, s in enumerate(seqs):
        s = s[-seq_len:]
        if left_align:
            out[i, : len(s)] = s
        else:
            out[i, seq_len - len(s):] = s
    return out


def featurize(
    examples: Sequence[TrainingExample],
    uihs: Sequence[ev.EventBatch],
    spec: FeatureSpec,
) -> Dict[str, np.ndarray]:
    """Build one base batch of dense arrays from materialized UIH sequences."""
    assert len(examples) == len(uihs)
    b = len(examples)
    lens = np.array([min(ev.batch_len(u), spec.seq_len) for u in uihs], np.int32)
    batch: Dict[str, np.ndarray] = {"uih_len": lens}
    for trait in spec.uih_traits:
        cols = [u.get(trait, np.zeros(0, np.int64)) for u in uihs]
        batch[f"uih_{trait}"] = pad_sequences(cols, spec.seq_len)
    mask = np.zeros((b, spec.seq_len), dtype=np.bool_)
    for i, n in enumerate(lens):
        mask[i, spec.seq_len - n:] = True
    batch["uih_mask"] = mask
    for f in spec.candidate_fields:
        batch[f"cand_{f}"] = np.array(
            [e.candidate.get(f, 0) for e in examples], np.int64
        )
    for f in spec.label_fields:
        batch[f"label_{f}"] = np.array(
            [e.labels.get(f, 0.0) for e in examples], np.float32
        )
    batch["request_ts"] = np.array([e.request_ts for e in examples], np.int64)
    batch["user_id"] = np.array([e.user_id for e in examples], np.int64)
    return batch


def merge_base_batches(batches: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    keys = batches[0].keys()
    return {k: np.concatenate([b[k] for b in batches], axis=0) for k in keys}


def reshuffle(batch: Dict[str, np.ndarray], seed: int) -> Dict[str, np.ndarray]:
    n = len(next(iter(batch.values())))
    perm = np.random.default_rng(seed).permutation(n)
    return {k: v[perm] for k, v in batch.items()}
