"""Declarative read-path specs (paper §2.3, §4.2): WHAT a tenant consumes,
not HOW the pipeline is wired.

A ``DatasetSpec`` is a frozen, hashable description of one model tenant's
feed: the data source (warehouse hour replay | live stream | sim examples),
the tenant's ``TenantProjection`` (sequence length, feature groups, traits),
the consistency mode, the generation policy, and the feed knobs (batch size,
prefetch depth, reshuffle seed, worker count). ``repro.data.open_feed``
compiles a spec into the existing data plane and returns a uniform ``Feed``;
``repro.data.MultiTenantPlanner`` co-plans N specs over the same store into
one union co-scan. Adding a tenant is a one-spec change, not a new pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.core import events as ev
from repro.core.projection import TenantProjection
from repro.dpp.featurize import FeatureSpec


@dataclasses.dataclass(frozen=True)
class WarehouseSource:
    """Batch replay of hourly warehouse partitions (user-bucketed buckets are
    the unit of work, preserving the §4.2.3 data-affinity clustering)."""

    hours: Optional[Tuple[int, ...]] = None   # None = every ingested hour
    epochs: int = 1

    def __post_init__(self):
        if self.hours is not None:
            object.__setattr__(self, "hours", tuple(self.hours))
        if self.epochs < 1:
            raise ValueError(f"epochs must be >= 1, got {self.epochs}")


@dataclasses.dataclass(frozen=True)
class SimSource:
    """Replay of the sim's logged examples (benchmark / test / demo traffic),
    affinity-planned per epoch. ``min_rows`` repeats shuffled epochs until at
    least that many example rows are dispatched (how a step-bounded trainer
    sizes its feed)."""

    epochs: int = 1
    shuffle: bool = True
    min_rows: Optional[int] = None

    def __post_init__(self):
        if self.epochs < 1:
            raise ValueError(f"epochs must be >= 1, got {self.epochs}")


@dataclasses.dataclass(frozen=True)
class StreamSource:
    """Live training-example stream, optionally preceded by the batch→stream
    catch-up backfill (warehouse replay with the exactly-once watermark).

    ``backfill_start_hour``/``backfill_end_hour`` bound the replay range
    (None = the warehouse's full sealed sweep at feed-open time). These are
    OPERATIONAL knobs, not dataset identity: a resumed feed may legitimately
    replay a longer range than the killed run did (the warehouse head moved),
    so they are excluded from the resume fingerprint."""

    backfill: bool = True
    micro_batch_examples: int = 8
    micro_batch_delay_s: float = 0.05
    backfill_start_hour: Optional[int] = None
    backfill_end_hour: Optional[int] = None

    def __post_init__(self):
        if self.micro_batch_examples < 1:
            raise ValueError("micro_batch_examples must be >= 1")


Source = Union[WarehouseSource, SimSource, StreamSource]

_CONSISTENCY = ("off", "audit")
_GENERATIONS = ("live", "pinned")


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """One tenant's declarative feed description.

    * ``source`` — where examples come from (warehouse | stream | sim);
    * ``tenant`` — the multi-dimensional projection pushed down to storage;
    * ``consistency`` — ``"audit"`` checksum-validates every full-window
      materialization (O2O), ``"off"`` trusts the protocol;
    * ``generations`` — ``"pinned"`` scans the example's logged (leased)
      generation byte-exact (the streaming protocol), ``"live"`` always
      re-resolves against the live generation;
    * feed knobs — full/base batch sizes, device prefetch depth, reshuffle
      seed, worker count, client buffering, per-worker window-cache size;
    * ``features`` — featurization spec; derived from the tenant's traits
      when omitted (every non-timestamp trait becomes a ``uih_*`` array).

    Frozen and hashable: specs can key plans, caches, and registries.
    """

    tenant: TenantProjection
    source: Source = dataclasses.field(default_factory=SimSource)
    consistency: str = "off"
    generations: str = "live"
    batch_size: int = 32
    base_batch_size: int = 8
    # None = auto: a device-prefetch stage (depth 2) iff open_feed targets a
    # cell; 0 = FORCE host feed even with a cell; >0 = explicit depth
    prefetch_depth: Optional[int] = None
    reshuffle_seed: Optional[int] = 0
    n_workers: int = 2
    buffer_batches: int = 4
    window_cache_size: int = 256
    features: Optional[FeatureSpec] = None
    # fault tolerance (§10): ``ordered`` routes finished base batches through
    # the pool's reorder buffer so full batches compose deterministically in
    # work-item order — the property crash-safe checkpoint/resume and the
    # byte-identical chaos guarantee rest on; ``max_item_retries`` bounds
    # pool-level self-healing (requeue + respawn) per work item, 0 = a worker
    # exception is immediately fatal (the pre-§10 behavior)
    ordered: bool = True
    max_item_retries: int = 3
    # device-side late materialization (DESIGN §3): ship compact jagged
    # payloads (arena + offsets) to the device-prefetch stage and run the
    # kernels/fused densify+decode on-accelerator instead of densifying on
    # the host. Batches are byte-identical to the host path (tested), so the
    # flag is an operational knob EXCLUDED from the resume fingerprint.
    # Requires a device-prefetch stage and no prep_fn; open_feed silently
    # falls back to the host path otherwise (fallback rules in DESIGN §3).
    device_materialize: bool = False
    # unified telemetry (§13): a ``repro.obs.Telemetry`` threaded by
    # ``open_feed`` through every pipeline stage (store RTT histograms, item
    # spans, control-plane events). Excluded from equality/hash/repr — an
    # observer is not dataset identity (and resume_fingerprint must not see
    # it; it builds from repr'd identity fields only).
    telemetry: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False, hash=False)

    def __post_init__(self):
        if self.consistency not in _CONSISTENCY:
            raise ValueError(
                f"consistency must be one of {_CONSISTENCY}, got "
                f"{self.consistency!r}")
        if self.generations not in _GENERATIONS:
            raise ValueError(
                f"generations must be one of {_GENERATIONS}, got "
                f"{self.generations!r}")
        if self.batch_size < 1 or self.base_batch_size < 1:
            raise ValueError("batch sizes must be >= 1")
        if self.prefetch_depth is not None and self.prefetch_depth < 0:
            raise ValueError("prefetch_depth must be >= 0 (or None = auto)")
        if self.n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        if self.buffer_batches < 1:
            raise ValueError("buffer_batches must be >= 1")
        if self.window_cache_size < 0:
            raise ValueError("window_cache_size must be >= 0")
        if self.max_item_retries < 0:
            raise ValueError("max_item_retries must be >= 0")
        if (self.features is not None
                and self.features.seq_len != self.tenant.seq_len):
            # a mismatch silently truncates (or over-pads) every sequence the
            # tenant projection paid to fetch — wrong model config, not a knob
            raise ValueError(
                f"features.seq_len={self.features.seq_len} != "
                f"tenant.seq_len={self.tenant.seq_len}; the featurized length "
                f"must match the tenant projection")

    # -- compiled-policy views -------------------------------------------------
    @property
    def validate_checksum(self) -> bool:
        return self.consistency == "audit"

    @property
    def pin_generations(self) -> bool:
        return self.generations == "pinned"

    @property
    def streaming(self) -> bool:
        return isinstance(self.source, StreamSource)

    def resolve_features(self, schema: ev.TraitSchema) -> FeatureSpec:
        """The effective featurization: explicit ``features``, else derived
        from the tenant (each non-timestamp projected trait -> ``uih_*``)."""
        if self.features is not None:
            return self.features
        traits = tuple(t for t in self.tenant.all_traits(schema)
                       if t != "timestamp")
        return FeatureSpec(seq_len=self.tenant.seq_len, uih_traits=traits)


def resume_fingerprint(spec: DatasetSpec) -> str:
    """Dataset identity for checkpoint/resume compatibility (§10).

    Covers every field that determines WHAT rows the feed produces in WHICH
    order (tenant projection, features, source identity, batch size, reshuffle
    seed, consistency/generation policy, ordering). Deliberately EXCLUDES
    operational knobs that may legitimately change across restarts without
    breaking exactly-once: worker count, base batch size, buffering, prefetch
    depth, micro-batch bounds, and the streaming backfill hour range (the
    warehouse head moves between runs — the resumed sweep is *expected* to be
    longer than the killed run's)."""
    src = spec.source
    if isinstance(src, StreamSource):
        src_key: tuple = ("stream", src.backfill)
    elif isinstance(src, WarehouseSource):
        src_key = ("warehouse", src.hours, src.epochs)
    else:
        src_key = ("sim", src.epochs, src.shuffle, src.min_rows)
    return repr((repr(spec.tenant), src_key, spec.consistency,
                 spec.generations, spec.batch_size, spec.reshuffle_seed,
                 repr(spec.features), spec.ordered))
