"""Multi-tenant sequence projection (paper §2.3, §4.1.2, §4.2.2).

Each model tenant declares its UIH requirements — target sequence length,
feature groups, and optionally a trait subset per group. The DPP query engine
pushes these down to the immutable store so short-sequence / few-feature
tenants never over-fetch (eliminating the multi-tenant penalty).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.core import events as ev


@dataclasses.dataclass(frozen=True)
class TenantProjection:
    name: str
    seq_len: int                                 # target UIH length (events)
    feature_groups: Tuple[str, ...]              # groups the model consumes
    traits_per_group: Optional[Mapping[str, Tuple[str, ...]]] = None

    def traits_for(self, schema: ev.TraitSchema, group: str) -> Tuple[str, ...]:
        if self.traits_per_group and group in self.traits_per_group:
            cols = self.traits_per_group[group]
            if "timestamp" not in cols:
                cols = ("timestamp",) + tuple(cols)
            return tuple(cols)
        return schema.group_traits(group)

    def all_traits(self, schema: ev.TraitSchema) -> Tuple[str, ...]:
        seen = []
        for g in self.feature_groups:
            for t in self.traits_for(schema, g):
                if t not in seen:
                    seen.append(t)
        return tuple(seen)


# The paper's three evaluation tenants (Table 1): long / mid / short sequence.
def table1_tenants(
    long_len: int = 2048, mid_len: int = 512, short_len: int = 64
) -> Dict[str, TenantProjection]:
    return {
        "model_a": TenantProjection(
            name="model_a",  # flagship late-stage ranking: long seq, all groups
            seq_len=long_len,
            feature_groups=("core", "engagement", "sideinfo"),
        ),
        "model_b": TenantProjection(
            name="model_b",  # pre-ranking: mid seq, no sideinfo
            seq_len=mid_len,
            feature_groups=("core", "engagement"),
        ),
        "model_c": TenantProjection(
            name="model_c",  # retrieval: short seq, core ids only
            seq_len=short_len,
            feature_groups=("core",),
            traits_per_group={"core": ("timestamp", "item_id")},
        ),
    }
