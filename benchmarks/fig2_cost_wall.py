"""Figure 2 reproduction: data-supporting-service vs GPU cost as sequence
length scales, under Fat Row vs versioned late materialization; plus the
'Fat Row Wall' (ratio > 0.75, §5.2)."""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchResult
from repro.core.fatrow import WorkloadModel, fat_row_cost, fat_row_wall, vlm_cost


def run(quick: bool = False) -> List[BenchResult]:
    m = WorkloadModel()
    out: List[BenchResult] = []
    seqs = [256, 4096, 65_536] if quick \
        else [256, 1024, 4096, 16_384, 65_536, 262_144]
    for seq in seqs:
        f = fat_row_cost(seq, m)
        v = vlm_cost(seq, m)
        out.append(BenchResult(
            f"fig2/seq_{seq}", 0.0,
            {
                "fatrow_data_over_gpu": round(f.ratio, 3),
                "vlm_data_over_gpu": round(v.ratio, 3),
                "fatrow_data_cost": f"{f.data_services:.3g}",
                "vlm_data_cost": f"{v.data_services:.3g}",
            },
        ))
    wall = fat_row_wall(0.75, m)
    vlm_wall = None
    seq = 256
    while seq <= (1 << 22):
        if vlm_cost(seq, m).ratio > 0.75:
            vlm_wall = seq
            break
        seq *= 2
    out.append(BenchResult(
        "fig2/fat_row_wall", 0.0,
        {"fatrow_wall_seq_len": wall,
         "paper_wall_approx": 4096,
         "vlm_wall_seq_len": vlm_wall or f">{1 << 22}"},
    ))
    return out


if __name__ == "__main__":
    for r in run():
        print(r.csv())
