"""Multi-tenant sequence projection (paper §2.3, §4.1.2, §4.2.2).

Each model tenant declares its UIH requirements — target sequence length,
feature groups, and optionally a trait subset per group. The DPP query engine
pushes these down to the immutable store so short-sequence / few-feature
tenants never over-fetch (eliminating the multi-tenant penalty).

Trait ordering is **canonical**: ``timestamp`` first (it is the versioning
key), then the group's schema order, then any non-schema extras in declaration
order, deduped. Overridden and schema-default groups therefore produce
identical orderings for identical trait sets — which is what makes window-
cache keys, union projections, and per-tenant carved views line up
byte-for-byte.

``TenantProjection`` is frozen and hashable (``traits_per_group`` is
normalized to tuples at construction), so it can key caches and live inside a
frozen ``repro.data.DatasetSpec``. ``TenantProjection.union`` builds the
*union* projection serving N tenants from ONE scan (max ``seq_len``, union of
feature groups, per-group union of traits); ``project_view`` carves a single
tenant's view back out of a union-fetched window.
"""
from __future__ import annotations

import dataclasses
import types
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core import events as ev


def canonical_traits(
    schema: ev.TraitSchema, group: str, cols: Sequence[str]
) -> Tuple[str, ...]:
    """Canonicalize a trait list: ``timestamp`` first, then the group's schema
    order, then non-schema extras in declaration order; deduped."""
    requested: List[str] = []
    seen = set()
    for t in cols:
        if t not in seen:
            seen.add(t)
            requested.append(t)
    group_order = schema.group_traits(group)
    in_schema = [t for t in group_order if t in seen and t != "timestamp"]
    extras = [t for t in requested
              if t not in group_order and t != "timestamp"]
    return ("timestamp", *in_schema, *extras)


@dataclasses.dataclass(frozen=True)
class TenantProjection:
    name: str
    seq_len: int                                 # target UIH length (events)
    feature_groups: Tuple[str, ...]              # groups the model consumes
    traits_per_group: Optional[Mapping[str, Tuple[str, ...]]] = None

    def __post_init__(self):
        if self.seq_len <= 0:
            raise ValueError(f"seq_len must be positive, got {self.seq_len}")
        # normalize to immutable forms so the projection is safely hashable
        # (callers may hand in lists / dicts); the read-only proxy keeps a
        # projection already used as a cache/spec key from being mutated out
        # from under its recorded hash
        object.__setattr__(self, "feature_groups", tuple(self.feature_groups))
        if self.traits_per_group is not None:
            object.__setattr__(
                self, "traits_per_group",
                types.MappingProxyType(
                    {g: tuple(cols)
                     for g, cols in self.traits_per_group.items()}))

    # dict fields are unhashable; hash the canonical content fingerprint
    # (dataclass __eq__ still compares fields directly, which is consistent:
    # equal projections have equal fingerprints)
    def __hash__(self) -> int:
        return hash(self.fingerprint())

    def fingerprint(self) -> tuple:
        """Hashable identity of the projection's content (cache keys)."""
        tp = self.traits_per_group
        return (
            self.name,
            self.seq_len,
            self.feature_groups,
            tuple(sorted((g, tuple(c)) for g, c in tp.items())) if tp else None,
        )

    def traits_for(self, schema: ev.TraitSchema, group: str) -> Tuple[str, ...]:
        """The group's traits under this projection, in canonical order.

        Both the override path and the schema-default path go through the same
        canonicalization (timestamp first, then schema order, deduped) — the
        orderings must not depend on WHERE the trait list came from, or
        ``all_traits()`` of two equivalent projections would differ."""
        if self.traits_per_group and group in self.traits_per_group:
            cols = self.traits_per_group[group]
        else:
            cols = schema.group_traits(group)
        return canonical_traits(schema, group, cols)

    def all_traits(self, schema: ev.TraitSchema) -> Tuple[str, ...]:
        seen = []
        for g in self.feature_groups:
            for t in self.traits_for(schema, g):
                if t not in seen:
                    seen.append(t)
        return tuple(seen)

    @classmethod
    def union(
        cls,
        tenants: Sequence["TenantProjection"],
        schema: ev.TraitSchema,
        name: str = "union",
    ) -> "TenantProjection":
        """The union projection serving every tenant from ONE co-scan (§2.3):
        max ``seq_len``, union of feature groups (schema order first, then
        non-schema extras), per-group union of traits in canonical order.

        Each tenant's solo fetch is a *sub-view* of the union fetch:
        ``project_view`` carves it back out byte-identically."""
        tenants = list(tenants)
        if not tenants:
            raise ValueError("union of zero tenants")
        if len(tenants) == 1:
            return tenants[0]
        groups: List[str] = []
        for t in tenants:
            for g in t.feature_groups:
                if g not in groups:
                    groups.append(g)
        schema_order = [g for g in schema.feature_groups if g in groups]
        groups = schema_order + [g for g in groups if g not in schema_order]
        traits: Dict[str, Tuple[str, ...]] = {}
        for g in groups:
            cols: List[str] = []
            for t in tenants:
                if g in t.feature_groups:
                    for c in t.traits_for(schema, g):
                        if c not in cols:
                            cols.append(c)
            traits[g] = canonical_traits(schema, g, cols)
        return cls(
            name=name,
            seq_len=max(t.seq_len for t in tenants),
            feature_groups=tuple(groups),
            traits_per_group=traits,
        )


def project_view(
    window: ev.EventBatch, tenant: TenantProjection, schema: ev.TraitSchema
) -> ev.EventBatch:
    """Carve one tenant's immutable view out of a wider (union-projection)
    window: keep the most recent ``seq_len`` events, project to the tenant's
    traits. Byte-identical to the tenant's own solo store fetch — the union
    window holds the most recent ``max(seq_len)`` events of the SAME bounded
    range, so its tail is exactly the narrower tenant's event set."""
    return ev.tail_view(window, tenant.seq_len, tenant.all_traits(schema))


# The paper's three evaluation tenants (Table 1): long / mid / short sequence.
def table1_tenants(
    long_len: int = 2048, mid_len: int = 512, short_len: int = 64
) -> Dict[str, TenantProjection]:
    return {
        "model_a": TenantProjection(
            name="model_a",  # flagship late-stage ranking: long seq, all groups
            seq_len=long_len,
            feature_groups=("core", "engagement", "sideinfo"),
        ),
        "model_b": TenantProjection(
            name="model_b",  # pre-ranking: mid seq, no sideinfo
            seq_len=mid_len,
            feature_groups=("core", "engagement"),
        ),
        "model_c": TenantProjection(
            name="model_c",  # retrieval: short seq, core ids only
            seq_len=short_len,
            feature_groups=("core",),
            traits_per_group={"core": ("timestamp", "item_id")},
        ),
    }
