"""Elastic DPP scaling + straggler mitigation (paper §4.2.1; fault tolerance).

The controller watches job-level GPU-starvation % (trainer idle) and worker
waste % (CPU idle) and adjusts the provisioned worker count so training stays
compute-bound. ``DPPWorkerPool`` runs N featurizing workers over planned work
items straight into the trainer's slot-based rebatching client, resizing live
on the controller's decisions. ``StragglerAwarePool`` re-dispatches work items
whose worker exceeded the straggler deadline (speculative execution), and
survives worker crashes.

**Self-healing** (``max_item_retries > 0``): a worker that dies mid-item —
store IOError, decode corruption, a crash injected by the fault harness
(``repro.testing``) — requeues its work item at the FRONT of the dispatch
order with a per-item attempt count, and a replacement worker (fresh state,
fresh caches) is spawned before the dying thread exits. Materialization is a
pure read, so re-running an item is safe; the item never reached the client
(failures inside ``put`` are NOT healed — a partially placed base batch
poisons its slot and retrying would duplicate rows), so slot accounting stays
exact and the output is byte-identical to a fault-free run. An item that
exhausts its retries is handed to ``on_abandon`` (streaming drop semantics:
release its generation leases) when set, else its error is fatal — batch
training must never silently drop examples. Surfaced via ``WorkerStats``:
``worker_restarts``, ``items_requeued``, ``lease_recoveries``.

**Ordered placement** (``ordered=True``): workers still materialize+featurize
concurrently, but finished base batches pass through a reorder buffer and a
single placer thread that copies them into the rebatching client in work-item
sequence order. Emitted full batches then compose deterministically from the
item list regardless of worker count, scheduling, crashes, or retries — the
property both the chaos tests ("byte-identical to the fault-free run") and
``Feed.checkpoint`` exactly-once resume (rows consumed = a prefix of the
canonical example order) are built on. Admission control bounds how far ahead
of the placement cursor a worker may start (``4 × workers``), so a slow head
item cannot buffer the whole epoch in RAM.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Callable, Deque, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.backoff import Backoff


@dataclasses.dataclass
class ElasticConfig:
    min_workers: int = 1
    max_workers: int = 32
    target_starvation_pct: float = 2.0   # scale up above this
    target_waste_pct: float = 60.0       # scale down above this
    step: int = 1


class ElasticController:
    """Pure decision logic (separated from the pool so it is unit-testable)."""

    def __init__(self, cfg: ElasticConfig):
        self.cfg = cfg
        self.decisions: List[int] = []

    def decide(self, workers: int, starvation_pct: float, waste_pct: float) -> int:
        new = workers
        if starvation_pct > self.cfg.target_starvation_pct:
            new = min(self.cfg.max_workers, workers + self.cfg.step)
        elif waste_pct > self.cfg.target_waste_pct and starvation_pct == 0.0:
            new = max(self.cfg.min_workers, workers - self.cfg.step)
        self.decisions.append(new)
        return new


@dataclasses.dataclass
class PoolStats:
    completed: int = 0
    speculative_retries: int = 0
    worker_failures: int = 0


class DPPWorkerPool:
    """N DPP workers draining planned work items into a rebatching client.

    Each thread owns a private ``DPPWorker`` (materializers are not shared
    across threads — their window caches and IO accounting are thread-local by
    design), pulls work items (example lists, e.g. ``plan_affine(...).items``)
    from a shared queue, and ``put``s the featurized base batch into the slot
    buffer of the trainer's ``RebatchingClient``.

    Elasticity: a monitor thread periodically feeds the job-level signals —
    trainer ``starvation_pct`` from the client, mean worker ``waste_pct`` —
    to an ``ElasticController`` and applies its decision: growth starts new
    worker threads immediately; shrink is cooperative (threads with index
    beyond the target retire before their next pull). Worker exceptions are
    captured and re-raised from ``join``/``run`` — never swallowed.
    """

    def __init__(
        self,
        worker_factory: Callable[[], "object"],
        client,
        n_workers: int = 2,
        controller: Optional[ElasticController] = None,
        control_interval_s: float = 0.25,
        close_client: bool = True,
        jagged: bool = True,
        max_item_retries: int = 0,
        ordered: bool = False,
        on_place: Optional[Callable[[List], None]] = None,
        on_abandon: Optional[Callable[[List, BaseException], None]] = None,
        on_skip: Optional[Callable[[List], None]] = None,
        retry_backoff: Optional["Backoff"] = None,
    ):
        self.worker_factory = worker_factory
        self.client = client
        self.controller = controller
        self.control_interval_s = control_interval_s
        self.close_client = close_client
        # fused path: workers emit arena+offsets base batches and the client
        # scatters them straight into slots (falls back to the dense put when
        # either side predates the jagged API)
        self.jagged = (jagged and hasattr(client, "put_jagged"))
        self._items: "queue.Queue" = queue.Queue()
        self._n_initial = n_workers
        self._lock = threading.Lock()
        self._threads: List[threading.Thread] = []
        self._workers: List[object] = []
        self._errors: List[BaseException] = []
        self._live = 0      # threads spawned and not yet exited
        self._retire = 0    # pending cooperative-shrink tokens
        self._done = threading.Event()
        # set once no further items will arrive: immediately by ``start``
        # (static work list), by the feeder thread's exit for ``start_stream``
        self._feed_done = threading.Event()
        self._feeder: Optional[threading.Thread] = None
        self._monitor: Optional[threading.Thread] = None
        self.items_done = 0
        self.peak_workers = n_workers
        # -- self-healing (see class docstring) -------------------------------
        self.max_item_retries = max_item_retries
        self.on_abandon = on_abandon
        # seeded deterministic backoff between an item's retries (shared
        # helper with the store failover path): the delay is a pure function
        # of (seed, attempt, item seq), so chaos runs stay reproducible.
        # None = immediate requeue (the historical behavior).
        self.retry_backoff = retry_backoff
        self._seq = 0                       # next work-item sequence number
        # retried tasks go to the FRONT of the dispatch order (ahead of the
        # shared queue): with one worker this restores exact item order, with
        # N it minimizes reorder-buffer stall after a crash
        self._retry: Deque[Tuple[int, int, List]] = collections.deque()
        # seq -> monotonic not-before time: the backoff delay of a requeued
        # item, paid by the worker that CLAIMS the retry (the retry itself is
        # visible in ``_retry`` immediately — an invisible in-flight retry
        # could wedge ordered admission: every worker blocks in ``_admit`` on
        # seqs past the crashed hole while nobody holds the hole's retry)
        self._retry_ready: Dict[int, float] = {}
        self.worker_restarts = 0
        self.items_requeued = 0
        self.items_abandoned = 0
        self.lease_recoveries = 0   # via record_lease_recoveries (lock-guarded)
        # -- ordered placement -------------------------------------------------
        self.ordered = ordered
        self.on_place = on_place
        # called (in placement order) for an item that reached its placement
        # turn WITHOUT output — abandoned after retries. Consumers tracking
        # stream positions (the session's resume cursor) must see skips too.
        self.on_skip = on_skip
        self._place_cv = threading.Condition()
        # seq -> (put_fn, out, item); (None, None, None) = tombstone
        self._obuf: Dict[int, Tuple] = {}
        self._next_place = 0
        self._obuf_cap = max(8, 4 * n_workers)
        self._place_dead = False
        self._placer: Optional[threading.Thread] = None
        # optional per-run telemetry (repro.obs.Telemetry): span mint point
        # for the whole pipeline — the work-item seq IS the correlation id
        self.telemetry = None

    @classmethod
    def from_plan(cls, plan, client, **kwargs) -> "DPPWorkerPool":
        """Pool over a spec-compiled ``repro.dpp.worker.WorkerPlan`` instead
        of a hand-wired worker factory (the declarative read path's entry)."""
        from repro.dpp.worker import DPPWorker

        return cls(lambda: DPPWorker.from_plan(plan), client, **kwargs)

    # -- worker loop -------------------------------------------------------------
    def _task(self, item: List) -> Tuple[int, int, List]:
        with self._lock:
            seq = self._seq
            self._seq += 1
        tel = self.telemetry
        if tel is not None:
            tel.spans.mint(seq)   # sampled 1-in-N inside the tracker
        return (seq, 0, item)

    def _worker_loop(self, worker) -> None:
        t0 = time.perf_counter()
        try:
            while True:
                with self._lock:
                    if self._retire > 0:
                        self._retire -= 1
                        return  # cooperative shrink: retire this thread
                    task = self._retry.popleft() if self._retry else None
                    not_before = (self._retry_ready.pop(task[0], 0.0)
                                  if task is not None else 0.0)
                if task is not None and not_before:
                    # claimed retry still inside its backoff window: THIS
                    # thread owns it now (it counts in ``_live``, so the pool
                    # cannot drain out underneath), so just wait it out
                    remaining = not_before - time.monotonic()
                    if remaining > 0:
                        time.sleep(remaining)
                if task is None:
                    try:
                        task = self._items.get(timeout=0.05)
                    except queue.Empty:
                        if self._feed_done.is_set() and self._items.empty():
                            with self._lock:
                                if not self._retry:
                                    return  # stream over AND queues drained
                        continue    # live feed: stay parked for the next item
                seq, attempts, item = task
                if self.ordered and not self._admit(seq):
                    # placement is wedged (placer died): hand the task back so
                    # any surviving sibling can observe it, and bail out
                    with self._lock:
                        self._retry.append(task)
                    return
                tel = self.telemetry
                if tel is not None:
                    # park this item's span in the thread-local so the
                    # worker's _lookup/_featurize record stages ambiently
                    tel.spans.enter_item(seq)
                try:
                    if self.jagged and hasattr(worker, "process_jagged"):
                        out = worker.process_jagged(item)
                        put = self.client.put_jagged
                    else:
                        out = worker.process(item)
                        put = self.client.put
                except BaseException as exc:
                    # the item never reached the client: requeue-and-respawn is
                    # safe (materialization is a pure read). Failures inside
                    # ``put`` below are NOT healed — a partial placement
                    # poisons its slot, so a retry would duplicate rows.
                    if tel is not None:
                        tel.events.emit("worker_crash", seq=seq,
                                        error=type(exc).__name__)
                    if self._heal(seq, attempts, item, exc):
                        return  # replacement spawned; this thread retires
                    if tel is not None:
                        tel.spans.abandon(seq)
                    self._tombstone(seq)
                    raise
                finally:
                    if tel is not None:
                        tel.spans.exit_item()
                self._deliver(seq, item, out, put)
                with self._lock:
                    self.items_done += 1
        except BaseException as e:
            with self._lock:
                self._errors.append(e)
        finally:
            with self._lock:
                self._live -= 1
            if self.ordered:
                with self._place_cv:
                    self._place_cv.notify_all()  # placer re-checks liveness
            worker.stats.total_time_s += time.perf_counter() - t0

    # -- self-healing ------------------------------------------------------------
    def _heal(self, seq: int, attempts: int, item: List,
              exc: BaseException) -> bool:
        """Recover from a worker dying mid-item. Returns True when handled
        (item requeued or abandoned, replacement spawned); False means the
        failure is fatal and the caller must record it."""
        if self.max_item_retries <= 0:
            return False
        attempts += 1
        if attempts > self.max_item_retries:
            if self.on_abandon is None:
                # batch training: silently dropping examples is worse than
                # dying — surface the poison item's error from join()
                return False
            with self._lock:
                self.items_abandoned += 1
            try:
                self.on_abandon(item, exc)
            except BaseException as cb_exc:
                with self._lock:
                    self._errors.append(cb_exc)
            if self.telemetry is not None:
                self.telemetry.spans.abandon(seq)
                self.telemetry.events.emit("item_abandoned", seq=seq,
                                           attempts=attempts)
            self._tombstone(seq, item)
        else:
            with self._lock:
                if self.retry_backoff is not None:
                    # seeded deterministic delay between this item's retries;
                    # stamped as a not-before time and paid by the worker
                    # that claims the retry (see ``_retry_ready``)
                    self._retry_ready[seq] = time.monotonic() + \
                        self.retry_backoff.delay(attempts - 1, token=seq)
                self._retry.append((seq, attempts, item))
                self.items_requeued += 1
            if self.telemetry is not None:
                self.telemetry.events.emit("item_requeued", seq=seq,
                                           attempts=attempts)
        self._respawn()
        return True

    def record_lease_recoveries(self, n: int) -> None:
        """Count leases released through crash recovery (the session's
        ``on_abandon`` calls this; every pool counter mutates under the
        lock so concurrent abandons cannot lose updates)."""
        with self._lock:
            self.lease_recoveries += n

    def _respawn(self) -> None:
        """Replace a dying worker with a fresh one (fresh materializer, fresh
        caches) BEFORE the dying thread exits, so the logical worker count —
        and the guarantee that a requeued head item finds a runnable thread —
        never dips."""
        if self.telemetry is not None:
            self.telemetry.events.emit("worker_restart")
        with self._lock:
            self.worker_restarts += 1
            if self._retire > 0:
                self._retire -= 1   # a pending shrink wanted one fewer anyway
                return
            worker = self.worker_factory()
            th = threading.Thread(target=self._worker_loop, args=(worker,),
                                  daemon=True)
            self._workers.append(worker)
            self._threads.append(th)
            self._live += 1
            th.start()

    # -- ordered placement (reorder buffer -> single placer thread) ---------------
    def _admit(self, seq: int) -> bool:
        """Bound how far ahead of the placement cursor a worker may start: a
        slow/crashed head item must not let the rest of the pool materialize
        the whole epoch into the reorder buffer. The head (and any already
        admitted retry) is always admitted, so recovery cannot deadlock."""
        with self._place_cv:
            while seq >= self._next_place + self._obuf_cap:
                if self._place_dead or self._done.is_set():
                    return False
                self._place_cv.wait(timeout=0.1)
            return not self._place_dead

    def _put_with_span(self, seq: int, put, out) -> None:
        """``put`` with the item's span parked in the thread-local so the
        client can attach it to every slot the rows land in; records the
        place stage and retires the span from the live-item map."""
        tel = self.telemetry
        if tel is None:
            put(out)
            return
        tel.spans.enter_item(seq, attempt=False)
        t0 = time.perf_counter()
        try:
            put(out)
            sp = tel.spans.get(seq)
            if sp is not None:
                sp.stage("place", t0, time.perf_counter())
        finally:
            tel.spans.exit_item()
            tel.spans.finish_item(seq)

    def _finish_span(self, seq: int) -> None:
        """Retire an item that reached its placement turn without a ``put``
        (worker dropped every example) so its span cannot orphan."""
        if self.telemetry is not None:
            self.telemetry.spans.finish_item(seq)

    def _deliver(self, seq: int, item: List, out, put) -> None:
        if not self.ordered:
            if self.on_place is not None:
                self.on_place(item)     # before put, as in the placer
            if out is not None:   # None = worker dropped every example
                self._put_with_span(seq, put, out)
            else:
                self._finish_span(seq)
            return
        with self._place_cv:
            self._obuf[seq] = (put, out, item)
            self._place_cv.notify_all()

    def _tombstone(self, seq: int, item: Optional[List] = None) -> None:
        """Mark a seq that will never produce output (abandoned item or fatal
        failure) so ordered placement can advance past it. An abandoned item
        rides along so ``on_skip`` can observe it at its placement turn."""
        if not self.ordered:
            return
        with self._place_cv:
            self._obuf[seq] = (None, None, item)
            self._place_cv.notify_all()

    def _placer_loop(self) -> None:
        try:
            while True:
                with self._place_cv:
                    while self._next_place not in self._obuf:
                        if self._placer_done():
                            return
                        self._place_cv.wait(timeout=0.05)
                    seq = self._next_place
                    put, out, item = self._obuf.pop(seq)
                # place OUTSIDE the cv: ``put`` may block on the client's
                # bounded slot queue (that stall IS the pool's backpressure —
                # admission gates on the cursor, which only moves below).
                # on_place runs BEFORE put: the session's resume ledger must
                # cover a row before the batch containing it can possibly be
                # delivered/trained/checkpointed (ledger-ahead is harmless,
                # ledger-behind would crash a racing checkpoint())
                if put is not None:
                    if item is not None and self.on_place is not None:
                        self.on_place(item)
                    if out is not None:
                        self._put_with_span(seq, put, out)
                    else:
                        self._finish_span(seq)
                elif item is not None and self.on_skip is not None:
                    self.on_skip(item)   # abandoned item reached its turn
                with self._place_cv:
                    self._next_place += 1
                    self._place_cv.notify_all()
        except BaseException as e:
            with self._lock:
                self._errors.append(e)
            with self._place_cv:
                self._place_dead = True      # unwedge admission waiters
                self._place_cv.notify_all()

    def _placer_done(self) -> bool:
        """Call with ``_place_cv`` held and ``_next_place`` not buffered: no
        further deposit can arrive once the feed is finished and no worker is
        alive to produce (or requeue) one."""
        if not self._feed_done.is_set():
            return False
        with self._lock:
            return self._live == 0 and not self._retry

    def _resize_to(self, target: int) -> None:
        """Grow by spawning threads; shrink by issuing retirement tokens."""
        with self._lock:
            logical = self._live - self._retire
            if target > logical:
                for _ in range(target - logical):
                    worker = self.worker_factory()
                    th = threading.Thread(target=self._worker_loop,
                                          args=(worker,), daemon=True)
                    self._workers.append(worker)
                    self._threads.append(th)
                    self._live += 1
                    th.start()
            elif target < logical:
                self._retire += logical - target
            self.peak_workers = max(self.peak_workers, target)

    def current_workers(self) -> int:
        with self._lock:
            return max(0, self._live - self._retire)

    # -- elasticity ---------------------------------------------------------------
    def _busy_time_total(self) -> float:
        with self._lock:
            workers = list(self._workers)
        return sum(w.stats.busy_time_s for w in workers)

    def _monitor_loop(self) -> None:
        """Feed WINDOWED starvation/waste to the controller: lifetime
        aggregates ratchet — one slow warmup step (jit compile) would read as
        permanent starvation, growing to max_workers and never shrinking
        (the shrink branch needs a starvation-free WINDOW, which a cumulative
        counter can never show again after its first recorded wait)."""
        last_starved = self.client.stats.starved_time_s
        last_train = self.client.stats.train_time_s
        last_busy = self._busy_time_total()
        last_t = time.perf_counter()
        while not self._done.wait(self.control_interval_s):
            if self._feed_done.is_set() and self._items.empty():
                return
            s = self.client.stats
            now = time.perf_counter()
            d_starved = s.starved_time_s - last_starved
            d_train = s.train_time_s - last_train
            busy = self._busy_time_total()
            d_busy = busy - last_busy
            d_wall = (now - last_t) * max(self.current_workers(), 1)
            last_starved, last_train, last_busy, last_t = (
                s.starved_time_s, s.train_time_s, busy, now)
            denom = d_starved + d_train
            starvation = 100.0 * d_starved / denom if denom > 0 else 0.0
            waste = max(0.0, 1.0 - d_busy / d_wall) * 100.0 if d_wall > 0 \
                else 0.0
            new = self.controller.decide(self.current_workers(), starvation,
                                         waste)
            self._resize_to(new)

    # -- API ---------------------------------------------------------------------
    def start(self, items: Sequence[List]) -> "DPPWorkerPool":
        """Dispatch a STATIC work list; workers exit once it is drained."""
        for item in items:
            self._items.put(self._task(item))
        self._feed_done.set()
        self._start_threads()
        return self

    def start_stream(self, items: Iterable[List],
                     max_buffered: int = 0) -> "DPPWorkerPool":
        """Dispatch a LIVE item source (e.g. ``StreamingSource.micro_batches``):
        a feeder thread pulls items as they become available and workers stay
        parked across idle gaps; they exit only when the source is exhausted
        AND the queue is drained. A feeder failure is re-raised from
        ``join()`` like any worker error.

        ``max_buffered`` > 0 bounds the item queue, applying backpressure to
        the source — without it a fast producer (e.g. a warehouse backfill
        replay) would buffer its entire output in memory ahead of the
        workers."""
        if max_buffered > 0:
            # workers have not started yet; swapping the queue is safe
            self._items = queue.Queue(maxsize=max_buffered)

        def feeder() -> None:
            try:
                for item in items:
                    task = self._task(item)
                    while True:
                        # NO live workers + recorded errors = the pool died:
                        # stop feeding (checked per attempt, not just on
                        # queue.Full, so an unbounded queue doesn't keep
                        # consuming the source for nobody), or join() (and
                        # the client close that unblocks the trainer) would
                        # wait on this feeder forever
                        with self._lock:
                            dead = self._live == 0 and bool(self._errors)
                        if dead:
                            return
                        try:
                            self._items.put(task, timeout=0.1)
                            break
                        except queue.Full:
                            continue
            except BaseException as e:
                with self._lock:
                    self._errors.append(e)
            finally:
                self._feed_done.set()

        self._feeder = threading.Thread(target=feeder, daemon=True,
                                        name="dpp-feeder")
        self._feeder.start()
        self._start_threads()
        return self

    def _start_threads(self) -> None:
        self._resize_to(self._n_initial)
        if self.ordered and self._placer is None:
            self._placer = threading.Thread(target=self._placer_loop,
                                            daemon=True, name="dpp-placer")
            self._placer.start()
        if self.controller is not None:
            self._monitor = threading.Thread(target=self._monitor_loop,
                                             daemon=True)
            self._monitor.start()

    def _join_workers(self) -> None:
        while True:
            with self._lock:
                alive = [t for t in self._threads if t.is_alive()]
            if not alive:
                return
            for t in alive:
                t.join()

    @property
    def errors(self) -> List[BaseException]:
        with self._lock:
            return list(self._errors)

    def join(self) -> None:
        try:
            # workers first: if they ALL died on errors while the feeder is
            # parked on a full bounded queue, the feeder's dead-pool check
            # needs the worker exits to have landed before it can abort
            self._join_workers()
            if self._feeder is not None:
                while self._feeder.is_alive():
                    self._feeder.join(timeout=0.1)
                    if self._feeder.is_alive():
                        with self._lock:
                            dead = self._live == 0 and bool(self._errors)
                        if dead:
                            # the feeder may be parked INSIDE the source
                            # iterator (idle-open stream) where no dead-pool
                            # check can run: abandon the daemon thread so the
                            # client close + error re-raise below still happen
                            break
            self._join_workers()
            self._done.set()
            if self._monitor is not None:
                self._monitor.join()
            self._join_workers()   # monitor may have spawned a final thread
            if self._placer is not None:
                self._placer.join()
        finally:
            # close EVEN ON worker failure: the consumer must receive the
            # end-of-stream sentinel or it blocks forever on a dead feed
            # (the raise below reaches join's caller, not the trainer)
            if self.close_client:
                self.client.close()
        if self._errors:
            raise RuntimeError(
                f"{len(self._errors)} DPP worker(s) failed") from self._errors[0]

    def run(self, items: Sequence[List]) -> "DPPWorkerPool":
        """Blocking convenience: dispatch ``items``, wait, close the client.

        The client's buffer must be drained concurrently (or sized to hold the
        whole stream) or workers block on the bounded slot queue."""
        self.start(items)
        self.join()
        return self

    def merged_worker_stats(self):
        """Aggregate per-thread WorkerStats into one job-level view."""
        from repro.dpp.worker import WorkerStats

        out = WorkerStats()
        with self._lock:
            workers = list(self._workers)
        for w in workers:
            s = w.stats
            out.base_batches += s.base_batches
            out.examples += s.examples
            out.probe_time_s += s.probe_time_s
            out.lookup_time_s += s.lookup_time_s
            out.featurize_time_s += s.featurize_time_s
            out.total_time_s += s.total_time_s
            out.dedup_hits += s.dedup_hits
            out.decode_cache_hits += s.decode_cache_hits
            out.parallel_shards += s.parallel_shards
        with self._lock:
            out.worker_restarts += self.worker_restarts
            out.items_requeued += self.items_requeued
            out.lease_recoveries += self.lease_recoveries
        return out


class StragglerAwarePool:
    """Thread pool with deadline-based speculative re-dispatch.

    Work items are idempotent (materialization is a pure read), so running a
    straggler's item twice is safe — first completion wins.
    """

    def __init__(
        self,
        work_fn: Callable[[object], object],
        n_workers: int = 2,
        straggler_deadline_s: float = 5.0,
    ):
        self.work_fn = work_fn
        self.straggler_deadline_s = straggler_deadline_s
        self._task_q: "queue.Queue" = queue.Queue()
        self._done: Dict[int, object] = {}
        self._done_cv = threading.Condition()
        self._inflight: Dict[int, float] = {}   # task id -> dispatch time
        self._retried: set = set()
        self._stop = threading.Event()
        self.stats = PoolStats()
        self._threads: List[threading.Thread] = []
        self.resize(n_workers)

    # -- worker loop -------------------------------------------------------------
    def _loop(self, me: int) -> None:
        while not self._stop.is_set():
            try:
                task_id, payload = self._task_q.get(timeout=0.05)
            except queue.Empty:
                continue
            with self._done_cv:
                if task_id in self._done:   # speculative duplicate already done
                    continue
                self._inflight[task_id] = time.perf_counter()
            try:
                result = self.work_fn(payload)
            except Exception:
                self.stats.worker_failures += 1
                # crash-equivalent: re-queue the item for another worker
                self._task_q.put((task_id, payload))
                continue
            with self._done_cv:
                if task_id not in self._done:
                    self._done[task_id] = result
                    self.stats.completed += 1
                self._inflight.pop(task_id, None)
                self._done_cv.notify_all()

    # -- API ---------------------------------------------------------------------
    def submit(self, task_id: int, payload: object) -> None:
        self._task_q.put((task_id, payload))

    def _respeculate(self, pending_payloads: Dict[int, object]) -> None:
        now = time.perf_counter()
        with self._done_cv:
            for tid, started in list(self._inflight.items()):
                if (
                    now - started > self.straggler_deadline_s
                    and tid not in self._retried
                    and tid in pending_payloads
                ):
                    self._retried.add(tid)
                    self.stats.speculative_retries += 1
                    self._task_q.put((tid, pending_payloads[tid]))

    def gather(self, task_ids, payloads: Dict[int, object], timeout_s: float = 60.0):
        """Wait for all task_ids, re-dispatching stragglers as needed."""
        deadline = time.perf_counter() + timeout_s
        while True:
            with self._done_cv:
                if all(t in self._done for t in task_ids):
                    return [self._done[t] for t in task_ids]
                self._done_cv.wait(timeout=0.05)
            self._respeculate(payloads)
            if time.perf_counter() > deadline:
                raise TimeoutError("pool gather timed out")

    def resize(self, n_workers: int) -> None:
        while len(self._threads) < n_workers:
            t = threading.Thread(target=self._loop, args=(len(self._threads),),
                                 daemon=True)
            t.start()
            self._threads.append(t)
        # shrink is cooperative: extra threads exit when stop is set; for the
        # simulation we only record the logical size
        self.n_workers = n_workers

    def shutdown(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=1.0)
