"""Serving-tier QPS + tail latency under three regimes (DESIGN.md §14.6).

One request mix (live traffic: every request asks for a user's UIH as of
"now", user sequence replayed from the sim's logged requests so repeat users
dominate), served twice per regime — a COLD wave against an empty embedding
cache and a WARM wave of the identical mix — under:

  * ``serve_healthy``     — monolith store, nothing racing: baseline QPS,
                            p50/p99, and the warm/cold speedup the per-user
                            embedding cache buys (asserted >= 2x in full
                            mode; open-loop waves, so the wall measures
                            server throughput rather than caller-thread
                            scheduling, and p50/p99 include queueing);
  * ``serve_churn``       — a compaction thread flips generations the whole
                            time: every flip invalidates cached embeddings
                            and forces re-materialization, yet snapshot
                            consistency must hold (zero failed requests, no
                            ``StaleGeneration`` escapes, no leaked leases);
  * ``serve_faults``      — the 4-node r=2 sharded/replicated tier with a
                            seeded ``FaultPlan`` of ``node_flap`` +
                            ``node_slow``: flaps are absorbed by replica
                            failover, slow nodes stretch the tail, and the
                            same zero-escape invariants are asserted.

Every wave also asserts the warm results byte-identical to the cold wave's
(healthy regime) — the cache is a latency optimization, never a staleness
trade.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult
from repro.core import events as ev
from repro.core.simulation import ProductionSim, SimConfig
from repro.models import recsys as R
from repro.obs import Telemetry
from repro.serve import RetrievalServer, ServeConfig
from repro.testing import FaultPlan, FaultSpec, wrap_sim

CORPUS = 2_048
TOP_K = 10
CALLERS = 64

# remote-I/O latency for the disaggregated regime: light enough for a quick
# run, heavy enough that a node_slow x8 stretch is visible in the tail
SERVE_LATENCY = (lambda seeks, nbytes, fanout:
                 3e-4 * seeks + nbytes / 2e8)


def _model_cfg(quick: bool) -> R.TwoTowerConfig:
    return R.TwoTowerConfig(
        name="bench-serve", embed_dim=32, tower_mlp=(64, 32),
        item_vocab=CORPUS, user_vocab=4_096,
        uih_len=16 if quick else 128, compute_dtype=jnp.float32)


def _sim(quick: bool, nodes: int = 0, replication: int = 1,
         hedge: float = 0.0, events_mean: float = 0.0,
         users: int = 0) -> ProductionSim:
    # full mode targets the paper's regime: dense histories so the cold
    # path's scan+featurize+encode is the dominant cost a cache can save.
    # ``events_mean``/``users`` override that shape (the churn regime needs
    # cheap compaction cycles so generation flips actually race the waves).
    d_users, days = (8, 2) if quick else (32, 4)
    users = users or d_users
    sim = ProductionSim(SimConfig(
        stream=ev.StreamConfig(
            n_users=users, n_items=CORPUS, days=days + 2,
            events_per_user_day_mean=(
                events_mean or (20.0 if quick else 400.0)), seed=7),
        stripe_len=16, requests_per_user_day=3, mode="vlm", seed=7,
        n_store_nodes=nodes, replication_factor=replication,
        hedge_quantile=hedge))
    sim.run_days(days, capture_reference=False)
    return sim


def _mix(sim, n_requests: int) -> Tuple[int, List[int]]:
    """(now, user sequence): the logged request users replayed round-robin,
    all asking for their UIH as of the last logged request time."""
    now = max(e.request_ts for e in sim.examples)
    seq = [e.user_id for e in sim.examples]
    users = (seq * (n_requests // len(seq) + 1))[:n_requests]
    return now, users


def _issue(server: RetrievalServer, now: int, users: List[int]):
    """Fire the mix from CALLERS concurrent threads; returns (results,
    wall_s, per-request latencies)."""
    lats: List[float] = []
    lock = threading.Lock()

    def one(u: int):
        t0 = time.perf_counter()
        r = server.retrieve(u, now, k=TOP_K, timeout=60.0)
        dt = time.perf_counter() - t0
        with lock:
            lats.append(dt)
        return r

    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=CALLERS) as pool:
        results = list(pool.map(one, users))
    return results, time.perf_counter() - t0, lats


def _issue_open(server: RetrievalServer, now: int, users: List[int]):
    """Open-loop throughput wave: enqueue the WHOLE mix up front, then drain.
    The coalescer sees genuinely full batches and the wall clock measures
    server-side throughput instead of caller-thread scheduling jitter (the
    closed-loop ``_issue`` wall is dominated by Python thread wakeups)."""
    t0 = time.perf_counter()
    pendings = [server.submit(u, now, k=TOP_K) for u in users]
    results = [p.result(timeout=60.0) for p in pendings]
    wall = time.perf_counter() - t0
    lats = [p.done_t - p.enqueue_t for p in pendings]
    return results, wall, lats


def _warmup(server: RetrievalServer, now: int) -> None:
    """Trigger the XLA compiles (user tower at the pad shape, top-k scorer)
    outside the timed waves, then reset the caches so the cold wave is cold."""
    server.retrieve(0, now, k=TOP_K, timeout=60.0)
    if server.cache is not None:
        server.cache.clear()
    server.materializer._window_cache.clear()


def _assert_consistent(server: RetrievalServer, store) -> None:
    st = server.stats
    assert st.failed_requests == 0, f"requests failed: {st}"
    assert server.materializer.stats.stale_failures == 0, (
        "StaleGeneration escaped remediation")
    leaked = store.leased_generations()
    assert leaked == {}, f"leaked leases after shutdown: {leaked}"


def _result(name: str, wall_cold: float, wall_warm: float, n: int,
            lats: List[float], server: RetrievalServer,
            extra=None) -> BenchResult:
    st, cs = server.stats, server.cache.stats
    lat = np.asarray(lats)
    derived = {
        "qps_cold": round(n / wall_cold, 1),
        "qps_warm": round(n / wall_warm, 1),
        "warm_speedup": round(wall_cold / wall_warm, 2),
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
        "cold_requests": st.cold_requests,
        "cache_hit_rate": round(cs.hits / max(1, cs.lookups), 3),
        "batches": server.coalescer.stats.batches,
    }
    derived.update(extra or {})
    return BenchResult(name, wall_cold / n * 1e6, derived)


def _run_healthy(quick: bool, n_req: int, telemetry) -> BenchResult:
    """Cold path (cache disabled) vs warm cache on the SAME mix: the two
    servers share the store, model and params, so cache-on must be
    byte-identical to cache-off — and >= 2x faster once warm."""
    sim = _sim(quick)
    cfg_m = _model_cfg(quick)
    params = R.init_two_tower(jax.random.PRNGKey(0), cfg_m)
    now, users = _mix(sim, n_req)
    reps = 1 if quick else 2   # best-of-N walls: de-noise thread scheduling

    # batch size matches the caller count: in the closed loop the warm wave
    # then flushes mostly-full size batches, so its wall is ~n_req/CALLERS
    # top-k dispatches instead of dozens of ragged deadline flushes
    cold_srv = RetrievalServer.from_sim(
        sim, params, cfg_m, telemetry=telemetry,
        cfg=ServeConfig(max_batch=CALLERS, max_delay_s=0.002, cache_capacity=0,
                        window_cache_size=0,   # true cold path: every request scans
                        lookback_ms=sim.cfg.lookback_ms))
    _warmup(cold_srv, now)
    wall_cold, lats = float("inf"), []
    for _ in range(reps):      # cache-free server: every wave is fully cold
        cold, w, ls = _issue_open(cold_srv, now, users)
        wall_cold, lats = min(wall_cold, w), lats + ls
    cold_srv.close()
    _assert_consistent(cold_srv, sim.immutable)

    warm_srv = RetrievalServer.from_sim(
        sim, params, cfg_m, telemetry=telemetry,
        cfg=ServeConfig(max_batch=CALLERS, max_delay_s=0.002,
                        lookback_ms=sim.cfg.lookback_ms))
    _warmup(warm_srv, now)
    _issue_open(warm_srv, now, users)      # populate the embedding cache
    wall_warm, lats_w = float("inf"), []
    for _ in range(reps):
        warm, w, ls = _issue_open(warm_srv, now, users)
        wall_warm, lats_w = min(wall_warm, w), lats_w + ls
    warm_srv.close()
    _assert_consistent(warm_srv, sim.immutable)

    identical = all(
        np.array_equal(a.item_ids, b.item_ids)
        and np.array_equal(a.scores, b.scores)
        for a, b in zip(cold, warm))
    assert identical, "cache-on results diverged from the cache-off path"
    if not quick:
        assert wall_cold / wall_warm >= 2.0, (
            f"warm-cache throughput only {wall_cold / wall_warm:.2f}x the "
            f"cold path (acceptance floor is 2x)")
    return _result("serve/healthy", wall_cold, wall_warm, n_req,
                   lats + lats_w, warm_srv, {
                       "byte_identical": identical,
                       "qps_cold_path": round(n_req / wall_cold, 1)})


def _run_churn(quick: bool, n_req: int, telemetry) -> BenchResult:
    sim = _sim(quick, events_mean=20.0, users=8)
    cfg_m = _model_cfg(quick)
    params = R.init_two_tower(jax.random.PRNGKey(1), cfg_m)
    server = RetrievalServer.from_sim(
        sim, params, cfg_m, telemetry=telemetry,
        cfg=ServeConfig(max_batch=32, max_delay_s=0.001,
                        lookback_ms=sim.cfg.lookback_ms))
    now, users = _mix(sim, n_req)
    _warmup(server, now)
    gen0 = sim.immutable.generation
    stop = threading.Event()
    flips = [0]

    def churn():
        while not stop.is_set():
            sim.run_compaction(now, evict=False)   # generation churn
            flips[0] += 1
            time.sleep(0.002)

    th = threading.Thread(target=churn, daemon=True)
    th.start()
    try:
        _, wall_cold, lats = _issue(server, now, users)
        _, wall_warm, lats_w = _issue(server, now, users)
    finally:
        stop.set()
        th.join()
    server.close()
    _assert_consistent(server, sim.immutable)
    assert sim.immutable.generation > gen0 and flips[0] >= 1, (
        "churn thread never flipped a generation")
    return _result("serve/compaction_churn", wall_cold, wall_warm, n_req,
                   lats + lats_w, server, {
                       "generation_flips": flips[0],
                       "cache_invalidations":
                           server.cache.stats.invalidated_generation,
                       "pinned_windows":
                           server.materializer.stats.pinned_windows,
                   })


def _run_faults(quick: bool, n_req: int, telemetry) -> BenchResult:
    sim = _sim(quick, nodes=4, replication=2, hedge=0.9)
    cfg_m = _model_cfg(quick)
    params = R.init_two_tower(jax.random.PRNGKey(2), cfg_m)
    if quick:
        # a tiny run has too few scan ticks for seeded rates to reliably
        # land: pin one flap + one slow early so both paths still execute
        plan = FaultPlan([
            FaultSpec("node_flap", at=1, node=1, duration=2),
            FaultSpec("node_slow", at=3, node=2, duration=2, factor=8.0),
        ])
    else:
        plan = FaultPlan.seeded(
            11, {"node_flap": 0.10, "node_slow": 0.10}, horizon=48)
    fsim = wrap_sim(sim, plan)
    sim.immutable.latency_model = SERVE_LATENCY
    server = RetrievalServer.from_sim(
        fsim, params, cfg_m, telemetry=telemetry,
        cfg=ServeConfig(max_batch=32, max_delay_s=0.001,
                        lookback_ms=sim.cfg.lookback_ms))
    now, users = _mix(sim, n_req)
    _warmup(server, now)
    _, wall_cold, lats = _issue(server, now, users)
    _, wall_warm, lats_w = _issue(server, now, users)
    server.close()
    settled = fsim.immutable.settle_node_state()
    sim.immutable.latency_model = None
    _assert_consistent(server, sim.immutable)
    assert plan.n_fired >= 1, "fault plan never fired"
    io = sim.immutable.stats
    return _result("serve/sharded_faults", wall_cold, wall_warm, n_req,
                   lats + lats_w, server, {
                       "faults_fired": plan.n_fired,
                       "faults_settled": settled,
                       "failovers": io.failovers,
                       "hedged_reads": io.hedged_reads,
                       "degraded_scans": io.degraded_scans,
                   })


def run(quick: bool = False, telemetry=None):
    n_req = 96 if quick else 512
    tel = telemetry if telemetry is not None else Telemetry()
    return [
        _run_healthy(quick, n_req, tel),
        _run_churn(quick, n_req, tel),
        _run_faults(quick, n_req, tel),
    ]


if __name__ == "__main__":
    for r in run(quick="--quick" in __import__("sys").argv):
        print(r.csv())
