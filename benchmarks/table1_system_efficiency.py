"""Table 1 reproduction: system efficiency of VLM vs Fat Row on a shared
(union) dataset serving 3 model tenants.

Measured mechanisms (same causes as the paper, our scale):
  * primary write bandwidth of the shared training dataset (stream bytes)
  * per-tenant primary read bandwidth (serialized example bytes actually read)
  * per-tenant sequence-lookup bandwidth vs baseline primary read
    (streaming = arrival order, no warehouse clustering; batch = user-bucketed
    warehouse replay with affinity amortization)
  * per-batch data loading latency through a DPP worker with an emulated
    remote-storage cost model: primary store 256 MB/s; immutable single-level
    store 3.4x that (870 MB/s, §5.1) + 50us per batched multi-range scan.
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import BenchResult, standard_sim
from repro.core.projection import TenantProjection
from repro.dpp.affinity import plan_affine, plan_arrival_order
from repro.dpp.featurize import FeatureSpec
from repro.dpp.worker import DPPWorker

PAPER = {  # Table 1 reference values
    "write_bw_delta_pct": -46.2,
    "model_a": {"read": -70.3, "lookup_stream": +62.7, "lookup_batch": +24.6,
                "latency": +9.7},
    "model_b": {"read": -50.9, "lookup_stream": +16.2, "lookup_batch": +6.5,
                "latency": -26.4},
    "model_c": {"read": -47.7, "lookup_stream": +8.7, "lookup_batch": +3.4,
                "latency": -36.2},
}

TENANTS = {
    "model_a": TenantProjection("model_a", seq_len=360,
                                feature_groups=("core", "engagement", "sideinfo")),
    "model_b": TenantProjection("model_b", seq_len=96,
                                feature_groups=("core", "engagement")),
    "model_c": TenantProjection("model_c", seq_len=24,
                                feature_groups=("core",),
                                traits_per_group={"core": ("timestamp", "item_id")}),
}

BATCH = 16
BW_PRIMARY = 256e6          # bytes/s
BW_LOOKUP = 3.4 * BW_PRIMARY  # single-level immutable store (§5.1: 3.4x)
SCAN_OVERHEAD_S = 2e-5


def _spec_for(tenant: TenantProjection) -> FeatureSpec:
    return FeatureSpec(seq_len=tenant.seq_len,
                       uih_traits=("item_id", "timestamp"))


def _lookup_bytes(sim, tenant, affine: bool) -> int:
    """Immutable-store bytes for one full replay under a given access plan."""
    mat = sim.materializer(validate_checksum=False)
    plan_fn = plan_affine if affine else plan_arrival_order
    plan = plan_fn(sim.examples, sim.immutable.router.n_shards, BATCH)
    before = sim.immutable.stats.snapshot()
    for item in plan.items:
        mat.materialize_batch(item, tenant)
    return sim.immutable.stats.delta(before).bytes_scanned


DECODE_BW = 1e9  # bytes/s, same decode engine on both paths


def _batch_replay(sim, tenant) -> Dict[str, float]:
    """Warehouse (batch-training) replay; per-batch latency is modelled from
    *measured* byte/op counters through a calibrated remote-storage cost model
    (python constant factors would otherwise swamp the comparison):

      t = primary_bytes/BW_p + scans*overhead + lookup_bytes/BW_l
          + decoded_bytes/decode_BW
    """
    mat = sim.materializer(validate_checksum=False)
    mat.window_cache_size = 512       # DPP-worker window cache (block cache)
    worker = DPPWorker(mat, tenant, _spec_for(tenant), sim.schema)
    primary_bytes = 0
    decoded_fat = 0
    n_batches = 0
    before = sim.immutable.stats.snapshot()
    for hour in sim.warehouse.hours():
        for bucket in sim.warehouse.iter_bucketed(hour):
            for lo in range(0, len(bucket), BATCH):
                batch = bucket[lo : lo + BATCH]
                pb = sum(e.payload_bytes(sim.schema) for e in batch)
                primary_bytes += pb
                if batch[0].is_fat:
                    decoded_fat += pb            # fat rows decode their payload
                worker.process(batch)
                n_batches += 1
    d = sim.immutable.stats.delta(before)
    # bytes_decoded credits the store's stripe-decode LRU (the §4.2.3 block
    # cache, on by default) — that is part of the system under test; the Fat
    # Row path decodes its own payload per example and has nothing cacheable
    total_t = (primary_bytes / BW_PRIMARY
               + d.batched_requests * SCAN_OVERHEAD_S
               + d.bytes_scanned / BW_LOOKUP
               + (d.bytes_decoded + decoded_fat) / DECODE_BW)
    return {"latency_s": total_t / max(n_batches, 1),
            "primary_bytes": primary_bytes}


def run(quick: bool = False) -> List[BenchResult]:
    if quick:
        vlm = standard_sim("vlm", users=6, days=2, req_per_day=3)
        fat = standard_sim("fatrow", users=6, days=2, req_per_day=3)
        tenants = {"model_c": TENANTS["model_c"]}
    else:
        vlm = standard_sim("vlm")
        fat = standard_sim("fatrow")
        tenants = TENANTS

    out: List[BenchResult] = []
    write_delta = 100.0 * (vlm.stream.bytes_published
                           - fat.stream.bytes_published) / fat.stream.bytes_published
    out.append(BenchResult(
        "table1/primary_write_bandwidth", 0.0,
        {"ours_pct": round(write_delta, 1),
         "paper_pct": PAPER["write_bw_delta_pct"],
         "vlm_bytes": vlm.stream.bytes_published,
         "fat_bytes": fat.stream.bytes_published},
    ))

    for name, tenant in tenants.items():
        fat_run = _batch_replay(fat, tenant)
        vlm_run = _batch_replay(vlm, tenant)
        lk_stream = _lookup_bytes(vlm, tenant, affine=False)
        lk_batch = _lookup_bytes(vlm, tenant, affine=True)
        base_read = fat_run["primary_bytes"]
        read_delta = 100.0 * (vlm_run["primary_bytes"] - base_read) / base_read
        lat_delta = 100.0 * (vlm_run["latency_s"] - fat_run["latency_s"]) \
            / fat_run["latency_s"]
        out.append(BenchResult(
            f"table1/{name}", vlm_run["latency_s"] * 1e6,
            {
                "read_bw_pct": round(read_delta, 1),
                "paper_read_pct": PAPER[name]["read"],
                "lookup_stream_pct_of_baseline_read":
                    round(100.0 * lk_stream / base_read, 1),
                "paper_lookup_stream": PAPER[name]["lookup_stream"],
                "lookup_batch_pct_of_baseline_read":
                    round(100.0 * lk_batch / base_read, 1),
                "paper_lookup_batch": PAPER[name]["lookup_batch"],
                "latency_delta_pct": round(lat_delta, 1),
                "paper_latency_pct": PAPER[name]["latency"],
            },
        ))
    return out


if __name__ == "__main__":
    for r in run():
        print(r.csv())
