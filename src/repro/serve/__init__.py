"""Low-latency serving tier: snapshot-consistent top-k retrieval over the
live versioned store (DESIGN.md §14).

The inference half of the paper's O2O-consistency story: the same
immutable/mutable tiers, generation leases and late materialization that
training rides also answer live requests — coalesced into micro-batches,
materialized under a transient lease, encoded by the two-tower user tower,
and scored against a refreshable item-tower candidate index.
"""
from repro.serve.cache import EmbedCacheStats, UserEmbeddingCache
from repro.serve.coalescer import (
    CoalesceStats,
    PendingRequest,
    RequestCoalescer,
)
from repro.serve.index import CandidateIndex, IndexStats
from repro.serve.server import (
    RetrievalResult,
    RetrievalServer,
    ServeConfig,
    ServeStats,
)

__all__ = [
    "CandidateIndex",
    "CoalesceStats",
    "EmbedCacheStats",
    "IndexStats",
    "PendingRequest",
    "RequestCoalescer",
    "RetrievalResult",
    "RetrievalServer",
    "ServeConfig",
    "ServeStats",
    "UserEmbeddingCache",
]
