"""Launch layer: production mesh, per-family sharding rules, cell builders
(step function + input specs per arch × shape), dry-run driver, train/serve
drivers."""
