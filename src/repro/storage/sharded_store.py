"""Disaggregated immutable tier: a replicated multi-node sharded store (§4.2.3).

The paper's normalized immutable UIH tier is a horizontally sharded service;
this module splits the in-process monolith into:

  * ``StoreNode`` — one storage node, owning its resident shard tables, its
    stripe-decode LRU, its per-node ``IOStats`` and its generation/lease
    state. A node is a full ``ImmutableUIHStore`` (bulk load, planned batch
    scans over its *local* shards, leases) that happens to hold only the
    users placed on it.
  * ``ShardedUIHStore`` — the client every consumer actually talks to. It
    implements the complete ``StoreProtocol`` surface (``plan`` /
    ``execute_plan`` / ``scan`` / ``bulk_load`` / ``acquire_lease`` /
    ``estimate_scan`` / generations / introspection) by routing requests to
    nodes through a per-generation ``PlacementMap`` and executing node groups
    concurrently — one remote round-trip per node, nodes overlapped on a
    thread pool, each node further parallelizing across its local shards.

**Placement** (FlexShard-style, 2301.02959): the torso routes by symmetric
hash (``shard_of`` -> ``node_of_shard``); the heavy tail of ultra-long users
gets an explicit balanced assignment recomputed from the generation's actual
stripe bytes (``length_aware_overrides``). The resulting map is generation
metadata: the client retains the map of every live/retained generation, so a
pinned scan finds its bytes on the node where *that* generation placed them
even after a later ``rebalance()`` moved the user.

**Replication** (``replication_factor`` = r): every bulk load installs each
user's stripes on the r nodes of the user's replica chain —
``PlacementMap.replicas_of``: LPT-placed primary, then round-robin
anti-affine successors, all distinct nodes. Leases pin on every node, so any
replica can serve a pinned scan.

**Failover** (DESIGN.md §12): reads go through a health-aware executor. Each
node has a consecutive-failure ``CircuitBreaker`` (open -> probe half-open ->
close); a failed or breaker-open primary re-routes to the next live replica
(``failovers``), a whole failed node group is re-issued after seeded
deterministic backoff WITHOUT re-running its completed siblings
(``partial_reissues``), and — opt-in via ``hedge_quantile`` — a request
still in flight past the tier's latency quantile fires a speculative replica
read (``hedged_reads`` / ``hedge_wins``). Only when every replica in the
chain fails does the read raise ``NodeUnavailable`` (``degraded_scans``) —
the *retryable* class, so the DPP pool's PR 5 self-healing takes over and
output stays byte-identical once a replica returns. ``GenerationUnavailable``
still means the data is gone (remediation), but the executor first checks the
survivors: a pinned generation GC'd on a recovered node is served by the
replica that still retains it.

**Epoch barrier**: ``bulk_load`` and ``acquire_lease`` serialize on one flip
lock. A lease therefore pins the SAME generation on every node — there is no
interleaving where node 0 leases generation g while node 1 has already
flipped to g+1 — which is exactly the consistency the snapshotter's
transient lease and the streaming pin protocol (PR 3/4) assume. The lock is
never taken on the scan path: reads stay lock-free exactly like the
monolith's. A node that is down is *excluded* from the barrier rather than
blocking it: its missed loads queue for replay and its missed lease releases
park as orphans, both settled by ``recover()``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from concurrent.futures import wait as futures_wait
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import events as ev
from repro.core.backoff import Backoff
from repro.obs.registry import Histogram
from repro.storage.failover import CircuitBreaker
from repro.storage.immutable_store import (
    GenerationUnavailable,
    ImmutableUIHStore,
    IOStats,
    LeaseStats,
    ScanPlan,
    ScanRequest,
    build_scan_plan,
)
from repro.storage.protocol import NodeUnavailable  # noqa: F401  (re-export:
#   the exception is part of the storage protocol now; this module keeps the
#   historical import path alive for existing consumers)
from repro.storage.sharding import (
    PlacementMap,
    ShardRouter,
    length_aware_overrides,
)


class StoreNode(ImmutableUIHStore):
    """One node of the disaggregated immutable tier.

    Owns everything node-local: shard tables for the users placed here, the
    stripe-decode LRU, per-node ``IOStats``, and this node's generation /
    lease state. ``n_shards`` is the node's LOCAL shard count (its internal
    scan parallelism); global routing is the client's job."""

    # decorrelates the node-LOCAL shard hash from the global placement hash:
    # a node's residents all agree on shard_of(u, n_global) mod n_nodes, and
    # nested moduli of the same mix value collapse them into one local shard
    # (see ShardRouter.salt) — killing the node's internal scan parallelism
    LOCAL_SALT = 0x5DEECE66D

    def __init__(self, node_id: int, schema=None, n_shards: int = 2,
                 decode_cache_size: int = 256):
        super().__init__(schema, n_shards=n_shards,
                         decode_cache_size=decode_cache_size)
        self.router = ShardRouter(n_shards, salt=self.LOCAL_SALT)
        self.node_id = node_id

    def __repr__(self) -> str:
        return (f"StoreNode(id={self.node_id}, gen={self.generation}, "
                f"local_shards={self.n_shards})")


@dataclasses.dataclass
class NodeStats:
    """Per-node skew + health surface: who is doing the work, who holds the
    bytes, and which nodes the failover executor currently trusts.

    ``max_mean_*_ratio`` is the p-max load metric the placement policy
    optimizes: 1.0 = perfectly even, N = one node carries everything."""

    per_node: List[IOStats]          # each node's cumulative IOStats snapshot
    scan_load: List[int]             # bytes_scanned per node (read skew)
    seeks: List[int]                 # seeks per node
    decodes: List[int]               # stripes decoded per node
    stored: List[int]                # resident blob bytes per node (placement)
    max_mean_load_ratio: float       # max/mean of scan_load
    max_mean_stored_ratio: float     # max/mean of stored
    # -- health (replicated tier, DESIGN.md §12) ------------------------------
    down: List[bool] = dataclasses.field(default_factory=list)
    breaker: List[str] = dataclasses.field(default_factory=list)
    breaker_opens: List[int] = dataclasses.field(default_factory=list)
    pending_replays: List[int] = dataclasses.field(default_factory=list)

    @staticmethod
    def _ratio(values: Sequence[int]) -> float:
        mean = sum(values) / max(len(values), 1)
        return (max(values) / mean) if mean > 0 else 1.0


class ShardedGenerationLease:
    """One logical lease = one node lease on EVERY reachable node, acquired
    under the flip lock so all of them name the same generation (epoch
    barrier). Release fans back in across the survivors: a node that died
    while leased gets its release parked as an orphan and settled by
    ``recover()`` — nothing leaks either way."""

    __slots__ = ("generation", "_store", "_node_leases", "_released")

    def __init__(self, store: "ShardedUIHStore", generation: int, node_leases):
        self.generation = generation
        self._store = store
        self._node_leases = node_leases   # [(node_id, node lease), ...]
        self._released = False

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._store._release_client_lease(self.generation,
                                              self._node_leases)

    def __enter__(self) -> "ShardedGenerationLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class ShardedUIHStore:
    """Replicated multi-node client for the disaggregated immutable tier.

    Drop-in for ``ImmutableUIHStore`` everywhere the ``StoreProtocol`` is
    spoken — same plan/execute/lease surface, same ``StaleGeneration``
    remediation contract — with reads fanned out across ``n_nodes`` store
    nodes, r-way replication, and a health-aware failover executor that
    keeps reads available through node loss (see module docstring)."""

    def __init__(
        self,
        schema=None,
        n_shards: int = 8,
        n_nodes: int = 4,
        decode_cache_size: int = 256,
        placement_policy: str = "length_aware",   # "length_aware" | "hash"
        heavy_tail_fraction: float = 0.05,
        replication_factor: int = 1,
        hedge_quantile: float = 0.0,     # 0 disables hedged reads
        max_group_retries: int = 2,      # re-issues of a failed node group
        breaker_threshold: int = 3,
        breaker_reset_s: float = 0.05,
        backoff: Optional[Backoff] = None,
    ):
        if n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
        if placement_policy not in ("length_aware", "hash"):
            raise ValueError(f"unknown placement_policy {placement_policy!r}")
        if not 1 <= replication_factor <= n_nodes:
            raise ValueError(
                f"replication_factor must be in [1, n_nodes={n_nodes}], "
                f"got {replication_factor}")
        if not 0.0 <= hedge_quantile < 1.0:
            raise ValueError(
                f"hedge_quantile must be in [0, 1), got {hedge_quantile}")
        self.schema = (schema if schema is not None
                       else ev.default_schema())
        self.n_shards = n_shards
        self.n_nodes = n_nodes
        self.router = ShardRouter(n_shards)   # symmetric data-placement key
        self.placement_policy = placement_policy
        self.heavy_tail_fraction = heavy_tail_fraction
        self.replication_factor = replication_factor
        self.hedge_quantile = hedge_quantile
        self.max_group_retries = max_group_retries
        local_shards = max(1, n_shards // n_nodes)
        self.nodes: List[StoreNode] = [
            StoreNode(i, self.schema, n_shards=local_shards,
                      decode_cache_size=decode_cache_size)
            for i in range(n_nodes)
        ]
        self.generation = -1
        # epoch barrier: generation flips and lease acquisition serialize here
        # (the scan path never takes it — reads stay lock-free per node)
        self._flip_lock = threading.Lock()
        self._lease_refs: Dict[int, int] = {}     # gen -> logical lease refs
        self._lease_ls = LeaseStats()
        # placement is generation metadata: retained as long as the
        # generation is live or lease-retained anywhere
        self._live_placement = PlacementMap(n_nodes, n_shards, {},
                                            replication_factor)
        self._placements: Dict[int, PlacementMap] = {}
        self._rebalance_pending = False
        # -- health state (DESIGN.md §12) ------------------------------------
        self._down = [False] * n_nodes
        self._slow = [1.0] * n_nodes         # injected latency multipliers
        self._breakers = [CircuitBreaker(breaker_threshold, breaker_reset_s)
                          for _ in range(n_nodes)]
        # Tier-wide RTT histogram (the hedge trigger). A registry-grade
        # Histogram with a bounded exact-quantile window — same semantics
        # the old ad-hoc LatencyTracker had (None below min_samples); when a
        # Telemetry object is attached it is re-homed into the run registry
        # as ``repro_store_rtt_seconds``.
        self._latency = Histogram(window=256, min_samples=16)
        self._telemetry = None
        self._backoff = backoff or Backoff(base_s=0.002, max_s=0.05)
        # bulk loads a down node missed, replayed in order by recover()
        self._pending_loads: List[List[Tuple[int, dict]]] = [
            [] for _ in range(n_nodes)]
        # node leases whose release fanned in while the node was down
        self._orphan_leases: List[List] = [[] for _ in range(n_nodes)]
        self.rereplications = 0        # generations replayed by recover()
        self.rereplicated_bytes = 0    # stripe bytes re-pushed by recover()
        self._stats_lock = threading.Lock()
        self._client_plan_stats = IOStats()   # batched_requests/dedup/subsumed
        self._failover_stats = IOStats()      # failovers/hedges/breaker/degraded
        self._pool = ThreadPoolExecutor(
            max_workers=min(n_nodes, 16), thread_name_prefix="uih-node")
        # hedged + timed attempts run here so a group thread can wait on its
        # primary with a deadline; threads spawn lazily, so the pool is free
        # until the first hedge-eligible call
        self._hedge_pool = ThreadPoolExecutor(
            max_workers=max(4, 2 * min(n_nodes, 16)),
            thread_name_prefix="uih-hedge")

    # -- placement -----------------------------------------------------------
    def live_placement(self) -> PlacementMap:
        return self._live_placement

    def placement_for(self, generation: int) -> PlacementMap:
        """The map that generation's bulk load placed users with (live map
        for -1/unknown: an unknown pinned generation is GC'd, and its scan
        will raise ``GenerationUnavailable`` wherever it lands)."""
        if generation < 0:
            return self._live_placement
        return self._placements.get(generation, self._live_placement)

    def rebalance(self) -> Dict[int, int]:
        """Recompute heavy-tail placement at the NEXT generation flip.

        Placement is otherwise sticky across flips (daily compaction must not
        reshuffle the torso's working set); ``rebalance()`` marks the next
        ``bulk_load`` to re-derive the override map from the new generation's
        actual stripe bytes. Returns a preview computed from the LIVE tables
        so operators can see the planned moves."""
        with self._flip_lock:
            self._rebalance_pending = True
            loads = self._live_loads()
        return length_aware_overrides(
            loads, self.n_nodes, self.n_shards, self.heavy_tail_fraction)

    def _live_loads(self) -> Dict[int, int]:
        # with replication every user appears on r nodes; the uniform r-fold
        # scaling cancels in the LPT balance decisions and the mean threshold
        loads: Dict[int, int] = {}
        for node in self.nodes:
            for shard in node._shards:
                for (uid, _group), (_starts, stripes) in shard.items():
                    loads[uid] = loads.get(uid, 0) + sum(
                        len(s.blob) for s in stripes)
        return loads

    # -- node routing ---------------------------------------------------------
    def _node_of(self, user_id: int, generation: int = -1) -> int:
        return self.placement_for(generation).node_of(user_id)

    def _node_for(self, user_id: int, generation: int = -1) -> StoreNode:
        return self.nodes[self._node_of(user_id, generation)]

    # -- telemetry (DESIGN.md §13) --------------------------------------------
    @property
    def telemetry(self):
        return self._telemetry

    @telemetry.setter
    def telemetry(self, tel) -> None:
        self._telemetry = tel
        if tel is None:
            return
        # Re-home the hedge-trigger RTT histogram into the run registry and
        # point every breaker's transition listener at the event log.
        self._latency = tel.registry.histogram(
            "repro_store_rtt_seconds",
            help="per-attempt store-node round-trip time (hedge trigger)",
            window=256, min_samples=16)
        for nid, breaker in enumerate(self._breakers):
            breaker.listener = self._breaker_listener(nid)

    def _breaker_listener(self, node_id: int):
        def _on_transition(old: str, new: str) -> None:
            self._emit(f"breaker_{new}", node=node_id, prev=old)
        return _on_transition

    def _emit(self, kind: str, **fields) -> None:
        tel = self._telemetry
        if tel is not None:
            tel.events.emit(kind, **fields)

    def publish_telemetry(self) -> None:
        """Publish tier + per-node IOStats and health counters into the
        attached run registry (labels: store / node)."""
        tel = self._telemetry
        if tel is None:
            return
        tel.publish_stats(self.stats, "io", store="sharded")
        tel.publish_stats(self.lease_stats, "lease", store="sharded")
        for nid, node in enumerate(self.nodes):
            tel.publish_stats(node.stats.snapshot(), "io_node", node=nid)
        down_g = tel.registry.gauge("repro_store_node_down", labels=("node",))
        opens_c = tel.registry.counter("repro_store_breaker_opens_total",
                                       labels=("node",))
        for nid in range(self.n_nodes):
            down_g.labels(node=nid).set(1.0 if self._down[nid] else 0.0)
            opens_c.labels(node=nid).set_total(self._breakers[nid].opens)

    # -- health surface --------------------------------------------------------
    def set_node_down(self, node_id: int, down: bool = True) -> None:
        """Mark a node unreachable: its reads raise ``NodeUnavailable`` (and
        with replicas, fail over) until it returns. Marking a node back up
        goes through ``recover()`` — replaying missed loads and settling
        orphaned leases, never just flipping the flag."""
        if not down:
            self.recover(node_id)
            return
        self._down[node_id] = True
        self._emit("node_down", node=node_id)

    def set_node_slow(self, node_id: int, multiplier: float = 1.0) -> None:
        """Inject a latency multiplier on one node (the ``node_slow`` chaos
        kind): every round-trip through it is stretched by ``multiplier``.
        1.0 restores full speed. Slow responses still feed the tier's latency
        tracker, which is exactly how quantile-triggered hedging notices."""
        if multiplier < 1.0:
            raise ValueError(f"multiplier must be >= 1, got {multiplier}")
        self._slow[node_id] = float(multiplier)

    def recover(self, node_id: int) -> int:
        """Bring a node back into the tier. Re-replication bookkeeping:
        bulk loads the node missed while down are replayed in order
        (``rereplications`` / ``rereplicated_bytes``), lease releases that
        fanned in while it was dead are settled (the orphans parked by
        ``_release_client_lease``), its breaker and injected slowness reset.
        Returns the number of generations replayed."""
        with self._flip_lock:
            self._down[node_id] = False
            self._slow[node_id] = 1.0
            node = self.nodes[node_id]
            replayed = 0
            for gen, sub in self._pending_loads[node_id]:
                node.bulk_load(sub, gen)
                replayed += 1
                self.rereplicated_bytes += sum(
                    len(s.blob) for stripes in sub.values() for s in stripes)
            self._pending_loads[node_id] = []
            for lease in self._orphan_leases[node_id]:
                lease.release()
            self._orphan_leases[node_id] = []
            self._breakers[node_id].reset()
            self.rereplications += replayed
            self._gc_placements_locked()
        self._emit("node_recover", node=node_id, replayed=replayed)
        return replayed

    # -- write path -----------------------------------------------------------
    def bulk_load(self, tables, generation: int) -> None:
        """Install a generation on every replica atomically w.r.t. leases.

        Runs under the flip lock (the epoch barrier): once any node sees the
        new generation, every concurrent ``acquire_lease`` sees it on ALL
        reachable nodes. Lease-id reuse is validated client-side BEFORE any
        node installs, so a rejected load never leaves nodes on mixed
        generations. Each (user, group) table lands on the r nodes of the
        user's replica chain; every node receives the load (possibly with an
        empty subset) so generation state stays uniform across the tier. A
        down node's load queues for replay at ``recover()``."""
        with self._flip_lock:
            if generation >= 0 and self._lease_refs.get(generation, 0) > 0:
                raise ValueError(
                    f"generation id {generation} is still leased "
                    f"(refs={self._lease_refs[generation]}); ids must not be "
                    f"reused while leased")
            placement = self._placement_for_load(tables)
            node_tables: List[dict] = [{} for _ in self.nodes]
            for (user_id, group), stripes in tables.items():
                for nid in placement.replicas_of(user_id):
                    node_tables[nid][(user_id, group)] = stripes
            for nid, (node, sub) in enumerate(zip(self.nodes, node_tables)):
                if self._down[nid]:
                    self._pending_loads[nid].append((generation, sub))
                else:
                    node.bulk_load(sub, generation)
            self.generation = generation
            self._placements[generation] = placement
            self._live_placement = placement
            self._rebalance_pending = False
            self._gc_placements_locked()
        self._emit("generation_flip", store="sharded", generation=generation,
                   tables=len(tables))

    def _placement_for_load(self, tables) -> PlacementMap:
        if self.placement_policy == "hash":
            return PlacementMap(self.n_nodes, self.n_shards, {},
                                self.replication_factor)
        if self.generation >= 0 and not self._rebalance_pending:
            # sticky: reuse the live overrides until an explicit rebalance —
            # daily compaction must not migrate users as a side effect
            return PlacementMap(self.n_nodes, self.n_shards,
                                dict(self._live_placement.overrides),
                                self.replication_factor)
        loads: Dict[int, int] = {}
        for (user_id, _group), stripes in tables.items():
            loads[user_id] = loads.get(user_id, 0) + sum(
                len(s.blob) for s in stripes)
        return PlacementMap(
            self.n_nodes, self.n_shards,
            length_aware_overrides(loads, self.n_nodes, self.n_shards,
                                   self.heavy_tail_fraction),
            self.replication_factor)

    def _gc_placements_locked(self) -> None:
        for g in list(self._placements):
            if g == self.generation:
                continue
            if any(node.has_generation(g) for node in self.nodes):
                continue   # still live/retained on some replica
            if any(g == pg for pending in self._pending_loads
                   for pg, _sub in pending):
                continue   # awaiting replay on a down node
            del self._placements[g]

    # -- generation leases -----------------------------------------------------
    def acquire_lease(
        self, generation: Optional[int] = None
    ) -> ShardedGenerationLease:
        """Pin one CONSISTENT generation on every reachable node (epoch
        barrier: the flip lock orders this against ``bulk_load``, so all node
        leases name the same generation). A down node is skipped — its copy
        is settled by ``recover()`` — so pinned scans resolve on the
        survivors. Raises ``GenerationUnavailable`` — with no node lease left
        behind — if the generation is gone."""
        with self._flip_lock:
            node_leases: List[Tuple[int, object]] = []
            try:
                for nid, node in enumerate(self.nodes):
                    if self._down[nid]:
                        continue
                    node_leases.append((nid, node.acquire_lease(generation)))
            except GenerationUnavailable:
                for _nid, lease in node_leases:
                    lease.release()
                raise
            if not node_leases:
                raise NodeUnavailable(
                    "no store node reachable to acquire a generation lease")
            gen = node_leases[0][1].generation
            self._lease_refs[gen] = self._lease_refs.get(gen, 0) + 1
            self._lease_ls.acquired += 1
        self._emit("lease_acquire", store="sharded", generation=gen,
                   nodes=len(node_leases))
        return ShardedGenerationLease(self, gen, node_leases)

    def _release_client_lease(self, generation: int, node_leases) -> None:
        with self._flip_lock:
            for nid, lease in node_leases:
                if self._down[nid]:
                    # the node died while leased: park the release as an
                    # orphan — recover() settles it, so nothing leaks and the
                    # node's retained copy survives until reconciliation
                    self._orphan_leases[nid].append(lease)
                    self._lease_ls.lease_recoveries += 1
                else:
                    lease.release()
            self._lease_ls.released += 1
            refs = self._lease_refs.get(generation, 0) - 1
            if refs <= 0:
                self._lease_refs.pop(generation, None)
            else:
                self._lease_refs[generation] = refs
            self._gc_placements_locked()
        self._emit("lease_release", store="sharded", generation=generation)

    @property
    def lease_stats(self) -> LeaseStats:
        """Logical (client-level) acquire/release counts; retention/GC cycles
        are uniform across nodes, so node 0's counters stand for the tier."""
        n0 = self.nodes[0].lease_stats
        return LeaseStats(
            acquired=self._lease_ls.acquired,
            released=self._lease_ls.released,
            generations_retained=n0.generations_retained,
            generations_gc=n0.generations_gc,
            lease_recoveries=self._lease_ls.lease_recoveries,
        )

    def has_generation(self, generation: int) -> bool:
        # union over replicas: a generation is servable while ANY node still
        # holds it (a recovered node may have dropped a retained generation
        # that survivors still pin — the failover executor routes there)
        return (generation == self.generation
                or any(node.has_generation(generation)
                       for node in self.nodes))

    def leased_generations(self) -> Dict[int, int]:
        """generation -> outstanding LOGICAL lease refcount (one sharded
        lease counts once, not once per node)."""
        with self._flip_lock:
            return dict(self._lease_refs)

    def retained_generations(self) -> List[int]:
        out = set()
        for node in self.nodes:
            out.update(node.retained_generations())
        return sorted(out)

    # -- failover executor -----------------------------------------------------
    # failover-stat fields that double as control-plane timeline events
    # (breaker transitions are emitted by the breakers' own listeners, and
    # hedged_reads is volume, not an incident)
    _COUNT_EVENTS = {"failovers": "failover", "hedge_wins": "hedge_win",
                     "degraded_scans": "degraded_scan",
                     "partial_reissues": "partial_reissue"}

    def _count(self, call: Optional[IOStats], field: str, n: int = 1) -> None:
        with self._stats_lock:
            setattr(self._failover_stats, field,
                    getattr(self._failover_stats, field) + n)
            if call is not None:
                setattr(call, field, getattr(call, field) + n)
        kind = self._COUNT_EVENTS.get(field)
        if kind is not None:
            self._emit(kind)

    def _timed_op(self, op: Callable[[int], object], rep: int):
        """One attempt against one node: down check, injected slowness, and
        the round-trip feeds the tier latency tracker (the hedge trigger)."""
        if self._down[rep]:
            raise NodeUnavailable(f"store node {rep} is down")
        t0 = time.perf_counter()
        out = op(rep)
        elapsed = time.perf_counter() - t0
        mult = self._slow[rep]
        if mult > 1.0:
            extra = (mult - 1.0) * max(elapsed, 1e-3)
            time.sleep(extra)
            elapsed += extra
        self._latency.record(elapsed)
        return out

    def _hedge_deadline(self) -> Optional[float]:
        if not self.hedge_quantile:
            return None
        return self._latency.quantile(self.hedge_quantile)

    def _attempt(self, rep: int, chain: Sequence[int],
                 op: Callable[[int], object], call: Optional[IOStats]):
        """One (possibly hedged) attempt. With hedging armed, the primary
        runs with a deadline at the tier's latency quantile; past it, the
        same op fires at the next live replica and the first success wins.
        The loser's result is discarded — its physical I/O still lands in
        that node's own counters, which is the truth: hedges burn real I/O
        to buy tail latency."""
        deadline = self._hedge_deadline() if len(chain) > 1 else None
        hedge_to = None
        if deadline is not None:
            hedge_to = next((c for c in chain
                             if c != rep and not self._down[c]), None)
        if hedge_to is None:
            return self._timed_op(op, rep)
        primary_f = self._hedge_pool.submit(self._timed_op, op, rep)
        try:
            return primary_f.result(timeout=deadline)
        except FutureTimeout:
            pass   # slow, not failed: hedge it
        self._count(call, "hedged_reads")
        hedge_f = self._hedge_pool.submit(self._timed_op, op, hedge_to)
        pending = {primary_f, hedge_f}
        while pending:
            done, _ = futures_wait(pending, return_when=FIRST_COMPLETED)
            pending -= done
            for f in (primary_f, hedge_f):   # prefer the primary on a tie
                if f in done and f.exception() is None:
                    if f is hedge_f:
                        self._count(call, "hedge_wins")
                        self._breakers[hedge_to].record_success()
                    return f.result()
        raise primary_f.exception()

    def _with_failover(self, primary: int, chain: Sequence[int],
                       op: Callable[[int], object],
                       call: Optional[IOStats] = None,
                       reissue_siblings: bool = False):
        """Run ``op`` against the replica chain with health-aware retries.

        Walks the chain skipping breaker-open nodes; an I/O failure records
        on the node's breaker and falls over to the next replica
        (``failovers``). A fully-failed pass re-issues after seeded
        deterministic backoff, up to ``max_group_retries`` times — when the
        caller has sibling groups whose results are being retained, each
        re-issue counts as a ``partial_reissue``. ``GenerationUnavailable``
        never trips a breaker (the node is healthy, the data is gone) but
        the next replica is still consulted: a survivor may retain the
        generation. Exhausting the chain raises ``NodeUnavailable``
        (``degraded_scans``) if any failure was I/O, else propagates the
        data-gone error."""
        last_io: Optional[Exception] = None
        last_gen: Optional[GenerationUnavailable] = None
        for rnd in range(self.max_group_retries + 1):
            if rnd:
                if last_io is None:
                    break   # pure data-gone: retrying cannot help
                if reissue_siblings:
                    self._count(call, "partial_reissues")
                self._backoff.sleep(rnd - 1, token=primary + 1)
            attempted = False
            for rep in chain:
                breaker = self._breakers[rep]
                if not breaker.allow():
                    continue
                attempted = True
                try:
                    out = self._attempt(rep, chain, op, call)
                except GenerationUnavailable as exc:
                    last_gen = exc
                    continue
                except (NodeUnavailable, IOError) as exc:
                    if breaker.record_failure():
                        self._count(call, "breaker_opens")
                    last_io = exc
                    continue
                breaker.record_success()
                if rep != primary:
                    self._count(call, "failovers")
                return out
            if not attempted and last_io is None:
                # every breaker in the chain is open from prior calls — the
                # outage predates this read; classify it as I/O so the retry
                # rounds (whose backoff outlives breaker reset) get a probe
                last_io = NodeUnavailable(
                    f"all replica breakers open for node group {primary} "
                    f"(chain {tuple(chain)})")
        if last_io is not None:
            self._count(call, "degraded_scans")
            raise NodeUnavailable(
                f"all {len(chain)} replica(s) of node group {primary} "
                f"unavailable (chain {tuple(chain)})") from last_io
        assert last_gen is not None
        raise last_gen

    def _group_chain(self, nid: int, reqs: Sequence[ScanRequest]
                     ) -> Tuple[int, ...]:
        """Replica chain for a node group. Requests in a group share their
        primary, and replicas are uniform offsets from it, so the group
        chain is the user chain; a group mixing generations loaded at
        different replication factors uses the smallest (a replica that one
        generation never loaded to must not serve the group)."""
        gens = {q.generation for q in reqs}
        r = min((max(1, min(self.placement_for(g).replication_factor,
                            self.n_nodes)) for g in gens), default=1)
        return tuple((nid + k) % self.n_nodes for k in range(r))

    # -- read path -------------------------------------------------------------
    def _effective_traits(self, req: ScanRequest) -> Tuple[str, ...]:
        return req.traits or self.schema.group_traits(req.group)

    def scan(self, req: ScanRequest) -> ev.EventBatch:
        chain = self.placement_for(req.generation).replicas_of(req.user_id)
        return self._with_failover(
            chain[0], chain, lambda rep: self.nodes[rep].scan(req), IOStats())

    def estimate_scan(self, req: ScanRequest) -> Tuple[int, int]:
        """Metadata-only cost walk (see the monolith): routed like the scan
        would be, but served even from a down node — estimates are control
        plane, not data I/O."""
        return self._node_for(req.user_id, req.generation).estimate_scan(req)

    def plan(self, reqs: Sequence[ScanRequest]) -> ScanPlan:
        """Client-side planning: dedupe + union-projection subsumption over
        the whole batch (a request answered by an in-plan twin or carved from
        a wider root never crosses the network at all), roots grouped by
        TARGET NODE — ``ScanPlan.shard_groups`` keys are node ids here."""
        return build_scan_plan(
            reqs,
            lambda r: self._node_of(r.user_id, r.generation),
            self._effective_traits)

    def execute_plan(
        self, plan: ScanPlan, out_stats: Optional[IOStats] = None
    ) -> List[ev.EventBatch]:
        """Execute node groups concurrently: ONE batched round-trip per node
        group (the node replans its slice over its local shards and
        parallelizes there), subsumed requests carved client-side from the
        covering results. Each group runs under the failover executor, so a
        failed group re-routes to its replicas and re-issues with backoff
        WITHOUT touching its completed siblings; only if a group exhausts its
        whole chain does the call raise (``NodeUnavailable``, retryable) —
        and then no partial result is returned. Results return in original
        request order."""
        results: List[Optional[ev.EventBatch]] = [None] * len(plan.unique)
        call = IOStats()
        groups = list(plan.shard_groups.items())
        many = len(groups) > 1

        def run_group(pair) -> IOStats:
            nid, idxs = pair
            reqs = [plan.unique[j] for j in idxs]
            chain = self._group_chain(nid, reqs)

            def op(rep: int):
                # fresh stats per attempt: a failed or losing attempt must
                # not leak its partial I/O into the call's delta (the node's
                # own cumulative counters still record it — physical truth)
                local = IOStats()
                parts = self.nodes[rep].multi_range_scan(reqs, local)
                return parts, local

            parts, local = self._with_failover(nid, chain, op, call,
                                               reissue_siblings=many)
            for j, part in zip(idxs, parts):
                results[j] = part
            return local

        if not many:
            node_locals = [run_group(g) for g in groups]
        else:
            futures = [self._pool.submit(run_group, g) for g in groups]
            node_locals = []
            first_exc: Optional[BaseException] = None
            for f in futures:
                try:
                    node_locals.append(f.result())
                except BaseException as exc:   # noqa: BLE001 — re-raised below
                    if first_exc is None:
                        first_exc = exc
            if first_exc is not None:
                # no partial results: completed siblings were retained for
                # the in-plan re-issues, but the CALL fails whole
                raise first_exc
        for j, k in plan.derived.items():
            results[j] = ev.tail_view(results[k], plan.unique[j].max_events,
                                      self._effective_traits(plan.unique[j]))
        for local in node_locals:
            call.merge(local)
        # plan-level counters are the CLIENT's: nodes each count their own
        # round-trip, and dedupe/subsumption already happened up here
        call.batched_requests = 1
        call.dedup_hits = plan.dedup_hits
        call.subsumed_hits = plan.subsumed
        with self._stats_lock:
            self._client_plan_stats.batched_requests += 1
            self._client_plan_stats.dedup_hits += plan.dedup_hits
            self._client_plan_stats.subsumed_hits += plan.subsumed
        if out_stats is not None:
            out_stats.merge(call)
        return [results[j] for j in plan.assignment]

    def multi_range_scan(
        self,
        reqs: Sequence[ScanRequest],
        out_stats: Optional[IOStats] = None,
    ) -> List[ev.EventBatch]:
        return self.execute_plan(self.plan(reqs), out_stats)

    # -- stats + introspection -------------------------------------------------
    @property
    def stats(self) -> IOStats:
        """Tier-wide view: physical I/O summed over nodes (including the
        losing half of hedged reads — that I/O really happened), plan-level
        counters (batched_requests / dedup_hits / subsumed_hits) from the
        client planner, health counters (failovers / hedged_reads /
        hedge_wins / breaker_opens / degraded_scans / partial_reissues) from
        the failover executor. ``parallel_shards`` sums the nodes' local
        shard fanout — the tier's real physical scan parallelism."""
        agg = IOStats()
        for node in self.nodes:
            agg.merge(node.stats)
        with self._stats_lock:
            agg.merge(self._failover_stats)
            agg.batched_requests = self._client_plan_stats.batched_requests
            agg.dedup_hits = self._client_plan_stats.dedup_hits
            agg.subsumed_hits = self._client_plan_stats.subsumed_hits
        return agg

    def node_stats(self) -> NodeStats:
        per_node = [node.stats.snapshot() for node in self.nodes]
        scan_load = [s.bytes_scanned for s in per_node]
        stored = [node.stored_bytes() for node in self.nodes]
        return NodeStats(
            per_node=per_node,
            scan_load=scan_load,
            seeks=[s.seeks for s in per_node],
            decodes=[s.stripes_read for s in per_node],
            stored=stored,
            max_mean_load_ratio=NodeStats._ratio(scan_load),
            max_mean_stored_ratio=NodeStats._ratio(stored),
            down=list(self._down),
            breaker=[b.state for b in self._breakers],
            breaker_opens=[b.opens for b in self._breakers],
            pending_replays=[len(p) for p in self._pending_loads],
        )

    @property
    def latency_model(self):
        return self.nodes[0].latency_model

    @latency_model.setter
    def latency_model(self, model) -> None:
        # each node charges its own remote-I/O latency; node groups overlap
        # on the client pool, so a batch's wall time is the max over nodes
        for node in self.nodes:
            node.latency_model = model

    @property
    def bulk_load_bytes(self) -> int:
        return sum(node.bulk_load_bytes for node in self.nodes)

    def stored_bytes(self) -> int:
        return sum(node.stored_bytes() for node in self.nodes)

    def retained_bytes(self) -> int:
        return sum(node.retained_bytes() for node in self.nodes)

    def stored_events(self, user_id: int, group: str) -> int:
        return self._node_for(user_id).stored_events(user_id, group)

    def watermark(self, user_id: int, group: str = "core",
                  generation: int = -1) -> int:
        return self._node_for(user_id, generation).watermark(
            user_id, group, generation)

    def fanout(self, reqs: Sequence[ScanRequest]) -> int:
        """Distinct NODES a batch touches (the cross-network fanout the
        affinity planner minimizes)."""
        return len({self._node_of(r.user_id, r.generation) for r in reqs})

    def close(self) -> None:
        self._pool.shutdown(wait=True)
        self._hedge_pool.shutdown(wait=True)
        for node in self.nodes:
            node.close()

    def __enter__(self) -> "ShardedUIHStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
