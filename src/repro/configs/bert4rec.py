"""BERT4Rec [arXiv:1904.06690]: embed 64, 2 blocks, 2 heads, seq 200,
bidirectional cloze objective."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import BERT4RecConfig

FULL = BERT4RecConfig(
    name="bert4rec", embed_dim=64, n_blocks=2, n_heads=2, seq_len=200,
    item_vocab=1_000_448, loss_chunk=50,
)

SMOKE = BERT4RecConfig(
    name="bert4rec-smoke", embed_dim=16, n_blocks=2, n_heads=2, seq_len=16,
    item_vocab=300, compute_dtype=jnp.float32,
)


def spec() -> ArchSpec:
    return ArchSpec("bert4rec", "recsys", FULL, SMOKE, RECSYS_SHAPES)
