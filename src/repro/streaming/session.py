"""Streaming training session (paper §3.2): event → gradient, one object.

``StreamingSession`` closes the loop the batch pipeline leaves open: a
``StreamingSource`` (optionally fronted by a ``BackfillCoordinator`` for the
batch→stream catch-up handoff) feeds micro-batches into the existing
``DPPWorkerPool`` → ``RebatchingClient`` data plane, and the session itself
speaks the client's feed protocol (``get_full_batch`` / ``recycle`` /
``record_train_step`` / ``stats``) so a ``Trainer`` or ``DevicePrefetcher``
consumes it exactly like a batch feed.

Protocol duties handled here:

  * **lease release**: after a worker materializes+featurizes a micro-batch,
    its examples' generation leases are released (``TrainingExampleStream.ack``)
    — the store may then GC superseded generations ("GC once drained");
  * **freshness**: each example's publish wall clock rides from the stream
    through the source into a FIFO settlement queue; each
    ``record_train_step`` call (the trainer's step-completion signal, which a
    ``DevicePrefetcher`` delegates through) settles the OLDEST delivered
    batch's rows into event→gradient latency samples — correct even when the
    prefetcher pulls ``depth`` batches ahead of the gradient (FIFO
    row-matching is exact at full-batch granularity, approximate at row
    granularity under the reshuffle — documented, and irrelevant to the
    mean). A consumer that never records steps still gets all samples
    settled, late, at ``join()``.

Shutdown: close the stream; the source drains, the feeder finishes, workers
exit, the pool closes the client, the trainer sees end-of-stream. ``join()``
then surfaces any worker/feeder error.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.core.materialize import ChecksumMismatch
from repro.dpp.client import RebatchingClient
from repro.dpp.elastic import DPPWorkerPool, ElasticController
from repro.dpp.worker import DPPWorker, WorkerPlan
from repro.storage.stream import TrainingExampleStream, Warehouse
from repro.streaming.backfill import BackfillCoordinator
from repro.streaming.source import MicroBatchConfig, StreamingSource


@dataclasses.dataclass
class FreshnessStats:
    batches_delivered: int = 0
    rows_settled: int = 0
    samples: int = 0
    event_to_gradient_s_sum: float = 0.0
    event_to_gradient_s_max: float = 0.0

    @property
    def mean_event_to_gradient_s(self) -> float:
        if not self.samples:
            return 0.0
        return self.event_to_gradient_s_sum / self.samples


class _AckingWorker:
    """Wraps a ``DPPWorker``: after a micro-batch is materialized+featurized,
    release its generation leases and queue its publish clocks for freshness
    settlement. Duck-compatible with ``DPPWorkerPool`` (stats/process*).

    A ``ChecksumMismatch``/``StaleGeneration`` from the materializer is the
    protocol's *drop this example* signal (its window genuinely changed, e.g.
    right-to-delete): the worker triages the micro-batch per example, drops
    the offenders (counted in ``session.stale_dropped``, leases released),
    and featurizes the survivors — it must NOT die and take the session down.
    """

    def __init__(self, inner, session: "StreamingSession"):
        self._inner = inner
        self._session = session

    @property
    def stats(self):
        return self._inner.stats

    @property
    def materializer(self):
        return self._inner.materializer

    def process(self, examples):
        return self._process(examples, self._inner.process)

    def process_jagged(self, examples):
        return self._process(examples, self._inner.process_jagged)

    def _process(self, examples, fn):
        kept = list(examples)
        dropped_all: List = []
        while True:
            try:
                out = fn(kept) if kept else None
                break
            except ChecksumMismatch:
                kept, dropped = self._triage(kept)
                dropped_all.extend(dropped)
                if not dropped:
                    # fn raised but per-example triage passed everything: a
                    # flip landed between triage and the batch re-run. Drop
                    # the remainder rather than loop (or die) — rare double
                    # race, and dropping is always protocol-safe.
                    dropped_all.extend(kept)
                    kept = []
        self._session._on_item_done(kept, dropped=dropped_all)
        return out

    def _triage(self, examples):
        keep, dropped = [], []
        mat, projection = self._inner.materializer, self._inner.projection
        for exm in examples:
            try:
                mat.materialize(exm, projection)
                keep.append(exm)
            except ChecksumMismatch:
                dropped.append(exm)
        return keep, dropped


class StreamingSession:
    def __init__(
        self,
        stream: TrainingExampleStream,
        make_worker,
        *,
        full_batch_size: int,
        micro_batch: Optional[MicroBatchConfig] = None,
        n_workers: int = 2,
        controller: Optional[ElasticController] = None,
        shuffle_seed: Optional[int] = 0,
        buffer_batches: int = 4,
        backfill_from: Optional[Warehouse] = None,
        jagged: bool = True,
    ):
        self.source = StreamingSource(stream, micro_batch)
        mb = self.source.cfg.max_examples
        self.coordinator = (
            BackfillCoordinator(backfill_from, self.source, micro_batch=mb)
            if backfill_from is not None else None
        )
        self.client = RebatchingClient(full_batch_size,
                                       buffer_batches=buffer_batches,
                                       shuffle_seed=shuffle_seed)
        self.freshness = FreshnessStats()
        self._pub_q: Deque[float] = collections.deque()
        self._pq_lock = threading.Lock()
        self._delivered: Deque[int] = collections.deque()  # rows per pulled batch
        self._n_workers = n_workers
        if isinstance(make_worker, WorkerPlan):
            # a spec-compiled plan (declarative read path): build the
            # per-thread worker factory from it
            plan = make_worker
            make_worker = lambda: DPPWorker.from_plan(plan)  # noqa: E731
        self.pool = DPPWorkerPool(
            lambda: _AckingWorker(make_worker(), self),
            self.client, n_workers=n_workers, controller=controller,
            jagged=jagged,
        )
        self._started = False
        self._joiner: Optional[threading.Thread] = None
        self._join_error: List[BaseException] = []
        # examples dropped by stale-generation triage (window truly changed)
        self.stale_dropped = 0

    # -- lifecycle --------------------------------------------------------------
    def start(self) -> "StreamingSession":
        """Start draining. A background joiner waits out the pool so the
        client receives its end-of-stream sentinel the moment the stream
        drains — the consumer must never be the one who has to call
        ``pool.join()`` (it would deadlock waiting for batches meanwhile)."""
        if not self._started:
            self._started = True
            feed = self.coordinator or self.source
            # bound the in-flight micro-batches: backpressure keeps a fast
            # backfill replay from materializing the whole warehouse at once
            self.pool.start_stream(feed.micro_batches(),
                                   max_buffered=4 * self._n_workers + 8)

            def joiner() -> None:
                try:
                    self.pool.join()   # closes the client even on failure
                except BaseException as e:
                    self._join_error.append(e)

            self._joiner = threading.Thread(target=joiner, daemon=True,
                                            name="streaming-joiner")
            self._joiner.start()
        return self

    def join(self) -> None:
        """Wait for the drain (stream closed + queue empty) and re-raise any
        worker/feeder failure. Call only after consuming the whole stream —
        a consumer that walked away early must use ``stop()`` instead (the
        workers are blocked on the bounded client queue and need a drainer)."""
        self._settle_all()
        if self._joiner is not None:
            self._joiner.join()
        if self._join_error:
            raise self._join_error[0]

    def stop(self, timeout: Optional[float] = None) -> None:
        """Abandon training mid-stream: keep draining (and recycling) full
        batches WITHOUT training until the pipeline shuts down, then join.
        This unblocks workers parked on the bounded client queue after the
        trainer exits early (``max_wall_s`` / ``max_steps``). Termination
        still requires the producer to close the stream; ``timeout`` bounds
        the wait (on expiry the daemon threads are simply abandoned)."""
        if not self._started or self._joiner is None:
            return
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        while self._joiner.is_alive():
            if deadline is not None and time.perf_counter() > deadline:
                return
            b = self.client.get_full_batch(timeout=0.05, record=False)
            if b is not None:
                self.client.recycle(b)
        self.join()

    # -- worker-side callbacks ---------------------------------------------------
    def _on_item_done(self, examples, dropped=()) -> None:
        walls: List[float] = []
        for exm in examples:
            w = self.source.pop_pub_wall(exm.request_id)
            if w is not None:
                walls.append(w)
        if walls:
            with self._pq_lock:
                self._pub_q.extend(walls)
        self.source.ack(examples)
        if dropped:
            # stale-drop path: release leases + clocks, but contribute no
            # freshness samples (these rows never reach a gradient)
            self.stale_dropped += len(dropped)
            self.source.ack(dropped)

    # -- feed protocol (Trainer / DevicePrefetcher face) --------------------------
    @property
    def stats(self):
        return self.client.stats

    @property
    def ended(self) -> bool:
        return self.client.ended

    @property
    def drained(self) -> bool:
        """Feed-protocol drain signal: the end-of-stream sentinel reached the
        consumer (stream closed, every batch delivered)."""
        return self.client.ended

    def close(self, timeout: Optional[float] = None) -> None:
        """Feed-protocol shutdown: drain the remaining stream untrained and
        join (see ``stop``)."""
        self.stop(timeout=timeout)

    def get_full_batch(self, timeout: Optional[float] = None,
                       record: bool = True):
        self.start()
        out = self.client.get_full_batch(timeout=timeout, record=record)
        if out is not None:
            self.freshness.batches_delivered += 1
            with self._pq_lock:
                self._delivered.append(len(next(iter(out.values()))))
        return out

    def _settle_one(self) -> None:
        """Convert the oldest delivered batch's publish clocks into
        event→gradient samples (FIFO at full-batch granularity)."""
        now = time.perf_counter()
        fr = self.freshness
        with self._pq_lock:
            if not self._delivered:
                return
            rows = self._delivered.popleft()
            take = min(rows, len(self._pub_q))
            for _ in range(take):
                dt = now - self._pub_q.popleft()
                fr.event_to_gradient_s_sum += dt
                if dt > fr.event_to_gradient_s_max:
                    fr.event_to_gradient_s_max = dt
                fr.samples += 1
            fr.rows_settled += rows

    def _settle_all(self) -> None:
        while self._delivered:
            self._settle_one()

    def recycle(self, batch: Dict[str, np.ndarray]) -> None:
        self.client.recycle(batch)

    def record_train_step(self, seconds: float) -> None:
        # the trainer (directly, or via DevicePrefetcher delegation) just
        # finished a step: the oldest delivered batch's gradient is applied
        self._settle_one()
        self.client.record_train_step(seconds)

    def __iter__(self):
        while True:
            b = self.get_full_batch()
            if b is None:
                return
            yield b

    # -- introspection -----------------------------------------------------------
    def merged_worker_stats(self):
        return self.pool.merged_worker_stats()

    @property
    def backfill_stats(self):
        return self.coordinator.stats if self.coordinator is not None else None
