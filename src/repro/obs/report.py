"""Render a telemetry run directory for humans.

    python -m repro.obs.report <run_dir> [--top-k N]

Reads the artifacts written by ``Telemetry.write_run_dir`` (metrics.json,
events.jsonl, spans.jsonl) and prints:

  * the per-stage time breakdown (total/mean/p50/p95 per pipeline stage),
  * starvation attribution — what fraction of the trainer's measured
    starvation wall-time each upstream stage is responsible for,
  * the control-plane event timeline (breaker flips, failovers, worker
    restarts, generation flips, ...),
  * the top-k slowest sampled batches with their stage splits.

Everything is pure-stdlib and file-driven so it works on any run dir,
including ones produced on another machine.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.obs.spans import HOST_STAGES, critical_path

STAGE_ORDER = ("scan", "featurize", "place", "h2d", "train")


def load_run_dir(run_dir) -> Dict[str, Any]:
    root = Path(run_dir)
    if not root.is_dir():
        raise FileNotFoundError(f"not a run directory: {root}")
    metrics: Dict[str, Any] = {}
    mpath = root / "metrics.json"
    if mpath.exists():
        metrics = json.loads(mpath.read_text())
    events = _read_jsonl(root / "events.jsonl")
    spans = _read_jsonl(root / "spans.jsonl")
    summary: Dict[str, Any] = {}
    spath = root / "summary.json"
    if spath.exists():
        summary = json.loads(spath.read_text())
    return {"metrics": metrics, "events": events, "spans": spans,
            "summary": summary}


def _read_jsonl(path: Path) -> List[Dict[str, Any]]:
    if not path.exists():
        return []
    out = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if line:
            out.append(json.loads(line))
    return out


def _counter_total(metrics: Dict[str, Any], name: str) -> float:
    fam = metrics.get(name)
    if not fam:
        return 0.0
    return sum(s.get("value", 0.0) for s in fam.get("series", []))


def _span_stage_records(spans: List[Dict[str, Any]]) -> Dict[str, List[float]]:
    """stage -> list of per-record durations (item-level for host stages,
    batch-level for h2d/train)."""
    recs: Dict[str, List[float]] = {}
    for bs in spans:
        for item in bs.get("items", []):
            for name, (t0, t1) in item.get("stages", {}).items():
                recs.setdefault(name, []).append(t1 - t0)
        for name, (t0, t1) in bs.get("stages", {}).items():
            recs.setdefault(name, []).append(t1 - t0)
    return recs


def _quantile(xs: List[float], q: float) -> float:
    ordered = sorted(xs)
    idx = min(len(ordered) - 1, max(0, int(q * len(ordered))))
    return ordered[idx]


def render_stage_breakdown(spans: List[Dict[str, Any]]) -> str:
    recs = _span_stage_records(spans)
    if not recs:
        return "== per-stage breakdown ==\n(no sampled spans)"
    total_all = sum(sum(v) for v in recs.values()) or 1.0
    lines = ["== per-stage breakdown ==",
             f"{'stage':<10} {'count':>7} {'total_s':>9} {'mean_ms':>9} "
             f"{'p50_ms':>8} {'p95_ms':>8} {'share':>7}"]
    ordered = [s for s in STAGE_ORDER if s in recs]
    ordered += [s for s in sorted(recs) if s not in STAGE_ORDER]
    for name in ordered:
        xs = recs[name]
        tot = sum(xs)
        lines.append(
            f"{name:<10} {len(xs):>7} {tot:>9.3f} "
            f"{1e3 * tot / len(xs):>9.3f} {1e3 * _quantile(xs, 0.5):>8.3f} "
            f"{1e3 * _quantile(xs, 0.95):>8.3f} {100 * tot / total_all:>6.1f}%")
    return "\n".join(lines)


def render_attribution(metrics: Dict[str, Any],
                       spans: List[Dict[str, Any]]) -> str:
    recs = _span_stage_records(spans)
    stage_totals = {name: sum(xs) for name, xs in recs.items()}
    starved_time_s = _counter_total(metrics, "repro_client_starved_time_s_total")
    starved_host_s = _counter_total(metrics, "repro_client_starved_host_s_total")
    starved_h2d_s = _counter_total(metrics, "repro_client_starved_h2d_s_total")
    cp = critical_path(stage_totals, starved_host_s=starved_host_s,
                       starved_h2d_s=starved_h2d_s,
                       starved_time_s=starved_time_s)
    lines = ["== starvation attribution =="]
    if starved_time_s <= 0:
        lines.append("measured starvation: 0.000s — trainer never starved; "
                     "attributed: 100.0% (nothing to attribute)")
        return "\n".join(lines)
    lines.append(f"measured starvation: {starved_time_s:.3f}s; "
                 f"attributed: {100 * cp['attributed_frac']:.1f}%")
    att = cp["attribution_s"]
    for name in sorted(att, key=att.get, reverse=True):
        lines.append(f"  {name:<10} {att[name]:>9.3f}s "
                     f"({100 * att[name] / starved_time_s:>5.1f}% of starvation)")
    if cp["dominant_stage"]:
        lines.append(f"dominant stage: {cp['dominant_stage']}")
    return "\n".join(lines)


def render_timeline(events: List[Dict[str, Any]], limit: int = 200) -> str:
    lines = ["== event timeline =="]
    if not events:
        lines.append("(no events)")
        return "\n".join(lines)
    t0 = min(ev["t_mono"] for ev in events)
    shown = events if len(events) <= limit else events[-limit:]
    if shown is not events:
        lines.append(f"(showing last {limit} of {len(events)} events)")
    for ev in shown:
        fields = {k: v for k, v in ev.items()
                  if k not in ("seq", "t_mono", "t_wall", "kind")}
        body = " ".join(f"{k}={v}" for k, v in fields.items())
        lines.append(f"+{ev['t_mono'] - t0:>8.3f}s {ev['kind']:<20} {body}")
    counts: Dict[str, int] = {}
    for ev in events:
        counts[ev["kind"]] = counts.get(ev["kind"], 0) + 1
    tally = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    lines.append(f"event counts: {tally}")
    return "\n".join(lines)


def render_slowest(spans: List[Dict[str, Any]], top_k: int = 5) -> str:
    lines = [f"== top-{top_k} slowest batches =="]
    ranked = [bs for bs in spans if bs.get("latency_s") is not None]
    ranked.sort(key=lambda bs: bs["latency_s"], reverse=True)
    if not ranked:
        lines.append("(no delivered sampled batches)")
        return "\n".join(lines)
    for bs in ranked[:top_k]:
        stage_ms = {}
        for item in bs.get("items", []):
            for name, (t0, t1) in item.get("stages", {}).items():
                stage_ms[name] = stage_ms.get(name, 0.0) + 1e3 * (t1 - t0)
        for name, (t0, t1) in bs.get("stages", {}).items():
            stage_ms[name] = stage_ms.get(name, 0.0) + 1e3 * (t1 - t0)
        split = ", ".join(f"{k} {stage_ms[k]:.2f}ms"
                          for k in STAGE_ORDER if k in stage_ms)
        lines.append(f"batch {bs['emit_seq']:>5}  rows={bs.get('rows', '?'):>4}  "
                     f"latency={1e3 * bs['latency_s']:.2f}ms  ({split})")
    return "\n".join(lines)


def _hist_quantile(series: List[Dict[str, Any]], q: float) -> Optional[float]:
    """Interpolated quantile over the SUMMED bucket vectors of a histogram
    family's series (same semantics as ``Histogram.quantile`` without a
    window), so multi-server runs report one combined figure."""
    buckets: List[float] = []
    counts: List[int] = []
    for s in series:
        if not s.get("counts"):
            continue
        if not buckets:
            buckets, counts = list(s["buckets"]), list(s["counts"])
        elif list(s["buckets"]) == buckets:
            counts = [a + b for a, b in zip(counts, s["counts"])]
    total = sum(counts)
    if not total:
        return None
    target = max(0.0, min(1.0, q)) * total
    cum = 0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if cum + c >= target:
            lo = buckets[i - 1] if i > 0 else 0.0
            hi = buckets[i] if i < len(buckets) else buckets[-1]
            return lo + (hi - lo) * ((target - cum) / c)
        cum += c
    return buckets[-1]


def render_serve(metrics: Dict[str, Any],
                 events: List[Dict[str, Any]]) -> Optional[str]:
    """Serving-tier section (DESIGN.md §14): request-latency p50/p99 from the
    ``repro_serve_request_seconds`` histogram, embedding-cache hit rate from
    the ``repro_serve_embed_cache_*`` counters, and the last sampled top-k
    answer. Returns ``None`` when the run served no requests."""
    fam = metrics.get("repro_serve_request_seconds") or {}
    series = fam.get("series", [])
    n = sum(s.get("count", 0) for s in series)
    requests = _counter_total(metrics, "repro_serve_requests_total")
    if not n and not requests:
        return None
    lines = ["== serving tier =="]
    p50, p99 = _hist_quantile(series, 0.5), _hist_quantile(series, 0.99)
    if p50 is not None:
        lines.append(f"requests: {int(requests or n)}  "
                     f"latency p50={1e3 * p50:.3f}ms p99={1e3 * p99:.3f}ms")
    lookups = _counter_total(metrics, "repro_serve_embed_cache_lookups_total")
    hits = _counter_total(metrics, "repro_serve_embed_cache_hits_total")
    if lookups:
        inv = (_counter_total(
                   metrics, "repro_serve_embed_cache_invalidated_generation_total")
               + _counter_total(
                   metrics, "repro_serve_embed_cache_invalidated_freshness_total"))
        lines.append(f"embedding cache: {int(hits)}/{int(lookups)} hits "
                     f"({100 * hits / lookups:.1f}%), "
                     f"{int(inv)} invalidations")
    cold = _counter_total(metrics, "repro_serve_cold_requests_total")
    batches = _counter_total(metrics, "repro_serve_batches_total")
    if batches:
        lines.append(f"micro-batches: {int(batches)} "
                     f"({int(cold)} cold-path requests)")
    samples = [e for e in events if e.get("kind") == "serve_topk_sample"]
    if samples:
        s = samples[-1]
        lines.append(f"sampled top-{s.get('k')} (user {s.get('user')}, "
                     f"gen {s.get('generation')}, "
                     f"index v{s.get('index_version')}): {s.get('items')}")
    return "\n".join(lines)


def render_report(run_dir, top_k: int = 5) -> str:
    data = load_run_dir(run_dir)
    sections = [
        f"telemetry report: {Path(run_dir).resolve()}",
        render_stage_breakdown(data["spans"]),
        render_attribution(data["metrics"], data["spans"]),
        render_timeline(data["events"]),
        render_slowest(data["spans"], top_k=top_k),
    ]
    serve = render_serve(data["metrics"], data["events"])
    if serve:
        sections.append(serve)
    summary = data.get("summary") or {}
    span_counts = summary.get("spans")
    if span_counts:
        sections.append("== span lifecycle ==\n" + " ".join(
            f"{k}={v}" for k, v in span_counts.items()))
    return "\n\n".join(sections)


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a telemetry run directory (see DESIGN.md §13).")
    p.add_argument("run_dir", help="directory written by Telemetry.write_run_dir")
    p.add_argument("--top-k", type=int, default=5,
                   help="slowest batches to list (default 5)")
    args = p.parse_args(argv)
    print(render_report(args.run_dir, top_k=args.top_k))
    return 0


if __name__ == "__main__":
    sys.exit(main())
