"""Symmetric hash partitioning + length-aware node placement (paper §4.2.3).

The primary training data and the immutable UIH store use the *identical* hash
partitioning scheme with a shared partition key (user_id), so that all UIH
lookups issued while loading one data batch map to the same storage shard —
eliminating cross-shard network fanout on the high-concurrency read path.

With the store disaggregated across N nodes (``storage.sharded_store``), pure
hashing is no longer enough: ultra-long-UIH power users are orders of
magnitude heavier than the torso, and a hash that is uniform in *users* is
badly skewed in *bytes* (FlexShard, 2301.02959). Placement is therefore
two-level:

  * torso users route by hash — ``shard_of(user, n_shards)`` picks the logical
    shard, ``node_of_shard`` maps shards round-robin onto nodes;
  * the heavy tail gets an **explicit balanced assignment**: the top-loaded
    users are greedily packed (longest-first) onto the least-loaded node, and
    the resulting ``user -> node`` override map is carried as *generation
    metadata* (``PlacementMap``) so every reader — store client, DPP affinity
    planner, multi-tenant planner — routes identically, and a pinned scan on a
    retained generation still finds the bytes where that generation placed
    them, even after a later rebalance moved the user.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Tuple


def shard_of(user_id: int, n_shards: int) -> int:
    """Deterministic, stable hash partition. Shared by trainer-data placement
    and by the immutable store so sharding stays *symmetric*."""
    # splitmix64-style mix; stable across processes (unlike hash()).
    x = (user_id & 0xFFFFFFFFFFFFFFFF) * 0x9E3779B97F4A7C15 & 0xFFFFFFFFFFFFFFFF
    x ^= x >> 32
    x = x * 0xBF58476D1CE4E5B9 & 0xFFFFFFFFFFFFFFFF
    x ^= x >> 29
    return int(x % n_shards)


def node_of_shard(shard: int, n_nodes: int) -> int:
    """Default shard -> store-node mapping (round-robin): contiguous shards
    interleave across nodes so a shard-sorted scan workload spreads out."""
    return shard % n_nodes


@dataclasses.dataclass(frozen=True)
class PlacementMap:
    """User -> store-node routing for ONE immutable generation.

    Torso users resolve through the symmetric hash (``shard_of`` then
    ``node_of_shard``); ``overrides`` pins the heavy tail explicitly. The map
    is immutable and carried per generation: the sharded store retains the map
    of every leased/retained generation so pinned scans route to where that
    generation's bulk load actually put the bytes.

    **Replication** (``replication_factor`` = r): length-aware LPT placement
    decides the PRIMARY only; the r-1 replicas follow round-robin from the
    primary — ``(primary + k) % n_nodes`` for k in 1..r-1 — which is the
    anti-affinity rule: consecutive offsets are distinct nodes, so no two
    copies of a user's stripes ever share a node (for r <= n_nodes). The
    chain is part of the placement map, i.e. generation metadata: a pinned
    scan's failover targets are the replicas *that generation* loaded to."""

    n_nodes: int
    n_shards: int
    overrides: Mapping[int, int] = dataclasses.field(default_factory=dict)
    replication_factor: int = 1

    def node_of(self, user_id: int) -> int:
        node = self.overrides.get(int(user_id))
        if node is not None:
            return node
        return node_of_shard(shard_of(user_id, self.n_shards), self.n_nodes)

    def replicas_of(self, user_id: int) -> Tuple[int, ...]:
        """Ordered replica chain for a user: primary first, then the
        round-robin anti-affine successors. Readers prefer the head; the
        failover executor walks the tail."""
        primary = self.node_of(user_id)
        r = max(1, min(self.replication_factor, self.n_nodes))
        return tuple((primary + k) % self.n_nodes for k in range(r))

    def shard_of(self, user_id: int) -> int:
        return shard_of(user_id, self.n_shards)


def length_aware_overrides(
    loads: Mapping[int, int],
    n_nodes: int,
    n_shards: int,
    heavy_tail_fraction: float = 0.05,
    heavy_load_ratio: float = 2.0,
) -> Dict[int, int]:
    """FlexShard-style heavy-tail assignment: pick the ultra-long users and
    balance them explicitly instead of trusting the hash.

    ``loads`` maps user_id -> load (stripe blob bytes is the natural currency:
    it is exactly what a full-window scan reads). The heavy set is the top
    ``heavy_tail_fraction`` of users by load, restricted to users whose load
    exceeds ``heavy_load_ratio`` x the mean (a uniform population yields no
    overrides — hash placement is already balanced there). Heavy users are
    then packed longest-first onto the least-loaded node (greedy LPT), with
    each node's load seeded by the hash-routed torso it already owns.

    Deterministic: ties break on user_id, so the same loads always produce
    the same map."""
    if n_nodes <= 1 or not loads:
        return {}
    mean = sum(loads.values()) / len(loads)
    k = max(1, math.ceil(heavy_tail_fraction * len(loads)))
    ranked = sorted(loads.items(), key=lambda kv: (-kv[1], kv[0]))
    heavy = [(u, b) for u, b in ranked[:k] if b > heavy_load_ratio * mean]
    if not heavy:
        return {}
    heavy_ids = {u for u, _ in heavy}
    node_load = [0] * n_nodes
    for u, b in loads.items():
        if u not in heavy_ids:
            node_load[node_of_shard(shard_of(u, n_shards), n_nodes)] += b
    overrides: Dict[int, int] = {}
    for u, b in heavy:  # already longest-first
        target = min(range(n_nodes), key=lambda n: (node_load[n], n))
        overrides[u] = target
        node_load[target] += b
    return overrides


class ShardRouter:
    """``salt=0`` (the default) is the canonical symmetric placement —
    byte-identical to bare ``shard_of``. A non-zero salt decorrelates a
    NESTED partition from its parent: ``shard_of(u, a*b) % b == shard_of(u,
    b)`` for the same mix value, so a store node re-sharding its local slice
    of a hash-partitioned population with the unsalted hash would collapse
    every resident user into one local shard (zero local parallelism)."""

    def __init__(self, n_shards: int, salt: int = 0):
        assert n_shards >= 1
        self.n_shards = n_shards
        self.salt = salt

    def route(self, user_id: int) -> int:
        return shard_of(int(user_id) ^ self.salt, self.n_shards)

    def fanout(self, user_ids) -> int:
        """Number of distinct shards touched by a batch of lookups."""
        return len({self.route(int(u)) for u in user_ids})
