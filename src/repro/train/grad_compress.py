"""Int8 gradient compression with error feedback (distributed-optimization
trick for bandwidth-bound all-reduce at pod scale).

Each leaf is quantized to int8 with a per-leaf max-abs scale before the
(logical) all-reduce; the quantization residual is carried in an error-feedback
buffer and added to the next step's gradient, making the compression unbiased
over time (EF-SGD/1-bit-Adam family). Wire-format bytes drop 4x vs fp32.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any     # pytree like grads (fp32)


def ef_init(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, state: EFState) -> Tuple[Any, EFState]:
    """Returns (decompressed grads as the optimizer sees them, new EF state)."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq, g32 - deq

    flat = jax.tree.map(one, grads, state.residual)
    deq = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, EFState(residual=res)


def wire_bytes(grads, compressed: bool) -> int:
    """Bytes a pod-level all-reduce moves per step (for the benchmarks)."""
    total = 0
    for g in jax.tree.leaves(grads):
        n = int(jnp.size(g))
        total += n * (1 if compressed else 4) + (4 if compressed else 0)
    return total
