"""Pure-jnp oracle: jagged <-> padded-dense sequence conversion (right-aligned,
most-recent-last — the DPP featurizer contract)."""
import jax
import jax.numpy as jnp


def jagged_to_padded(values: jax.Array, offsets: jax.Array, max_len: int
                     ) -> jax.Array:
    """values: (N, D); offsets: (B+1,) int32 row starts. Returns (B, L, D)
    right-aligned, truncating each row to its most recent max_len entries."""
    b = offsets.shape[0] - 1
    d = values.shape[1]
    if values.shape[0] == 0:
        return jnp.zeros((b, max_len, d), values.dtype)
    ends = offsets[1:]                                   # (B,)
    lens = jnp.minimum(ends - offsets[:-1], max_len)     # (B,)
    # gather index for (b, j): ends[b] - L + j, masked where j < L - len
    j = jnp.arange(max_len)[None, :]                     # (1, L)
    src = ends[:, None] - max_len + j                    # (B, L)
    valid = j >= (max_len - lens[:, None])
    src = jnp.clip(src, 0, values.shape[0] - 1)
    out = values[src]                                    # (B, L, D)
    return jnp.where(valid[..., None], out, jnp.zeros((), values.dtype))


def padded_to_jagged(padded: jax.Array, offsets: jax.Array, total: int
                     ) -> jax.Array:
    """Inverse (for rows whose length <= L): scatter right-aligned rows back
    into a (total, D) jagged buffer."""
    b, l, d = padded.shape
    ends = offsets[1:]
    lens = jnp.minimum(ends - offsets[:-1], l)
    j = jnp.arange(l)[None, :]
    dst = ends[:, None] - l + j
    valid = j >= (l - lens[:, None])
    dst = jnp.where(valid, dst, total)                   # OOB drop slot
    flat_dst = dst.reshape(-1)
    flat_val = padded.reshape(-1, d)
    out = jnp.zeros((total + 1, d), padded.dtype).at[flat_dst].add(
        flat_val, mode="drop")
    return out[:total]
