"""Benchmark aggregator: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes benchmarks/results.json.

``--quick`` runs every module at a tiny smoke config (seconds, not minutes) —
the tier-1 suite drives it (tests/test_benchmarks_quick.py) so a refactor
that breaks a benchmark module fails CI instead of rotting silently. Quick
numbers are NOT meaningful measurements; results.json is only written by
full runs.
"""
from __future__ import annotations

import inspect
import json
import sys
import time
import traceback
from pathlib import Path

MODULES = [
    "benchmarks.fig2_cost_wall",
    "benchmarks.table1_system_efficiency",
    "benchmarks.bench_prefetch",
    "benchmarks.bench_affinity",
    "benchmarks.bench_scan_plan",
    "benchmarks.bench_rebatch",
    "benchmarks.bench_feed",
    "benchmarks.bench_multitenant",
    "benchmarks.bench_sharded_store",
    "benchmarks.bench_failover",
    "benchmarks.bench_streaming",
    "benchmarks.bench_chaos",
    "benchmarks.bench_serve",
    "benchmarks.bench_kernels",
    "benchmarks.bench_device_mat",
    "benchmarks.fig4_ne_scaling",
]


def run_module(modname: str, quick: bool = False, telemetry=None):
    """Import + execute one benchmark module, honoring the ``quick`` and
    ``telemetry`` knobs if its ``run`` accepts them."""
    import importlib

    mod = importlib.import_module(modname)
    params = inspect.signature(mod.run).parameters
    kw = {}
    if quick and "quick" in params:
        kw["quick"] = True
    if telemetry is not None and "telemetry" in params:
        kw["telemetry"] = telemetry
    return mod.run(**kw)


def _headline(derived: dict) -> dict:
    """The trajectory-worthy subset of a result's derived dict: throughput
    (rows/s) and tail-latency (p99) figures."""
    return {k: v for k, v in derived.items()
            if "rows_per_s" in k or "p99" in k}


def main() -> None:
    args = [a for a in sys.argv[1:]]
    quick = "--quick" in args
    if quick:
        args.remove("--quick")
    telemetry = None
    if "--telemetry" in args:
        args.remove("--telemetry")
        from repro.obs import Telemetry

        telemetry = Telemetry()
    only = args[0] if args else None
    all_results = []
    failures = []
    print("name,us_per_call,derived")
    for modname in MODULES:
        if only and only not in modname:
            continue
        t0 = time.time()
        try:
            results = run_module(modname, quick=quick, telemetry=telemetry)
        except Exception as e:
            failures.append(modname)
            print(f"{modname},ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            continue
        for r in results:
            print(r.csv(), flush=True)
            all_results.append({"name": r.name, "us_per_call": r.us_per_call,
                                "derived": r.derived})
        print(f"# {modname} done in {time.time() - t0:.1f}s", flush=True)

    if telemetry is not None:
        # export the run's metrics/spans/events for `python -m repro.obs.report`
        run_dir = Path(__file__).parent / "telemetry"
        run_dir.mkdir(exist_ok=True)
        telemetry.write_run_dir(run_dir)
        print(f"# telemetry run dir: {run_dir}", flush=True)

    # persist only complete full-mode sweeps: quick numbers are smoke-test
    # noise, and a filtered run would clobber every other module's results
    if not quick and not only:
        out = Path(__file__).parent / "results.json"
        out.write_text(json.dumps(all_results, indent=1, default=str))
        # machine-readable perf trajectory: APPEND one entry per full sweep
        # (bench name -> headline rows/s + p99 figures) so regressions are
        # diffable across commits without parsing CSV logs
        obs = Path(__file__).parent / "BENCH_OBS.json"
        try:
            traj = json.loads(obs.read_text()) if obs.exists() else []
            if not isinstance(traj, list):
                traj = []
        except (ValueError, OSError):
            traj = []
        traj.append({
            "ts": time.strftime("%Y-%m-%dT%H:%M:%S", time.gmtime()),
            "results": {r["name"]: {"us_per_call": r["us_per_call"],
                                    **_headline(r["derived"])}
                        for r in all_results},
        })
        obs.write_text(json.dumps(traj, indent=1, default=str))
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
