"""MeshGraphNet [arXiv:2010.03409]: 15 MP layers, d_hidden 128, sum
aggregator, 2-layer MLPs. d_node_in is overridden per graph shape."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import MeshGraphNetConfig

FULL = MeshGraphNetConfig(
    name="meshgraphnet", n_layers=15, d_hidden=128, mlp_layers=2,
    d_node_in=16, d_edge_in=8, d_out=3, aggregator="sum",
)

SMOKE = MeshGraphNetConfig(
    name="meshgraphnet-smoke", n_layers=3, d_hidden=16, mlp_layers=2,
    d_node_in=8, d_edge_in=4, d_out=3, aggregator="sum",
    compute_dtype=jnp.float32,
)


def spec() -> ArchSpec:
    return ArchSpec(
        "meshgraphnet", "gnn", FULL, SMOKE, GNN_SHAPES,
        notes="VLM technique not applicable (graphs are not append-only "
              "per-user sequences); uses generic DPP prefetch only.",
    )
