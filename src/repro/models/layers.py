"""Shared pure-JAX layers: RMSNorm, RoPE, qk-norm, GQA + MLA attention,
SwiGLU MLP, chunked-causal attention (flash-style memory behaviour without a
kernel — scores are never materialized at (S, S))."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * scale


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 1e6) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e6) -> jax.Array:
    """x: [..., S, H, Dh]; positions: [..., S] (int)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                     # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]                        # [..., S, 1, dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (optionally qk-normed), chunked over queries
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 1e6
    q_chunk: int = 1024   # queries per chunk: scores live at (B,H,q_chunk,S)
    unroll: bool = False  # unroll the chunk scan (calibration lowerings)
    scores_f32: bool = True  # False: keep the score pipeline in compute dtype
                             # (halves attention HBM traffic; recsys encoders)


def init_gqa(key, cfg: AttnConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": _init(ks[0], (d, h * dh)),
        "wk": _init(ks[1], (d, hk * dh)),
        "wv": _init(ks[2], (d, hk * dh)),
        "wo": _init(ks[3], (h * dh, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def _attend_chunked(
    q: jax.Array,            # (B, Sq, H, Dh)
    k: jax.Array,            # (B, Sk, Hk, Dh)  Hk divides H (GQA: no repeat
    v: jax.Array,            # (B, Sk, Hk, Dv)   materialization — grouped einsum)
    q_positions: jax.Array,  # (B, Sq)
    kv_positions: jax.Array, # (B, Sk)
    kv_mask: Optional[jax.Array],  # (B, Sk) valid mask or None
    causal: bool,
    q_chunk: int,
    unroll: bool = False,
    scores_f32: bool = True,
) -> jax.Array:
    b, sq, h, dh = q.shape
    hk = k.shape[2]
    dv = v.shape[3]
    rep = h // hk
    scale = 1.0 / np.sqrt(dh)
    qc = min(q_chunk, sq)
    n_chunks = (sq + qc - 1) // qc
    pad = n_chunks * qc - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)))
    q = q.reshape(b, n_chunks * qc, hk, rep, dh)
    qs = q.reshape(b, n_chunks, qc, hk, rep, dh).transpose(1, 0, 2, 3, 4, 5)
    qps = q_positions.reshape(b, n_chunks, qc).transpose(1, 0, 2)

    def chunk_fn(carry, inp):
        qi, qpi = inp  # (B, qc, Hk, rep, Dh), (B, qc)
        acc_dt = jnp.float32 if scores_f32 else v.dtype
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qi, k,
                       preferred_element_type=acc_dt)
        s = s * jnp.asarray(scale, acc_dt)
        if causal:
            cm = qpi[:, None, None, :, None] >= kv_positions[:, None, None, None, :]
            s = jnp.where(cm, s, -1e30)
        if kv_mask is not None:
            s = jnp.where(kv_mask[:, None, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhrqk,bkhd->bqhrd", p, v)
        return carry, o

    if n_chunks == 1:
        _, outs = chunk_fn(None, (qs[0], qps[0]))
        outs = outs[None]
    else:
        _, outs = jax.lax.scan(chunk_fn, None, (qs, qps), unroll=unroll)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, n_chunks * qc, h, dv)
    return out[:, :sq]


def _qkv(params: Params, x: jax.Array, positions: jax.Array, cfg: AttnConfig):
    b, s, _ = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(b, s, h, dh)
    k = (x @ params["wk"].astype(dt)).reshape(b, s, hk, dh)
    v = (x @ params["wv"].astype(dt)).reshape(b, s, hk, dh)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(
    params: Params,
    x: jax.Array,                       # (B, S, D)
    positions: jax.Array,               # (B, S)
    cfg: AttnConfig,
    causal: bool = True,
    kv_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Self-attention over x (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, positions, cfg)
    out = _attend_chunked(q, k, v, positions, positions, kv_mask, causal,
                          cfg.q_chunk, cfg.unroll, cfg.scores_f32)
    return out.reshape(b, s, cfg.n_heads * cfg.head_dim) @ params["wo"].astype(x.dtype)


def gqa_decode(
    params: Params,
    x: jax.Array,                # (B, 1, D) new token
    position: jax.Array,         # (B, 1) its position
    k_cache: jax.Array,          # (B, Skv, Hk, Dh) rope'd cached keys
    v_cache: jax.Array,          # (B, Skv, Hk, Dh)
    cfg: AttnConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step: insert the new token's KV at ``position`` and attend
    against the full cache. Returns (out, k_cache, v_cache) updated."""
    b, s, _ = x.shape
    assert s == 1
    q, k_new, v_new = _qkv(params, x, position, cfg)
    # write the new entry (batch-wise positions may differ -> vmap the update)
    def upd(cache, entry, pos):
        return jax.lax.dynamic_update_slice_in_dim(cache, entry, pos, axis=0)

    k_cache = jax.vmap(upd)(k_cache, k_new, position[:, 0])
    v_cache = jax.vmap(upd)(v_cache, v_new, position[:, 0])
    skv = k_cache.shape[1]
    kv_mask = jnp.arange(skv)[None, :] <= position  # (B, Skv)
    kvp = jnp.broadcast_to(jnp.arange(skv)[None, :], (b, skv))
    out = _attend_chunked(q, k_cache, v_cache, position, kvp, kv_mask, False,
                          cfg.q_chunk)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ params["wo"].astype(x.dtype)
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init(ks[0], (d_model, d_ff)),
        "w_up": _init(ks[1], (d_model, d_ff)),
        "w_down": _init(ks[2], (d_ff, d_model)),
    }


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jax.nn.silu(x @ params["w_gate"].astype(dt))
    u = x @ params["w_up"].astype(dt)
    return (g * u) @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, DeepSeek-V2) — compressed KV cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 1e4
    q_chunk: int = 1024
    unroll: bool = False


def init_mla(key, cfg: MLAConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": _init(ks[0], (d, h * qd)),
        "w_dkv": _init(ks[1], (d, cfg.kv_lora_rank)),     # compress
        "w_k_rope": _init(ks[2], (d, cfg.qk_rope_dim)),   # shared rope key
        "w_uk": _init(ks[3], (cfg.kv_lora_rank, h * cfg.qk_nope_dim)),
        "w_uv": _init(ks[4], (cfg.kv_lora_rank, h * cfg.v_head_dim)),
        "wo": _init(ks[5], (h * cfg.v_head_dim, d)),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
    }


def mla_attention_train(
    params: Params,
    x: jax.Array,              # (B, S, D)
    positions: jax.Array,      # (B, S)
    cfg: MLAConfig,
) -> jax.Array:
    """Training/prefill path: decompress K/V and run standard causal MHA."""
    b, s, d = x.shape
    h = cfg.n_heads
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_pe = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    c_kv = rms_norm(x @ params["w_dkv"].astype(dt), params["kv_norm"])  # (B,S,r)
    k_pe = apply_rope(
        (x @ params["w_k_rope"].astype(dt))[:, :, None, :], positions, cfg.rope_theta
    )  # (B,S,1,rope)
    k_nope = (c_kv @ params["w_uk"].astype(dt)).reshape(b, s, h, cfg.qk_nope_dim)
    v = (c_kv @ params["w_uv"].astype(dt)).reshape(b, s, h, cfg.v_head_dim)

    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (b, s, h, cfg.qk_rope_dim))], axis=-1)
    out = _attend_chunked(q_full, k_full, v, positions, positions, None, True,
                          cfg.q_chunk, cfg.unroll)
    return out.reshape(b, s, h * cfg.v_head_dim) @ params["wo"].astype(dt)


def mla_attention_decode(
    params: Params,
    x: jax.Array,               # (B, 1, D)
    position: jax.Array,        # (B, 1)
    c_kv_cache: jax.Array,      # (B, Skv, r) compressed latents (normed)
    k_pe_cache: jax.Array,      # (B, Skv, rope)
    kv_mask: jax.Array,         # (B, Skv)
    cfg: MLAConfig,
) -> jax.Array:
    """Decode path with the absorbed-matmul trick: score against the compressed
    latents directly; W_uk/W_uv are absorbed into the query/output sides, so the
    per-token KV-cache read is r + rope floats instead of 2*H*Dh."""
    b, s, d = x.shape
    h = cfg.n_heads
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_pe = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_pe = apply_rope(q_pe, position, cfg.rope_theta)

    w_uk = params["w_uk"].astype(dt).reshape(cfg.kv_lora_rank, h, cfg.qk_nope_dim)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)          # absorb W_uk
    s_lat = jnp.einsum("bshr,bkr->bhsk", q_lat, c_kv_cache,
                       preferred_element_type=jnp.float32)
    s_pe = jnp.einsum("bshn,bkn->bhsk", q_pe, k_pe_cache,
                      preferred_element_type=jnp.float32)
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (s_lat + s_pe) * scale
    scores = jnp.where(kv_mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(dt)
    o_lat = jnp.einsum("bhsk,bkr->bshr", p, c_kv_cache)         # (B,1,H,r)
    w_uv = params["w_uv"].astype(dt).reshape(cfg.kv_lora_rank, h, cfg.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv)             # absorb W_uv
    return out.reshape(b, s, h * cfg.v_head_dim) @ params["wo"].astype(dt)


def mla_new_cache_entries(params: Params, x: jax.Array, positions: jax.Array,
                          cfg: MLAConfig) -> Tuple[jax.Array, jax.Array]:
    """Compressed cache entries for new tokens: (c_kv, k_pe)."""
    dt = x.dtype
    c_kv = rms_norm(x @ params["w_dkv"].astype(dt), params["kv_norm"])
    k_pe = apply_rope(
        (x @ params["w_k_rope"].astype(dt))[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]
    return c_kv, k_pe
