"""Pallas TPU kernel: fused late materialization — jagged trait arena ->
dense right-aligned [B, L, T] block with in-window timestamp delta-decode.

This is the device half of the paper's §4.2 training-time reconstruction:
the host ships only the compact values arena + offsets (no [B, L] zero
padding over the wire), and the densify + decode run where the bandwidth
is. All traits of a batch share one ScatterPlan, so their clipped tails
stack as int32 columns of a single (N, T) arena (float traits ride
bit-cast — see ops.pack_arena). TPU mapping mirrors ``kernels/jagged``:
grid = (B,); each step DMAs the L-row window ending at ``offsets[b+1]``
(wrapper front-pads by L so the window is always in-bounds) from HBM into
a VMEM scratch, masks the invalid prefix, and — when the batch carries a
delta-encoded timestamp column — rebuilds absolute timestamps with an
in-VMEM cumsum plus the per-row (int32-wrapped) base before the (1, L, T)
output block is written.

The decode is the ``delta_decode`` recurrence inlined at its only training
use site: the carry never leaves the row's VMEM window, so the int32-width
hazard of the standalone kernel (see delta_decode/ops.py) cannot arise —
window-relative offsets are duration-bounded by codec construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(offsets_ref, bases_ref, values_ref, out_ref, scratch, sem, *,
            max_len, ts_col):
    b = pl.program_id(0)
    end = offsets_ref[b + 1] + max_len        # +max_len: wrapper front-pad
    start = offsets_ref[b]
    ln = jnp.minimum(end - max_len - start, max_len)
    copy = pltpu.make_async_copy(
        values_ref.at[pl.ds(end - max_len, max_len), :], scratch, sem)
    copy.start()
    copy.wait()
    j = jax.lax.broadcasted_iota(jnp.int32, scratch.shape, 0)
    valid = j >= (max_len - ln)
    win = jnp.where(valid, scratch[...], 0)
    if ts_col >= 0:
        # in-window delta decode: the first kept element's delta is 0 by
        # encoding, so the cumsum over the zero-masked window yields the
        # window-relative offset at every valid lane; adding the wrapped
        # int32 base reproduces exactly what device_put'ing the host-dense
        # int64 timestamps canonicalizes to (x64 is disabled)
        col = jax.lax.broadcasted_iota(jnp.int32, scratch.shape, 1) == ts_col
        deltas = jnp.where(col, win, 0)
        decoded = jnp.cumsum(deltas, axis=0, dtype=jnp.int32) + bases_ref[b]
        win = jnp.where(jnp.logical_and(col, valid), decoded, win)
    out_ref[0] = win


@functools.partial(jax.jit, static_argnames=("max_len", "ts_col", "interpret"))
def fused_densify_kernel(
    values_padded: jax.Array,   # (N + max_len, T) int32: front-padded arena
    offsets: jax.Array,         # (B+1,) int32
    ts_bases: jax.Array,        # (B,) int32 (zeros when ts_col < 0)
    max_len: int,
    ts_col: int = -1,
    interpret: bool = False,
) -> jax.Array:
    b = offsets.shape[0] - 1
    t = values_padded.shape[1]
    kern = functools.partial(_kernel, max_len=max_len, ts_col=ts_col)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # offsets (scalar loads)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # per-row ts bases
            pl.BlockSpec(memory_space=pl.ANY),       # stacked arena in HBM
        ],
        out_specs=pl.BlockSpec((1, max_len, t), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, max_len, t), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((max_len, t), jnp.int32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(offsets, ts_bases, values_padded)
