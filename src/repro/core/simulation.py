"""End-to-end production lifecycle simulation.

Drives the full loop of Fig. 1 / Fig. 3 over synthetic traffic:

  day d:   user events arrive -> blind-write appends into the mutable tier
  daily:   offloaded compaction consolidates history <= watermark into the
           immutable tier (bulk load), mutable tier evicts <= watermark
  online:  ranking requests fire at T_request -> snapshotter assembles UIH from
           both tiers, logs a training example (VLM: mutable slice + version
           metadata; baseline: Fat Row) -> published to the stream and ingested
           into hourly warehouse partitions

Used by the consistency tests (ground-truth inference UIH is captured at
request time) and by the Table-1/Fig-2 benchmarks (byte accounting).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import events as ev
from repro.core.materialize import Materializer
from repro.core.snapshot import (
    BaseSnapshotter,
    FatRowSnapshotter,
    SnapshotterConfig,
    VLMSnapshotter,
)
from repro.core.versioning import TrainingExample
from repro.storage.compaction import CompactionConfig, CompactionPipeline, ScrubFn
from repro.storage.immutable_store import ImmutableUIHStore
from repro.storage.mutable_store import MutableUIHStore
from repro.storage.protocol import StoreProtocol
from repro.storage.sharded_store import ShardedUIHStore
from repro.storage.stream import TrainingExampleStream, Warehouse


@dataclasses.dataclass
class SimConfig:
    stream: ev.StreamConfig = dataclasses.field(default_factory=ev.StreamConfig)
    stripe_len: int = 64
    requests_per_user_day: int = 4
    lookback_ms: int = 30 * ev.MS_PER_DAY
    n_shards: int = 8
    n_buckets: int = 8
    mode: str = "vlm"  # "vlm" | "fatrow"
    seed: int = 0
    # Bifurcated-protocol generation pinning: published stream examples hold a
    # lease on the generation their version metadata references until a
    # streaming consumer acks them (repro.streaming). Opt-in: batch-only
    # workloads never ack, so pinning would retain one superseded generation
    # per compaction cycle for the whole run.
    pin_generations: bool = False
    # Disaggregated immutable tier: 0 = in-process monolith (the default every
    # existing scenario runs on); N>0 = ShardedUIHStore client over N store
    # nodes with length-aware heavy-tail placement (DESIGN.md §11).
    n_store_nodes: int = 0
    placement_policy: str = "length_aware"  # "length_aware" | "hash"
    # Replicated tier (DESIGN.md §12): r-way replication with health-aware
    # failover; hedge_quantile > 0 arms speculative replica reads once a
    # request outlives the tier's latency quantile. Monolith sims ignore both.
    replication_factor: int = 1
    hedge_quantile: float = 0.0


class ProductionSim:
    def __init__(self, cfg: SimConfig, schema: Optional[ev.TraitSchema] = None):
        self.cfg = cfg
        self.schema = schema or ev.default_schema()
        self.events = ev.SyntheticEventStream(cfg.stream, self.schema)
        self.mutable = MutableUIHStore(self.schema)
        if cfg.n_store_nodes > 0:
            self.immutable: StoreProtocol = ShardedUIHStore(
                self.schema, n_shards=cfg.n_shards,
                n_nodes=cfg.n_store_nodes,
                placement_policy=cfg.placement_policy,
                replication_factor=cfg.replication_factor,
                hedge_quantile=cfg.hedge_quantile)
        else:
            self.immutable = ImmutableUIHStore(
                self.schema, n_shards=cfg.n_shards)
        self.compactor = CompactionPipeline(
            self.schema,
            CompactionConfig(stripe_len=cfg.stripe_len, lookback_ms=cfg.lookback_ms),
        )
        snap_cfg = SnapshotterConfig(lookback_ms=cfg.lookback_ms)
        snap_cls = VLMSnapshotter if cfg.mode == "vlm" else FatRowSnapshotter
        self.snapshotter: BaseSnapshotter = snap_cls(
            self.mutable, self.immutable, self.schema, snap_cfg
        )
        self.stream = TrainingExampleStream(
            self.schema, capacity=1 << 20,
            lease_manager=self.immutable if cfg.pin_generations else None)
        self.warehouse = Warehouse(self.schema, n_buckets=cfg.n_buckets)
        self.examples: List[TrainingExample] = []
        self.references: List[ev.EventBatch] = []  # inference-time ground truth
        self._rng = np.random.default_rng(cfg.seed)
        self.current_day = -1
        # the compaction pipeline is a singleton in production; serializing it
        # here keeps generation-id allocation race-free when stress tests run
        # extra compaction churn concurrently with the daily cycle
        self._compaction_lock = threading.Lock()
        self.compaction_watermark = -1   # monotone: never regresses
        # optional: label_fn(inference_uih, candidate, rng) -> labels dict,
        # letting benchmarks synthesize labels that depend on long history
        self.label_fn = None

    # -- lifecycle -------------------------------------------------------------
    def _source_of_truth(self, user_id: int, t_lo: int, t_hi: int) -> ev.EventBatch:
        hist = self.events.history_until(user_id, t_hi)
        return ev.time_slice(hist, t_lo, t_hi)

    def run_compaction(self, as_of_ts: int, scrub: Optional[ScrubFn] = None,
                       evict: bool = True):
        """One compaction cycle: rebuild + bulk-load a new generation, then
        (optionally) evict the consolidated prefix from the mutable tier.
        ``evict=False`` is for re-compactions at an ALREADY-evicted watermark
        (generation churn): logically a no-op eviction, skipping it avoids
        rewriting chunk lists under concurrent ingestion."""
        users = range(self.cfg.stream.n_users)
        with self._compaction_lock:
            # watermark monotonicity: a re-run (or concurrent churn cycle) at
            # a stale watermark must not REGRESS the serving watermark — the
            # mutable tier has already evicted up to the established one, so a
            # regressed generation would lose the gap for every new snapshot
            as_of_ts = max(as_of_ts, self.compaction_watermark)
            report = self.compactor.run(
                self._source_of_truth, list(users), as_of_ts, self.immutable,
                scrub=scrub
            )
            self.compaction_watermark = as_of_ts
            if evict:
                self.mutable.evict_all_until(as_of_ts)
        return report

    def ingest_day_events(self, day: int) -> None:
        """Events arrive throughout the day as blind-write appends."""
        for uid in range(self.cfg.stream.n_users):
            batch = self.events.day_events(uid, day)
            n = ev.batch_len(batch)
            if n == 0:
                continue
            # split into a few out-of-order chunks to exercise blind writes
            n_chunks = min(3, n)
            splits = np.array_split(np.arange(n), n_chunks)
            order = self._rng.permutation(n_chunks)
            for c in order:
                self.mutable.append(uid, ev.take_batch(batch, splits[c]))

    def issue_requests(self, day: int, capture_reference: bool = True) -> None:
        """Ranking requests at random times within the day; snapshot + ingest."""
        cfg = self.cfg
        # requests from different users interleave in arrival (time) order,
        # as they would on a production stream
        pairs = []
        for uid in range(cfg.stream.n_users):
            # requests arrive in SESSIONS: bursts inside the same hour (this is
            # what makes user-bucketed hourly warehouse clustering effective)
            n = cfg.requests_per_user_day
            n_sessions = max(1, min(2, n // 2))
            starts = self._rng.integers(
                day * ev.MS_PER_DAY + 1_000_000,
                (day + 1) * ev.MS_PER_DAY - 3_600_000,
                size=n_sessions,
            )
            per = int(np.ceil(n / n_sessions))
            times = []
            for st in starts:
                times.extend(
                    int(st) + int(o)
                    for o in np.sort(self._rng.integers(0, 3_500_000, size=per))
                )
            pairs.extend((t, uid) for t in times[:n])
        pairs.sort()
        for t, uid in pairs:
                candidate = {"item_id": int(self._rng.integers(0, cfg.stream.n_items))}
                if self.label_fn is not None:
                    candidate["category"] = int(
                        self.events._item_category[candidate["item_id"]])
                    # labels derive from the inference-time UIH: use the SAME
                    # fetch for labels, example, and reference (a second fetch
                    # could land on the other side of a generation flip)
                    exm, ref = self.snapshotter.snapshot_with_reference(
                        uid, t, candidate, label_ts=t + 60_000,
                        labels_fn=lambda uih: self.label_fn(
                            uih, candidate, self._rng))
                    if capture_reference:
                        self.references.append(ref)
                elif capture_reference:
                    labels = {"click": float(self._rng.random() < 0.1)}
                    # example + reference from ONE two-tier fetch: the pair is
                    # consistent even when compaction flips the generation
                    # between requests (streaming stress tests rely on this)
                    exm, ref = self.snapshotter.snapshot_with_reference(
                        uid, t, candidate, labels, label_ts=t + 60_000)
                    self.references.append(ref)
                else:
                    labels = {"click": float(self._rng.random() < 0.1)}
                    exm = self.snapshotter.snapshot(uid, t, candidate, labels,
                                                    label_ts=t + 60_000)
                self.examples.append(exm)
                self.stream.publish(exm)
        self.warehouse.ingest(self.examples[-cfg.stream.n_users * cfg.requests_per_user_day:])

    def run_day(self, day: int, capture_reference: bool = True) -> None:
        """One production day: compaction of history < day, then live traffic."""
        # daily compaction consolidates everything strictly before this day
        watermark = day * ev.MS_PER_DAY - 1
        if watermark > 0:
            self.run_compaction(watermark)
        self.ingest_day_events(day)
        self.issue_requests(day, capture_reference=capture_reference)
        self.current_day = day

    def run_days(self, n_days: int, capture_reference: bool = True) -> None:
        for d in range(n_days):
            self.run_day(d, capture_reference=capture_reference)

    # -- verification hooks ------------------------------------------------------
    def materializer(self, validate_checksum: bool = True,
                     pin_generations: bool = False) -> Materializer:
        return Materializer(
            self.immutable, self.schema, validate_checksum=validate_checksum,
            pin_generations=pin_generations,
        )
