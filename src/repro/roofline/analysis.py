"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

  compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective = link_bytes_per_chip / 50e9 B/s ICI per link

``cost_analysis()`` on a compiled SPMD executable reports per-device flops
and bytes; the collective term comes from the HLO parser. The dominant term is
the bottleneck; roofline fraction = model_flops-derived ideal time / dominant.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    link_bytes_per_chip: float
    model_flops_total: float
    collective_counts: Dict[str, int]

    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.link_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def model_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO flops — how much compiled compute is useful
        (catches remat/redundancy waste)."""
        total_hlo = self.hlo_flops_per_chip * self.chips
        return self.model_flops_total / max(total_hlo, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Ideal (useful-flops-limited) time / bound time."""
        t_ideal = self.model_flops_total / (self.chips * PEAK_FLOPS)
        return t_ideal / max(self.t_bound, 1e-30)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "link_bytes_per_chip": self.link_bytes_per_chip,
            "model_flops_total": self.model_flops_total,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_ratio": self.model_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_counts": self.collective_counts,
        }


H2D_BW = 32e9            # bytes/s host->device link (PCIe gen4 x16 class)


@dataclasses.dataclass
class MaterializationRoofline:
    """Link/HBM model for the late-materialization handover (DESIGN §3).

    Compares the two ways a [B, L] dense batch can come to exist on device:

    * **host-dense**: the host scatters the jagged arena into zero-padded
      [B, L] arrays and ships them whole — H2D bytes scale with B*L*T
      regardless of fill;
    * **device (compact)**: only the arena + offsets cross the link
      (bytes scale with the *kept* elements), and the ``kernels/fused`` op
      rebuilds the dense layout on-accelerator.

    The fused op's HBM traffic is one arena read + one dense write. A STAGED
    device pipeline (densify kernel -> HBM -> separate decode kernel) pays the
    dense intermediate twice more (write + re-read), which is the quantitative
    case for fusing decode INTO densify. Fusing the embedding lookup as well
    buys nothing for training: the dense id lanes must reach HBM for the jit'd
    step either way (the table is a trained param inside it), so the fusion
    boundary stops at decode+densify — ``t_embed_extra`` is what a fused
    embed would merely relocate, not remove.
    """

    batch: int
    seq_len: int
    n_traits: int
    arena_rows: int          # total kept elements (sum of clipped row lens)
    itemsize: int = 4        # arena lane width (int32/float32 packing)
    table_dim: int = 0       # embedding width D; 0 = no embed stage modeled

    @property
    def fill(self) -> float:
        """Occupancy of the dense layout: kept / (B * L)."""
        return self.arena_rows / max(self.batch * self.seq_len, 1)

    @property
    def dense_h2d_bytes(self) -> int:
        return self.batch * self.seq_len * self.n_traits * self.itemsize

    @property
    def compact_h2d_bytes(self) -> int:
        # arena + shared offsets + per-row lens (both int32 [B(+1)])
        return (self.arena_rows * self.n_traits * self.itemsize
                + (self.batch + 1) * 4 + self.batch * 4)

    @property
    def h2d_savings(self) -> float:
        """Fraction of link bytes the compact payload avoids."""
        return 1.0 - self.compact_h2d_bytes / max(self.dense_h2d_bytes, 1)

    @property
    def t_h2d_dense(self) -> float:
        return self.dense_h2d_bytes / H2D_BW

    @property
    def t_h2d_compact(self) -> float:
        return self.compact_h2d_bytes / H2D_BW

    @property
    def fused_hbm_bytes(self) -> int:
        """One arena read + one dense write (decode rides in VMEM for free)."""
        return (self.arena_rows * self.n_traits * self.itemsize
                + self.dense_h2d_bytes)

    @property
    def staged_hbm_bytes(self) -> int:
        """Separate densify and decode kernels: the dense intermediate is
        written, re-read, and rewritten through HBM between the stages."""
        return self.fused_hbm_bytes + 2 * self.dense_h2d_bytes

    @property
    def t_fused(self) -> float:
        return self.fused_hbm_bytes / HBM_BW

    @property
    def t_staged(self) -> float:
        return self.staged_hbm_bytes / HBM_BW

    @property
    def t_embed_extra(self) -> float:
        """HBM time a fused embed stage would RELOCATE (not remove): the id
        lane re-read plus the table-row gather, both paid identically by the
        jit'd step's own lookup."""
        if self.table_dim <= 0:
            return 0.0
        ids = self.batch * self.seq_len * self.itemsize
        rows = self.batch * self.seq_len * self.table_dim * self.itemsize
        return (ids + rows) / HBM_BW

    @property
    def t_device_path(self) -> float:
        return self.t_h2d_compact + self.t_fused

    @property
    def t_host_path(self) -> float:
        """Link time only — host scatter cost is measured, not modeled (see
        benchmarks/bench_device_mat.py)."""
        return self.t_h2d_dense

    @property
    def device_wins(self) -> bool:
        return self.t_device_path < self.t_host_path

    def to_dict(self) -> Dict[str, Any]:
        return {
            "batch": self.batch, "seq_len": self.seq_len,
            "n_traits": self.n_traits, "arena_rows": self.arena_rows,
            "fill": self.fill,
            "dense_h2d_bytes": self.dense_h2d_bytes,
            "compact_h2d_bytes": self.compact_h2d_bytes,
            "h2d_savings": self.h2d_savings,
            "t_h2d_dense_s": self.t_h2d_dense,
            "t_h2d_compact_s": self.t_h2d_compact,
            "t_fused_s": self.t_fused,
            "t_staged_s": self.t_staged,
            "t_embed_extra_s": self.t_embed_extra,
            "t_device_path_s": self.t_device_path,
            "t_host_path_s": self.t_host_path,
            "device_wins": self.device_wins,
        }


def materialization_roofline(batch: int, seq_len: int, n_traits: int,
                             arena_rows: int, itemsize: int = 4,
                             table_dim: int = 0) -> MaterializationRoofline:
    """Model the host-dense vs device-compact materialization handover for
    one batch shape (see ``MaterializationRoofline``)."""
    return MaterializationRoofline(
        batch=batch, seq_len=seq_len, n_traits=n_traits,
        arena_rows=arena_rows, itemsize=itemsize, table_dim=table_dim)


def from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                  cost: Optional[Dict[str, float]],
                  link_bytes: float, collective_counts: Dict[str, int],
                  model_flops: float) -> Roofline:
    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    nbytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=nbytes,
        link_bytes_per_chip=link_bytes,
        model_flops_total=model_flops,
        collective_counts=collective_counts,
    )
