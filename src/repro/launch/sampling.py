"""Materialize runnable inputs for a Cell (smoke tests / e2e examples).

The dry-run itself never calls this — it lowers from ShapeDtypeStructs. Smoke
tests execute reduced cells on CPU with inputs sampled here (ids bounded by the
config's vocabularies, masks non-degenerate, floats standard-normal)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import Cell
from repro.models import gnn as G
from repro.models import recsys as R
from repro.models import transformer as T
from repro.train.optimizer import adamw_init

_INIT_FNS = {
    "lm": T.init,
    "gnn": G.init,
}


def _vocab_for(name: str, cfg, meta) -> int:
    c = cfg
    table = {
        "tokens": getattr(c, "vocab", 0),
        "targets": getattr(c, "vocab", 0),
        "token": getattr(c, "vocab", 0),
        "uih_item_id": getattr(c, "item_vocab", 0),
        "cand_item_id": getattr(c, "item_vocab", 0),
        "neg_ids": getattr(c, "item_vocab", 0),
        "user_id": getattr(c, "user_vocab", 0),
        "uih_category": getattr(c, "cat_vocab", 0),
        "cand_category": getattr(c, "cat_vocab", 0),
        "sparse_ids": getattr(c, "field_vocab", 0),
        "uih_action_type": 16,
        "senders": meta.get("n_nodes", 0),
        "receivers": meta.get("n_nodes", 0),
        "position": meta.get("kv_len", 1),
    }
    return table.get(name, 0)


def _sample_leaf(name: str, leaf, cfg, meta, rng: np.random.Generator):
    shape, dtype = leaf.shape, leaf.dtype
    if name == "position":
        return jnp.zeros(shape, dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        hi = max(_vocab_for(name, cfg, meta), 2)
        return jnp.asarray(rng.integers(0, hi, size=shape), dtype)
    if dtype == jnp.bool_:
        if "mask_pos" in name:
            return jnp.asarray(rng.random(shape) < 0.2)
        return jnp.asarray(rng.random(shape) < 0.9)
    if name == "label":
        return jnp.asarray(rng.random(shape) < 0.3, dtype)
    if name == "log_q":
        return jnp.zeros(shape, dtype)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def sample_args(cell: Cell, family: str, seed: int = 0):
    """Build positional args for cell.step_fn with real (small) arrays."""
    cfg = cell.meta["cfg"]
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    out = []

    def _cast_like(params, spec_tree):
        return jax.tree.map(
            lambda p, sp: p.astype(sp.dtype) if hasattr(sp, "dtype") else p,
            params, spec_tree)

    for i, arg in enumerate(cell.args_spec):
        if i == 0:  # params
            if family == "lm":
                out.append(_cast_like(T.init(key, cfg), arg))
            elif family == "gnn":
                out.append(_cast_like(G.init(key, cfg), arg))
            else:
                init_fn = {
                    "two-tower-retrieval": R.init_two_tower,
                    "dcn-v2": R.init_dcn_v2,
                    "dien": R.init_dien,
                    "bert4rec": R.init_bert4rec,
                    "dlrm-uih": R.init_dlrm_uih,
                }[cell.arch_id]
                out.append(_cast_like(init_fn(key, cfg), arg))
            continue
        if _is_opt_state(arg):
            out.append(adamw_init(out[0]))
            continue
        if _is_kv_cache(arg):
            out.append(jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), arg))
            continue
        out.append(
            jax.tree_util.tree_map_with_path(
                lambda path, l: _sample_leaf(_leaf_name(path), l, cfg,
                                             cell.meta, rng),
                arg,
            )
        )
    return tuple(out)


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _is_opt_state(arg) -> bool:
    return hasattr(arg, "_fields") and "m" in getattr(arg, "_fields", ())


def _is_kv_cache(arg) -> bool:
    return isinstance(arg, dict) and (set(arg) == {"k", "v"}
                                      or set(arg) == {"c_kv", "k_pe"})
