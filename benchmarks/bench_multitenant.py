"""Multi-tenant co-scan benchmark (Table 1's amplification elimination).

N model tenants (the Table 1 projections: long/mid/short sequence, nested
feature groups) train over the SAME union dataset. The baseline issues one
solo scan pass per tenant; the ``MultiTenantPlanner`` computes the per-window
union projection and issues ONE co-scan, carving per-tenant views host-side.

Measured for N ∈ {1, 2, 3} tenants over the same affinity-planned replay:

  * immutable-store bytes read (``IOStats.bytes_scanned``): co-scan vs the
    sum of solo scans — the co-scan must be strictly cheaper for N >= 2;
  * stripe decodes: co-scan decodes each window's stripes once, solos decode
    them once PER TENANT (the decode LRU is disabled so the comparison is
    raw work, not cache luck);
  * materialization throughput (rows/s across all tenant outputs);
  * the planner's own ``TenantShareStats`` accounting
    (``bytes_saved_vs_solo`` must agree in sign with the measured delta).

Per-tenant outputs are asserted byte-identical (keys, dtypes, values) to the
solo path — the saving is free, not lossy.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import BenchResult, standard_sim
from repro.core.materialize import Materializer
from repro.core.projection import TenantProjection
from repro.data import MultiTenantPlanner
from repro.dpp.affinity import plan_affine

TENANTS = {  # the Table 1 evaluation tenants at benchmark scale
    "model_a": TenantProjection("model_a", seq_len=360,
                                feature_groups=("core", "engagement",
                                                "sideinfo")),
    "model_b": TenantProjection("model_b", seq_len=96,
                                feature_groups=("core", "engagement")),
    "model_c": TenantProjection("model_c", seq_len=24,
                                feature_groups=("core",),
                                traits_per_group={"core": ("timestamp",
                                                           "item_id")}),
}

BATCH = 16


def _assert_identical(co: List[dict], solo: List[dict], name: str) -> None:
    assert len(co) == len(solo), name
    for a, b in zip(co, solo):
        assert list(a.keys()) == list(b.keys()), (name, sorted(a), sorted(b))
        for k in a:
            assert a[k].dtype == b[k].dtype, (name, k)
            assert np.array_equal(a[k], b[k]), (name, k)


def run(quick: bool = False) -> List[BenchResult]:
    if quick:
        sim = standard_sim("vlm", users=6, days=2, req_per_day=3)
    else:
        sim = standard_sim("vlm")
    # raw decode accounting: every stripe read is a decode, so "stripe
    # decodes" compares WORK, not decode-LRU hit luck
    sim.immutable.decode_cache = None
    n_shards = sim.immutable.router.n_shards
    items = plan_affine(sim.examples, n_shards, BATCH).items
    n_examples = len(sim.examples)
    store = sim.immutable

    out: List[BenchResult] = []
    all_tenants = list(TENANTS.values())
    for n in range(1, len(all_tenants) + 1):
        tenants = all_tenants[:n]

        # -- solo baseline: one full scan pass per tenant -------------------
        solo_out: Dict[str, List[dict]] = {}
        before = store.stats.snapshot()
        t0 = time.perf_counter()
        for t in tenants:
            mat = Materializer(store, sim.schema)   # window cache off: raw IO
            outs: List[dict] = []
            for item in items:
                outs.extend(mat.materialize_batch(item, t))
            solo_out[t.name] = outs
        solo_s = time.perf_counter() - t0
        d_solo = store.stats.delta(before)

        # -- union co-scan: ONE pass serves every tenant --------------------
        planner = MultiTenantPlanner(tenants, store, sim.schema)
        co_out: Dict[str, List[dict]] = {t.name: [] for t in tenants}
        before = store.stats.snapshot()
        t0 = time.perf_counter()
        for item in items:
            views = planner.materialize_batch(item)
            for name, batches in views.items():
                co_out[name].extend(batches)
        co_s = time.perf_counter() - t0
        d_co = store.stats.delta(before)

        for t in tenants:  # the saving must be lossless
            _assert_identical(co_out[t.name], solo_out[t.name], t.name)

        share = planner.share_stats
        rows = n_examples * n
        out.append(BenchResult(
            f"multitenant/n{n}_tenants", co_s / max(len(items), 1) * 1e6,
            {
                "tenants": n,
                "co_bytes": d_co.bytes_scanned,
                "solo_bytes_sum": d_solo.bytes_scanned,
                "bytes_saved_pct": round(
                    100.0 * (d_solo.bytes_scanned - d_co.bytes_scanned)
                    / max(d_solo.bytes_scanned, 1), 1),
                "co_stripe_decodes": d_co.stripes_read,
                "solo_stripe_decodes": d_solo.stripes_read,
                "co_rows_per_s": round(rows / max(co_s, 1e-9)),
                "solo_rows_per_s": round(rows / max(solo_s, 1e-9)),
                "share_bytes_saved_vs_solo": share.bytes_saved_vs_solo,
                "share_union_overfetch": share.union_overfetch_bytes,
                "co_scan_windows": share.co_scan_windows,
                "outputs_identical": True,   # asserted above
            },
        ))
    return out


if __name__ == "__main__":
    for r in run():
        print(r.csv())
