"""Multi-tenant co-scan planning (paper §2.3, §4.2.2, Table 1).

One normalized immutable UIH tier serves a *union* of heterogeneous model
tenants. Given N ``DatasetSpec``s (or bare ``TenantProjection``s) over the
same store, ``MultiTenantPlanner`` computes the per-window union projection
(max ``seq_len``, union of feature groups / trait columns), issues ONE
planned co-scan through the store's ``plan()``/``execute_plan()`` machinery
(via ``Materializer.materialize_multi``), and carves each tenant's view back
out host-side (tail-slice to its ``seq_len`` + trait projection) —
byte-identical to what that tenant's solo ``materialize_batch`` would have
produced, at a fraction of the read amplification.

``TenantShareStats`` quantifies the win per co-scanned window:
``bytes_saved_vs_solo`` (Σ solo-scan bytes − union-scan bytes) and
``union_overfetch_bytes`` (union bytes beyond the widest single tenant) —
the counters behind Table 1's multi-tenant amplification elimination.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Union

from repro.core import events as ev
from repro.core.materialize import Materializer, TenantShareStats
from repro.core.projection import TenantProjection
from repro.data.spec import DatasetSpec
from repro.dpp.affinity import AffinityPlan, plan_affine
from repro.storage.protocol import StoreProtocol


class MultiTenantPlanner:
    """Co-scan N tenants' reads over one store.

    ``specs`` may mix ``DatasetSpec``s and bare ``TenantProjection``s; when
    ``DatasetSpec``s are given they must agree on consistency and generation
    policy (one co-scan can only run one policy). Tenant names must be unique
    — they key the per-tenant outputs.
    """

    def __init__(
        self,
        specs: Sequence[Union[DatasetSpec, TenantProjection]],
        store: StoreProtocol,
        schema: ev.TraitSchema,
        *,
        window_cache_size: int = 0,
    ):
        if not specs:
            raise ValueError("MultiTenantPlanner needs at least one spec")
        tenants: List[TenantProjection] = []
        ds = [s for s in specs if isinstance(s, DatasetSpec)]
        for s in specs:
            tenants.append(s.tenant if isinstance(s, DatasetSpec) else s)
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"tenant names must be unique, got {names}")
        if ds:
            pol = {(s.consistency, s.generations) for s in ds}
            if len(pol) != 1:
                raise ValueError(
                    f"co-scanned specs must share consistency/generation "
                    f"policy, got {sorted(pol)}")
        validate = ds[0].validate_checksum if ds else False
        pin = ds[0].pin_generations if ds else False
        self.tenants = tenants
        self.schema = schema
        self.store = store
        self.union = (tenants[0] if len(tenants) == 1
                      else TenantProjection.union(tenants, schema))
        self.materializer = Materializer(
            store, schema, validate_checksum=validate, pin_generations=pin,
            window_cache_size=window_cache_size)
        self.share_stats = TenantShareStats()

    # -- co-scan ---------------------------------------------------------------
    def materialize_batch(
        self, examples: Sequence[Any]
    ) -> Dict[str, List[ev.EventBatch]]:
        """ONE union co-scan for the batch's windows, carved per tenant.

        Returns ``{tenant_name: [per-example EventBatch]}``; each tenant's
        list is byte-identical to its solo ``materialize_batch`` output."""
        return self.materializer.materialize_multi(
            examples, self.tenants, share_stats=self.share_stats,
            union=self.union)

    # -- work planning ---------------------------------------------------------
    def plan_items(
        self, examples: Sequence[Any], base_batch_size: int
    ) -> AffinityPlan:
        """Affinity-plan a co-scanned epoch against THIS planner's store:
        items are clustered by the store's routing — shard on the monolith,
        (node, shard) under the live placement map on the sharded store — so
        every co-scan work item stays node-local (zero cross-node fanout)."""
        return plan_affine(
            examples, self.store.n_shards, base_batch_size,
            placement=self.store.live_placement())

    # -- introspection ---------------------------------------------------------
    @property
    def io_stats(self):
        """This planner's store traffic (the materializer-local accumulator)."""
        return self.materializer.io_stats

    @property
    def stats(self):
        return self.materializer.stats
