"""Optimizer, checkpoint manager (atomicity, keep-k, checksum, resume),
elastic reshard, gradient compression, microbatch accumulation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.train.grad_compress import (
    compress_with_feedback,
    ef_init,
    quantize_int8,
    dequantize_int8,
    wire_bytes,
)
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    lr_schedule,
    make_train_step,
)
from repro.train.train_loop import Trainer, TrainerConfig


def _quad_problem(seed=0):
    """Simple convex problem: params -> || W x - y ||^2."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    w_true = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    y = x @ w_true

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    return loss_fn, params, {"x": x, "y": y}


def test_adamw_converges():
    loss_fn, params, batch = _quad_problem()
    cfg = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=300,
                      weight_decay=0.0)
    step = jax.jit(make_train_step(loss_fn, cfg))
    opt = adamw_init(params)
    losses = []
    for _ in range(300):
        params, opt, stats = step(params, opt, batch)
        losses.append(float(stats["loss"]))
    assert losses[-1] < 0.01 * losses[0]


def test_lr_schedule_warmup_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_schedule(jnp.asarray(5), cfg)) == pytest.approx(0.5)
    assert float(lr_schedule(jnp.asarray(10), cfg)) == pytest.approx(1.0)
    assert float(lr_schedule(jnp.asarray(100), cfg)) == pytest.approx(0.1, abs=1e-3)


def test_grad_clip_bounds_update():
    loss_fn, params, batch = _quad_problem()
    cfg = AdamWConfig(lr=1e-3, grad_clip=0.5, warmup_steps=0)
    g = jax.grad(lambda p: loss_fn(p, batch))(params)
    _, _, stats = adamw_update(params, g, adamw_init(params), cfg)
    assert float(stats["grad_norm"]) > 0


# -- checkpointing ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(10, dtype=jnp.float32),
             "nested": {"b": jnp.ones((3, 3), jnp.bfloat16)}}
    mgr.save(5, state, extra={"note": "hi"})
    restored, step, extra = mgr.restore(state)
    assert step == 5 and extra["note"] == "hi"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.zeros(4)}
    for s in [1, 2, 3, 4]:
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(100, dtype=jnp.float32)}
    path = mgr.save(1, state)
    # corrupt the arrays file
    f = path / "arrays.npz"
    data = bytearray(f.read_bytes())
    data[len(data) // 2] ^= 0xFF
    f.write_bytes(bytes(data))
    with pytest.raises(Exception):
        mgr.restore(state)


def test_checkpoint_ignores_partial_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"a": jnp.zeros(4)}
    mgr.save(1, state)
    # simulate a crash mid-save: tmp dir left behind
    (tmp_path / "tmp.step_000000002").mkdir()
    assert mgr.latest_step() == 1


def test_checkpoint_restore_skips_leftover_tmp(tmp_path):
    """A crash mid-save leaves tmp.step_N behind: restore (not just
    latest_step) must resume from the newest COMPLETE checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"a": jnp.arange(4, dtype=jnp.float32)}
    mgr.save(1, state)
    tmp = tmp_path / "tmp.step_000000002"
    tmp.mkdir()
    (tmp / "meta.json").write_text("{}")   # even a meta-bearing tmp is skipped
    assert mgr.latest_step() == 1
    restored, step, _ = mgr.restore(state)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))


def test_checkpoint_corrupt_latest_falls_back_to_previous(tmp_path):
    """A bit-flipped leaf in the NEWEST checkpoint fails the checksum;
    restore-from-latest falls back to the previous complete checkpoint. An
    explicitly requested step never falls back."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"a": jnp.arange(100, dtype=jnp.float32)})
    mgr.save(2, {"a": jnp.arange(100, dtype=jnp.float32) * 2})
    f = tmp_path / "step_000000002" / "arrays.npz"
    data = bytearray(f.read_bytes())
    data[len(data) // 2] ^= 0xFF
    f.write_bytes(bytes(data))
    template = {"a": jnp.zeros(100)}
    restored, step, _ = mgr.restore(template)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(100, dtype=np.float32))
    with pytest.raises(Exception):
        mgr.restore(template, step=2)   # named step: no silent fallback


def test_checkpoint_gc_never_removes_newest(tmp_path):
    state = {"a": jnp.zeros(4)}
    mgr = CheckpointManager(str(tmp_path), keep=1)
    for s in [1, 2, 3]:
        mgr.save(s, state)
        assert mgr.all_steps() == [s]   # newest survives every GC pass
    mgr0 = CheckpointManager(str(tmp_path / "nogc"), keep=0)
    for s in [1, 2]:
        mgr0.save(s, state)
    assert mgr0.all_steps() == [1, 2]   # keep=0 disables GC entirely


def test_checkpoint_feed_state_sidecar_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.zeros(4)}
    mgr.save(3, state, feed_state={"kind": "batch", "trained_rows": 24})
    mgr.save(5, state)   # no sidecar on this one
    assert mgr.feed_state(3) == {"kind": "batch", "trained_rows": 24}
    assert mgr.feed_state(5) is None
    assert mgr.feed_state() is None      # latest (5) has no sidecar
    assert CheckpointManager(str(tmp_path)).feed_state(3) is not None


def test_elastic_reshard_to_new_mesh(tmp_path):
    """Save under one sharding, restore under a different mesh layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state)
    mesh = jax.make_mesh((1,), ("model",))
    sh = {"w": NamedSharding(mesh, P("model", None))}
    restored, _, _ = mgr.restore(state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding.spec == P("model", None)


# -- gradient compression ----------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_preserves_convergence():
    loss_fn, params, batch = _quad_problem()
    cfg = AdamWConfig(lr=0.05, warmup_steps=0, weight_decay=0.0)
    opt = adamw_init(params)
    ef = ef_init(params)
    for _ in range(300):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch))(params)
        grads, ef = compress_with_feedback(grads, ef)
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(loss) < 0.02


def test_wire_bytes_4x_reduction():
    g = {"a": jnp.zeros((1000,)), "b": jnp.zeros((50, 50))}
    assert wire_bytes(g, compressed=False) == 4 * (1000 + 2500)
    assert wire_bytes(g, compressed=True) < 0.3 * wire_bytes(g, False)


# -- trainer integration -------------------------------------------------------------

def test_trainer_accum_matches_large_batch():
    """grad_accum=4 on batch B == one step on full batch (same grads)."""
    loss_fn, params, batch = _quad_problem()
    t1 = Trainer(loss_fn, params, TrainerConfig(
        opt=AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0,
                        grad_clip=0.0), grad_accum=1))
    t4 = Trainer(loss_fn, params, TrainerConfig(
        opt=AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0,
                        grad_clip=0.0), grad_accum=4))
    t1.run_step(batch)
    t4.run_step(batch)
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_trainer_resume_from_checkpoint(tmp_path):
    loss_fn, params, batch = _quad_problem()
    cfg = TrainerConfig(opt=AdamWConfig(lr=0.05, warmup_steps=0),
                        ckpt_dir=str(tmp_path), ckpt_every=5)
    t = Trainer(loss_fn, params, cfg)
    for _ in range(7):
        t.run_step(batch)
    # crash + restart
    t2 = Trainer(loss_fn, params, cfg)
    assert t2.try_resume()
    assert t2.step == 5
    np.testing.assert_array_equal(np.asarray(t2.opt_state.step), 5)
    t2.run_step(batch)  # continues fine
    assert t2.step == 6
