"""Training-time versioned late materialization ("Time-Travel", paper §3.3).

Given a logged training example, the materializer:
  1. extracts the version metadata + the snapshotted mutable slice;
  2. issues a bounded multi-range scan against the immutable store using the
     logged temporal boundaries, with the tenant's projection pushed down
     (sequence-length / feature-group / trait);
  3. concatenates immutable + mutable components into the complete UIH that
     exactly reproduces the inference-time state;
  4. optionally validates the checksum logged at inference time.

The logic depends only on the logged metadata, never on the training paradigm,
so streaming and batch training share it unchanged (§3.2).

**Stale-generation remediation** (bifurcated protocol, §3.2): an example may
reference an immutable generation that daily compaction has since superseded.
Resolution is layered:

  1. *pinned* (``pin_generations=True``, the streaming path): if the example's
     generation is still retained by a ``GenerationLease``, scan it directly —
     byte-exact reproduction even if the new generation scrubbed history;
  2. *re-resolve*: otherwise scan the LIVE generation with the version's
     ``end_ts`` clamp (compaction rebuilds the full lookback window, so the
     clamped scan reproduces the window and can never admit post-request
     events) and **revalidate the checksum** — in pinning mode this
     revalidation is mandatory for stale windows regardless of
     ``validate_checksum``;
  3. a revalidation mismatch on a stale window raises ``StaleGeneration``
     (a ``ChecksumMismatch`` subclass) in strict mode — the window genuinely
     changed (e.g. right-to-delete scrub) and the example must be dropped,
     not silently trained on drifted history.

Batch materialization is *planned* (§4.1.2, §4.2.3): ``materialize_batch``
groups the batch's examples by *window key* — ``(user_id, end_ts, seq_len,
checksum, generation, projection)`` pins the immutable window's exact content
even when per-request lookback ``start_ts`` differs — canonicalizes each
group's scan bounds, and issues ONE ``multi_range_scan`` covering every
example × feature group. The store's planner dedupes the canonicalized
duplicates (surfaced as ``IOStats.dedup_hits``), executes shard groups in
parallel, and decodes each stripe at most once; the materializer then
reassembles per-example UIHs from the shared windows. A true-LRU window cache
(hits promoted) persists windows ACROSS batches, the DPP-worker analogue of
the store-side block cache — all of a user's same-day requests share one
immutable window, so streaming and user-bucketed batch jobs both hit heavily.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import events as ev
from repro.core.projection import TenantProjection, project_view
from repro.core.versioning import TrainingExample, window_checksum
from repro.storage.immutable_store import (
    GenerationUnavailable,
    IOStats,
    ScanRequest,
)
from repro.storage.protocol import StoreProtocol


def _projection_fingerprint(projection: Optional[TenantProjection]):
    """Hashable identity of a projection's *content* for window-cache keys.

    The cache persists across batches, so ``id(projection)`` is unsafe: a
    garbage-collected projection's id can be reused by a different one and
    serve a stale window. TenantProjection itself may hold a dict
    (``traits_per_group``), so it is not reliably hashable — fingerprint the
    fields that affect the fetched window instead."""
    if projection is None:
        return None
    tp = projection.traits_per_group
    return (
        projection.seq_len,
        tuple(projection.feature_groups),
        tuple(sorted((g, tuple(ts)) for g, ts in tp.items())) if tp else None,
    )


class ChecksumMismatch(RuntimeError):
    pass


class StaleGeneration(ChecksumMismatch):
    """The example references a superseded immutable generation whose window is
    no longer reconstructible from the live generation (e.g. right-to-delete
    scrubs changed the event set) and is no longer lease-retained."""


@dataclasses.dataclass
class TenantShareStats:
    """Multi-tenant co-scan amplification accounting (§2.3, Table 1).

    Byte figures are *metadata-exact estimates* (``ImmutableUIHStore.
    estimate_scan`` walks the same stripe selection the scan executes, so
    they match ``IOStats.bytes_scanned`` for a stable generation) — computed
    per co-scanned window against what each tenant's solo scan would have
    read. Accounting is pinned to the generation actually scanned: live
    (gen=-1) fetches record the generation id that was live during the scan
    and estimate against it, so a compaction flip racing fetch and estimate
    cannot attribute the new generation's stripes to this window — if the
    scanned generation has since been dropped (no retaining lease), that
    window skips accounting rather than guessing."""

    co_scans: int = 0                # materialize_multi calls that hit the store
    co_scan_windows: int = 0         # unique windows fetched ONCE for N tenants
    union_bytes_est: int = 0         # blob bytes the union co-scan reads
    solo_bytes_est: int = 0          # Σ blob bytes the per-tenant solo scans would read
    bytes_saved_vs_solo: int = 0     # solo_bytes_est - union_bytes_est (signed)
    union_overfetch_bytes: int = 0   # union bytes beyond the WIDEST single tenant


@dataclasses.dataclass
class MaterializeStats:
    examples: int = 0
    checksum_validated: int = 0
    checksum_failures: int = 0
    immutable_events: int = 0
    mutable_events: int = 0
    window_cache_hits: int = 0   # cross-batch LRU hits (no store round-trip)
    windows_fetched: int = 0     # unique windows fetched from the store
    # stale-generation remediation (bifurcated protocol)
    pinned_windows: int = 0      # served byte-exact from a lease-retained gen
    stale_reresolved: int = 0    # stale windows re-resolved against the live gen
    stale_failures: int = 0      # re-resolved windows whose checksum mismatched
    pin_misses: int = 0          # pinning requested but the gen was already GC'd


class Materializer:
    def __init__(
        self,
        immutable: StoreProtocol,
        schema: ev.TraitSchema,
        validate_checksum: bool = False,
        strict: bool = True,
        window_cache_size: int = 0,
        pin_generations: bool = False,
    ):
        self.immutable = immutable
        self.schema = schema
        self.validate_checksum = validate_checksum
        self.strict = strict
        # Streaming-protocol mode: scan the example's logged generation while a
        # lease retains it (byte-exact); stale windows that must fall back to
        # the live generation are ALWAYS checksum-revalidated.
        self.pin_generations = pin_generations
        self.stats = MaterializeStats()
        # THIS materializer's store traffic. The store's own ``stats`` is
        # shared by every client, so concurrent workers cannot attribute
        # snapshot/delta windows of it to their own lookups; the store
        # accumulates each call's delta here instead.
        self.io_stats = IOStats()
        # True-LRU cache of immutable windows persisting ACROSS batches (the
        # DPP worker analogue of the store-side block cache, §4.2.3): hits are
        # promoted, so a hot user's window survives colder evictions.
        self.window_cache_size = window_cache_size
        self._window_cache: "OrderedDict" = OrderedDict()
        # The LRU is shared by concurrent callers (the serving tier issues
        # materializations from request threads); the promote-on-hit
        # move_to_end / evicting popitem pair corrupts an OrderedDict when
        # interleaved, so both cache ops take this lock. ``stats`` counters
        # remain unsynchronized — they are best-effort telemetry, and a lost
        # increment under contention is harmless where a corrupted cache is
        # not.
        self._cache_lock = threading.Lock()

    # -- single example -------------------------------------------------------
    def materialize(
        self,
        example: TrainingExample,
        projection: Optional[TenantProjection] = None,
    ) -> ev.EventBatch:
        if example.is_fat:
            # Fat Row path: UIH is already materialized; apply projection only.
            return self._project_fat(example, projection)

        assert example.version is not None, "VLM example missing version metadata"
        mutable_part = example.mutable_uih or ev.empty_batch(self.schema)
        immutable_part = self._fetch_immutable(example, projection)
        out = self._concat_and_project(immutable_part, mutable_part, projection)
        self.stats.examples += 1
        self.stats.immutable_events += ev.batch_len(immutable_part)
        self.stats.mutable_events += ev.batch_len(mutable_part)
        return out

    def materialize_batch(
        self,
        examples: Sequence[TrainingExample],
        projection: Optional[TenantProjection] = None,
    ) -> List[ev.EventBatch]:
        """Planned batch path with **data-affinity amortization** (§4.2.3).

        Examples are grouped by window key (same watermark + length + checksum
        => identical immutable event set, even when the lookback ``start_ts``
        differs slightly between adjacent requests). Each group's scan bounds
        are canonicalized to its first example's, and ONE ``multi_range_scan``
        covering every example × feature group goes to the store, whose planner
        dedupes the duplicates and executes shard groups in parallel. Windows
        are then reassembled per example.
        """
        out: List[Optional[ev.EventBatch]] = [None] * len(examples)
        # 1) group VLM examples by window key (batch-local dedupe scope)
        members: "OrderedDict[tuple, List[int]]" = OrderedDict()
        for i, ex in enumerate(examples):
            if ex.is_fat or ex.version is None:
                out[i] = self.materialize(ex, projection)
                continue
            members.setdefault(self._window_key(ex, projection), []).append(i)

        windows, _, _ = self._resolve_windows(members, examples, projection)

        # reassemble per-example UIHs from the shared windows
        for key, idxs in members.items():
            imm = windows[key]
            for i in idxs:
                ex = examples[i]
                mutable_part = ex.mutable_uih or ev.empty_batch(self.schema)
                out[i] = self._concat_and_project(imm, mutable_part, projection)
                self.stats.examples += 1
                self.stats.immutable_events += ev.batch_len(imm)
                self.stats.mutable_events += ev.batch_len(mutable_part)
        return out  # type: ignore[return-value]

    def materialize_multi(
        self,
        examples: Sequence[TrainingExample],
        projections: Sequence[TenantProjection],
        share_stats: Optional[TenantShareStats] = None,
        union: Optional[TenantProjection] = None,
    ) -> Dict[str, List[ev.EventBatch]]:
        """Co-scan materialization for N tenants over ONE window fetch (§2.3,
        §4.2.2): the batch's windows are fetched under the tenants' *union*
        projection (max ``seq_len``, union of feature groups / traits) in one
        planned store round-trip, then each tenant's view is carved host-side
        (``project_view``: tail-slice to its ``seq_len`` + trait projection) —
        byte-identical to that tenant's solo ``materialize_batch`` output.

        ``share_stats`` (optional) accumulates the co-scan's amplification
        savings per fetched window: what every tenant's solo scan would have
        read vs what the union scan reads (``TenantShareStats``).
        ``union`` (optional): the precomputed union of ``projections`` — a
        long-lived caller computes it once instead of per batch.

        Returns ``{tenant.name: [per-example EventBatch]}``. Stats semantics:
        ``stats.examples`` counts per-tenant *outputs* (N per source example),
        matching what N solo passes would have recorded."""
        projections = list(projections)
        if not projections:
            raise ValueError("materialize_multi needs at least one projection")
        names = [p.name for p in projections]
        if len(set(names)) != len(names):
            raise ValueError(f"tenant names must be unique, got {names}")
        if union is None:  # a long-lived caller (planner) passes its own
            union = (projections[0] if len(projections) == 1
                     else TenantProjection.union(projections, self.schema))

        out: Dict[str, List[Optional[ev.EventBatch]]] = {
            p.name: [None] * len(examples) for p in projections}
        members: "OrderedDict[tuple, List[int]]" = OrderedDict()
        for i, ex in enumerate(examples):
            if ex.is_fat or ex.version is None:
                for p in projections:
                    out[p.name][i] = self.materialize(ex, p)
                continue
            members.setdefault(self._window_key(ex, union), []).append(i)

        # hold the scan-time lease through the share estimates so the
        # generation the accounting is pinned to cannot be GC'd (and thus
        # skipped) by a compaction flip racing the estimate
        windows, fetched, lease = self._resolve_windows(
            members, examples, union, hold_lease=share_stats is not None)
        try:
            if share_stats is not None and fetched:
                self._account_share(fetched, projections, union, share_stats)
        finally:
            if lease is not None:
                lease.release()

        for key, idxs in members.items():
            imm = windows[key]
            # carve once per (window, tenant), shared across member examples;
            # a tenant that IS the union (N=1) uses the window as fetched —
            # it was scanned under exactly that projection, the carve is a
            # no-op re-slice/re-project
            views = {p.name: (imm if p is union
                              else project_view(imm, p, self.schema))
                     for p in projections}
            for i in idxs:
                ex = examples[i]
                mutable_part = ex.mutable_uih or ev.empty_batch(self.schema)
                for p in projections:
                    view = views[p.name]
                    out[p.name][i] = self._concat_and_project(
                        view, mutable_part, p)
                    self.stats.examples += 1
                    self.stats.immutable_events += ev.batch_len(view)
                    self.stats.mutable_events += ev.batch_len(mutable_part)
        return out  # type: ignore[return-value]

    def _resolve_windows(
        self,
        members: "OrderedDict[tuple, List[int]]",
        examples: Sequence[TrainingExample],
        projection: Optional[TenantProjection],
        hold_lease: bool = False,
    ):
        """Resolve every unique window key: cross-batch LRU first, then ONE
        planned store round-trip for the misses (with pin-race retry: a pinned
        generation's last lease can release between the availability check and
        the scan — demote ONLY the vanished windows to live re-resolution, so
        a still-leased sibling window keeps its byte-exact pinned service).
        The per-window decision is resolved once (counting each pin miss
        exactly once) and only demoted on retries, never re-derived.

        Returns ``(windows, fetched, lease)`` where ``fetched`` lists the
        ``(key, representative_example, generation)`` triples that actually
        hit the store (cache hits excluded). With ``hold_lease`` (share
        accounting), live (gen=-1) fetches record the generation id a
        transient lease named at scan start and ``lease`` is that lease,
        still held (the caller releases it after estimating against the
        recorded generation). Without it — the trainer's hot path, where the
        triples' generation is never consumed — no lease is taken and
        ``lease`` is ``None``."""
        windows: dict = {}
        to_fetch: List[Tuple[tuple, TrainingExample, int]] = []  # key, rep, n_members
        for key, idxs in members.items():
            cached = self._window_cache_get(key)
            if cached is not None:
                self.stats.window_cache_hits += 1
                windows[key] = cached
                continue
            to_fetch.append((key, examples[idxs[0]], len(idxs)))

        gens: dict = {key: self._window_generation(rep)
                      for key, rep, _ in to_fetch}

        def collect():
            reqs: List[ScanRequest] = []
            spans: List[Tuple[tuple, TrainingExample, int, int, int]] = []
            for key, rep, n_members in to_fetch:
                gen = gens[key]
                canonical = self._requests_for(rep, projection, gen)
                lo = len(reqs)
                # one canonicalized request tuple PER member example: the plan
                # covers example × group, the store dedupes (IOStats.dedup_hits)
                for _ in range(n_members):
                    reqs.extend(canonical)
                spans.append((key, rep, lo, lo + len(canonical), gen))
            return reqs, spans

        fetched: List[Tuple[tuple, TrainingExample, int]] = []
        lease = None
        if to_fetch:
            while True:
                reqs, fetch_spans = collect()
                # share accounting (hold_lease) takes a transient lease that
                # names — and retains — the generation live when the scan
                # STARTS: reading store.generation after the scan would name
                # whatever a racing compaction published in between,
                # mis-attributing the new generation's stripes to this
                # window's share accounting. (gen=-1 requests still resolve
                # per-request, so a mid-scan flip can straddle; audit mode's
                # checksum check catches actual content drift.) Plain fetches
                # never consume the recorded generation, so they skip the
                # lease and its _gen_lock round-trips on the hot path.
                if hold_lease:
                    lease = self.immutable.acquire_lease()
                try:
                    parts = self.immutable.multi_range_scan(reqs, self.io_stats)
                    break
                except GenerationUnavailable:
                    if lease is not None:
                        lease.release()
                        lease = None
                    demoted = False
                    for key in gens:
                        if (gens[key] >= 0
                                and not self.immutable.has_generation(gens[key])):
                            gens[key] = -1
                            self.stats.pin_misses += 1
                            demoted = True
                    if not demoted:
                        # cannot identify the vanished generation (it came
                        # back? paradoxical race) — force everything live to
                        # guarantee termination; live scans never raise
                        for key in gens:
                            gens[key] = -1
                except BaseException:
                    if lease is not None:
                        lease.release()
                    raise
            try:
                live_gen = (lease.generation if lease is not None
                            else self.immutable.generation)
                for key, rep, lo, hi, gen in fetch_spans:
                    imm = self._join_groups(parts[lo:hi])
                    self._maybe_check(rep, imm, projection, gen)
                    self.stats.windows_fetched += 1
                    windows[key] = imm
                    self._window_cache_put(key, imm)
                    fetched.append((key, rep, gen if gen >= 0 else live_gen))
            except BaseException:
                if lease is not None:
                    lease.release()
                raise
        return windows, fetched, lease

    def _account_share(
        self,
        fetched: Sequence[Tuple[tuple, TrainingExample, int]],
        projections: Sequence[TenantProjection],
        union: TenantProjection,
        share_stats: TenantShareStats,
    ) -> None:
        """Per fetched window: what each tenant's solo scan WOULD read vs what
        the union co-scan reads, via the store's metadata-exact estimator."""
        store = self.immutable
        share_stats.co_scans += 1
        for key, rep, gen in fetched:
            try:
                union_b = sum(
                    store.estimate_scan(r)[1]
                    for r in self._requests_for(rep, union, gen))
                solo = [
                    sum(store.estimate_scan(r)[1]
                        for r in self._requests_for(rep, p, gen))
                    for p in projections
                ]
            except GenerationUnavailable:
                continue  # the generation flipped after the fetch; skip
            share_stats.co_scan_windows += 1
            share_stats.union_bytes_est += union_b
            share_stats.solo_bytes_est += sum(solo)
            share_stats.bytes_saved_vs_solo += sum(solo) - union_b
            share_stats.union_overfetch_bytes += max(0, union_b - max(solo))

    # -- helpers ---------------------------------------------------------------
    def _window_key(
        self, example: TrainingExample, projection: Optional[TenantProjection]
    ) -> tuple:
        """Pins the *content* of an immutable window: same watermark + same
        length + same checksum => identical event set regardless of the
        per-request lookback start_ts."""
        v = example.version
        return (example.user_id, v.end_ts, v.seq_len, v.checksum, v.generation,
                _projection_fingerprint(projection))

    def _window_cache_get(self, key: tuple) -> Optional[ev.EventBatch]:
        if not self.window_cache_size:
            return None
        with self._cache_lock:
            hit = self._window_cache.get(key)
            if hit is not None:
                self._window_cache.move_to_end(key)  # true LRU: promote on hit
            return hit

    def _window_cache_put(self, key: tuple, imm: ev.EventBatch) -> None:
        if not self.window_cache_size:
            return
        with self._cache_lock:
            self._window_cache[key] = imm
            self._window_cache.move_to_end(key)
            while len(self._window_cache) > self.window_cache_size:
                self._window_cache.popitem(last=False)

    def _window_generation(self, example: TrainingExample) -> int:
        """Resolve which generation serves this example's window: the logged
        generation while a lease retains it (pinning mode), else -1 = live
        re-resolve (remediation)."""
        meta = example.version
        assert meta is not None
        if not self.pin_generations or meta.generation < 0:
            return -1
        if self.immutable.has_generation(meta.generation):
            return meta.generation
        self.stats.pin_misses += 1
        return -1

    def _requests_for(
        self,
        example: TrainingExample,
        projection: Optional[TenantProjection],
        generation: int = -1,
    ) -> List[ScanRequest]:
        """One ScanRequest per feature group for the example's window.

        Sequence-length projection: the tenant wants the *most recent*
        ``projection.seq_len`` events of the full UIH. The immutable fetch uses
        the full tenant budget (not seq_len - n_mutable) so the fetched window
        is shareable across same-user examples whose mutable slices differ;
        the final concat+trim keeps exactly seq_len events."""
        meta = example.version
        assert meta is not None
        groups = (
            projection.feature_groups
            if projection is not None
            else tuple(self.schema.feature_groups)
        )
        max_events = -1 if projection is None else projection.seq_len
        return [
            ScanRequest(
                user_id=example.user_id,
                group=g,
                start_ts=meta.start_ts,
                end_ts=meta.end_ts,
                max_events=meta.seq_len if max_events < 0 else max_events,
                traits=None if projection is None else projection.traits_for(self.schema, g),
                generation=generation,
            )
            for g in groups
        ]

    def _fetch_immutable(
        self, example: TrainingExample, projection: Optional[TenantProjection]
    ) -> ev.EventBatch:
        gen = self._window_generation(example)
        try:
            parts = self.immutable.multi_range_scan(
                self._requests_for(example, projection, gen), self.io_stats)
        except GenerationUnavailable:
            # pinned generation GC'd between check and scan: remediate live
            self.stats.pin_misses += 1
            gen = -1
            parts = self.immutable.multi_range_scan(
                self._requests_for(example, projection, gen), self.io_stats)
        imm = self._join_groups(parts)
        self._maybe_check(example, imm, projection, gen)
        self.stats.windows_fetched += 1
        return imm

    def _maybe_check(
        self,
        example: TrainingExample,
        imm: ev.EventBatch,
        projection: Optional[TenantProjection],
        used_generation: int = -1,
    ) -> None:
        """Checksum-validate iff the full window was fetched (a projected
        fetch can legitimately differ from the snapshot-time window).

        ``used_generation``: the generation the window was actually scanned
        from. A window served pinned is byte-exact by construction; a STALE
        window re-resolved against the live generation is the remediation
        path, and in pinning mode its revalidation is mandatory."""
        meta = example.version
        assert meta is not None
        # examples logged before the first compaction (generation -1) have no
        # generation to go stale — there was never a pinned window
        stale = (meta.generation >= 0
                 and meta.generation != self.immutable.generation)
        pinned = used_generation >= 0 and stale
        if pinned:
            self.stats.pinned_windows += 1
        elif stale:
            self.stats.stale_reresolved += 1
        must_validate = self.validate_checksum or (
            self.pin_generations and stale and not pinned)
        max_events = -1 if projection is None else projection.seq_len
        if (must_validate and meta.checksum
                and self._wants_full_window(projection, meta.seq_len, max_events)):
            self._check(example, imm, meta, stale=stale and not pinned)

    def _wants_full_window(self, projection, snap_len: int, max_events: int) -> bool:
        return projection is None or max_events >= snap_len

    def _join_groups(self, parts: Sequence[ev.EventBatch]) -> ev.EventBatch:
        """Feature groups are horizontal partitions of the SAME event sequence
        (compaction cuts one history into per-group stripes), so after applying
        identical temporal bounds + length budget they are position-aligned."""
        joined: ev.EventBatch = {}
        n = None
        for p in parts:
            if n is None:
                n = ev.batch_len(p)
            else:
                assert ev.batch_len(p) == n, "feature groups misaligned"
                if n and "timestamp" in joined:
                    assert np.array_equal(joined["timestamp"], p["timestamp"])
            joined.update(p)
        return joined

    def _check(self, example, immutable_part: ev.EventBatch, meta,
               stale: bool = False) -> None:
        need = {"timestamp", "item_id"}
        if not need <= set(immutable_part):
            return  # projection dropped identity columns; cannot validate
        self.stats.checksum_validated += 1
        got = window_checksum(immutable_part)
        if got != meta.checksum or ev.batch_len(immutable_part) != meta.seq_len:
            self.stats.checksum_failures += 1
            if stale:
                self.stats.stale_failures += 1
            if self.strict:
                exc = StaleGeneration if stale else ChecksumMismatch
                raise exc(
                    f"request {example.request_id}: immutable window changed "
                    f"(gen {meta.generation} -> {self.immutable.generation}); "
                    f"len {meta.seq_len} -> {ev.batch_len(immutable_part)}"
                    + ("; re-resolution against the live generation could not "
                       "reproduce the logged window" if stale else "")
                )

    def _concat_and_project(
        self,
        immutable_part: ev.EventBatch,
        mutable_part: ev.EventBatch,
        projection: Optional[TenantProjection],
    ) -> ev.EventBatch:
        if projection is not None:
            traits = projection.all_traits(self.schema)
            mutable_part = ev.project_traits(mutable_part, [t for t in traits if t in mutable_part])
            if immutable_part:
                immutable_part = ev.project_traits(
                    immutable_part, [t for t in traits if t in immutable_part]
                )
        full = ev.concat_batches([immutable_part, mutable_part])
        if not full:
            cols = (
                projection.all_traits(self.schema)
                if projection is not None
                else self.schema.trait_names
            )
            return ev.empty_batch(self.schema, cols)
        if projection is not None:
            n = ev.batch_len(full)
            if n > projection.seq_len:
                full = ev.slice_batch(full, n - projection.seq_len, n)
        return full

    def _project_fat(
        self, example: TrainingExample, projection: Optional[TenantProjection]
    ) -> ev.EventBatch:
        """Fat Row tenants must filter client-side — the monolithic row has
        already been read in full (this is the multi-tenant penalty)."""
        fat = example.fat_uih or ev.empty_batch(self.schema)
        if projection is None:
            return fat
        traits = [t for t in projection.all_traits(self.schema) if t in fat]
        out = ev.project_traits(fat, traits)
        n = ev.batch_len(out)
        if n > projection.seq_len:
            out = ev.slice_batch(out, n - projection.seq_len, n)
        return out
