"""Disaggregated immutable tier: a multi-node sharded store (§4.2.3).

The paper's normalized immutable UIH tier is a horizontally sharded service;
this module splits the in-process monolith into:

  * ``StoreNode`` — one storage node, owning its resident shard tables, its
    stripe-decode LRU, its per-node ``IOStats`` and its generation/lease
    state. A node is a full ``ImmutableUIHStore`` (bulk load, planned batch
    scans over its *local* shards, leases) that happens to hold only the
    users placed on it.
  * ``ShardedUIHStore`` — the client every consumer actually talks to. It
    implements the complete ``StoreProtocol`` surface (``plan`` /
    ``execute_plan`` / ``scan`` / ``bulk_load`` / ``acquire_lease`` /
    ``estimate_scan`` / generations / introspection) by routing requests to
    nodes through a per-generation ``PlacementMap`` and executing node groups
    concurrently — one remote round-trip per node, nodes overlapped on a
    thread pool, each node further parallelizing across its local shards.

**Placement** (FlexShard-style, 2301.02959): the torso routes by symmetric
hash (``shard_of`` -> ``node_of_shard``); the heavy tail of ultra-long users
gets an explicit balanced assignment recomputed from the generation's actual
stripe bytes (``length_aware_overrides``). The resulting map is generation
metadata: the client retains the map of every live/retained generation, so a
pinned scan finds its bytes on the node where *that* generation placed them
even after a later ``rebalance()`` moved the user.

**Epoch barrier**: ``bulk_load`` and ``acquire_lease`` serialize on one flip
lock. A lease therefore pins the SAME generation on every node — there is no
interleaving where node 0 leases generation g while node 1 has already
flipped to g+1 — which is exactly the consistency the snapshotter's
transient lease and the streaming pin protocol (PR 3/4) assume. The lock is
never taken on the scan path: reads stay lock-free exactly like the
monolith's.

**Fault surface**: a node marked down (``set_node_down``) fails its scans
with ``NodeUnavailable`` — a *retryable* I/O error (the DPP pool's
self-healing requeues the item), distinct from ``GenerationUnavailable``
(the remediation path). Metadata reads (watermark, estimates, leases) stay
up: an outage takes out data I/O, not the control plane.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import events as ev
from repro.storage.immutable_store import (
    GenerationUnavailable,
    ImmutableUIHStore,
    IOStats,
    LeaseStats,
    ScanPlan,
    ScanRequest,
    build_scan_plan,
)
from repro.storage.sharding import (
    PlacementMap,
    ShardRouter,
    length_aware_overrides,
)


class NodeUnavailable(IOError):
    """A store node is unreachable. Transient and retryable: the caller's
    work item fails cleanly (no partial result is returned) and a retry after
    the node returns succeeds — unlike ``GenerationUnavailable``, which means
    the *data* is gone and remediation must re-resolve."""


class StoreNode(ImmutableUIHStore):
    """One node of the disaggregated immutable tier.

    Owns everything node-local: shard tables for the users placed here, the
    stripe-decode LRU, per-node ``IOStats``, and this node's generation /
    lease state. ``n_shards`` is the node's LOCAL shard count (its internal
    scan parallelism); global routing is the client's job."""

    # decorrelates the node-LOCAL shard hash from the global placement hash:
    # a node's residents all agree on shard_of(u, n_global) mod n_nodes, and
    # nested moduli of the same mix value collapse them into one local shard
    # (see ShardRouter.salt) — killing the node's internal scan parallelism
    LOCAL_SALT = 0x5DEECE66D

    def __init__(self, node_id: int, schema=None, n_shards: int = 2,
                 decode_cache_size: int = 256):
        super().__init__(schema, n_shards=n_shards,
                         decode_cache_size=decode_cache_size)
        self.router = ShardRouter(n_shards, salt=self.LOCAL_SALT)
        self.node_id = node_id

    def __repr__(self) -> str:
        return (f"StoreNode(id={self.node_id}, gen={self.generation}, "
                f"local_shards={self.n_shards})")


@dataclasses.dataclass
class NodeStats:
    """Per-node skew surface: who is doing the work and who holds the bytes.

    ``max_mean_*_ratio`` is the p-max load metric the placement policy
    optimizes: 1.0 = perfectly even, N = one node carries everything."""

    per_node: List[IOStats]          # each node's cumulative IOStats snapshot
    scan_load: List[int]             # bytes_scanned per node (read skew)
    seeks: List[int]                 # seeks per node
    decodes: List[int]               # stripes decoded per node
    stored: List[int]                # resident blob bytes per node (placement)
    max_mean_load_ratio: float       # max/mean of scan_load
    max_mean_stored_ratio: float     # max/mean of stored

    @staticmethod
    def _ratio(values: Sequence[int]) -> float:
        mean = sum(values) / max(len(values), 1)
        return (max(values) / mean) if mean > 0 else 1.0


class ShardedGenerationLease:
    """One logical lease = one node lease on EVERY node, acquired under the
    flip lock so all of them name the same generation (epoch barrier)."""

    __slots__ = ("generation", "_store", "_node_leases", "_released")

    def __init__(self, store: "ShardedUIHStore", generation: int, node_leases):
        self.generation = generation
        self._store = store
        self._node_leases = node_leases
        self._released = False

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._store._release_client_lease(self.generation,
                                              self._node_leases)

    def __enter__(self) -> "ShardedGenerationLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class ShardedUIHStore:
    """Multi-node client for the disaggregated immutable tier.

    Drop-in for ``ImmutableUIHStore`` everywhere the ``StoreProtocol`` is
    spoken — same plan/execute/lease surface, same ``StaleGeneration``
    remediation contract — with reads fanned out across ``n_nodes`` store
    nodes and placement that keeps ultra-long users from hot-spotting one
    node."""

    def __init__(
        self,
        schema=None,
        n_shards: int = 8,
        n_nodes: int = 4,
        decode_cache_size: int = 256,
        placement_policy: str = "length_aware",   # "length_aware" | "hash"
        heavy_tail_fraction: float = 0.05,
    ):
        if n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
        if placement_policy not in ("length_aware", "hash"):
            raise ValueError(f"unknown placement_policy {placement_policy!r}")
        self.schema = (schema if schema is not None
                       else ev.default_schema())
        self.n_shards = n_shards
        self.n_nodes = n_nodes
        self.router = ShardRouter(n_shards)   # symmetric data-placement key
        self.placement_policy = placement_policy
        self.heavy_tail_fraction = heavy_tail_fraction
        local_shards = max(1, n_shards // n_nodes)
        self.nodes: List[StoreNode] = [
            StoreNode(i, self.schema, n_shards=local_shards,
                      decode_cache_size=decode_cache_size)
            for i in range(n_nodes)
        ]
        self.generation = -1
        # epoch barrier: generation flips and lease acquisition serialize here
        # (the scan path never takes it — reads stay lock-free per node)
        self._flip_lock = threading.Lock()
        self._lease_refs: Dict[int, int] = {}     # gen -> logical lease refs
        self._lease_ls = LeaseStats()
        # placement is generation metadata: retained as long as the
        # generation is live or lease-retained anywhere
        self._live_placement = PlacementMap(n_nodes, n_shards, {})
        self._placements: Dict[int, PlacementMap] = {}
        self._rebalance_pending = False
        self._down = [False] * n_nodes
        self._stats_lock = threading.Lock()
        self._client_plan_stats = IOStats()   # batched_requests/dedup/subsumed
        self._pool = ThreadPoolExecutor(
            max_workers=min(n_nodes, 16), thread_name_prefix="uih-node")

    # -- placement -----------------------------------------------------------
    def live_placement(self) -> PlacementMap:
        return self._live_placement

    def placement_for(self, generation: int) -> PlacementMap:
        """The map that generation's bulk load placed users with (live map
        for -1/unknown: an unknown pinned generation is GC'd, and its scan
        will raise ``GenerationUnavailable`` wherever it lands)."""
        if generation < 0:
            return self._live_placement
        return self._placements.get(generation, self._live_placement)

    def rebalance(self) -> Dict[int, int]:
        """Recompute heavy-tail placement at the NEXT generation flip.

        Placement is otherwise sticky across flips (daily compaction must not
        reshuffle the torso's working set); ``rebalance()`` marks the next
        ``bulk_load`` to re-derive the override map from the new generation's
        actual stripe bytes. Returns a preview computed from the LIVE tables
        so operators can see the planned moves."""
        with self._flip_lock:
            self._rebalance_pending = True
            loads = self._live_loads()
        return length_aware_overrides(
            loads, self.n_nodes, self.n_shards, self.heavy_tail_fraction)

    def _live_loads(self) -> Dict[int, int]:
        loads: Dict[int, int] = {}
        for node in self.nodes:
            for shard in node._shards:
                for (uid, _group), (_starts, stripes) in shard.items():
                    loads[uid] = loads.get(uid, 0) + sum(
                        len(s.blob) for s in stripes)
        return loads

    # -- node routing ---------------------------------------------------------
    def _node_of(self, user_id: int, generation: int = -1) -> int:
        return self.placement_for(generation).node_of(user_id)

    def _node_for(self, user_id: int, generation: int = -1,
                  check_down: bool = False) -> StoreNode:
        nid = self._node_of(user_id, generation)
        if check_down and self._down[nid]:
            raise NodeUnavailable(f"store node {nid} is down")
        return self.nodes[nid]

    def set_node_down(self, node_id: int, down: bool = True) -> None:
        """Mark a node unreachable: its scans raise ``NodeUnavailable`` until
        it is marked up again. Metadata reads and leases are unaffected."""
        self._down[node_id] = down

    # -- write path -----------------------------------------------------------
    def bulk_load(self, tables, generation: int) -> None:
        """Install a generation on every node atomically w.r.t. leases.

        Runs under the flip lock (the epoch barrier): once any node sees the
        new generation, every concurrent ``acquire_lease`` sees it on ALL
        nodes. Lease-id reuse is validated client-side BEFORE any node
        installs, so a rejected load never leaves nodes on mixed
        generations. Every node receives the load (possibly with an empty
        table subset) so generation state stays uniform across the tier."""
        with self._flip_lock:
            if generation >= 0 and self._lease_refs.get(generation, 0) > 0:
                raise ValueError(
                    f"generation id {generation} is still leased "
                    f"(refs={self._lease_refs[generation]}); ids must not be "
                    f"reused while leased")
            placement = self._placement_for_load(tables)
            node_tables: List[dict] = [{} for _ in self.nodes]
            for (user_id, group), stripes in tables.items():
                node_tables[placement.node_of(user_id)][(user_id, group)] = \
                    stripes
            for node, sub in zip(self.nodes, node_tables):
                node.bulk_load(sub, generation)
            self.generation = generation
            self._placements[generation] = placement
            self._live_placement = placement
            self._rebalance_pending = False
            self._gc_placements_locked()

    def _placement_for_load(self, tables) -> PlacementMap:
        if self.placement_policy == "hash":
            return PlacementMap(self.n_nodes, self.n_shards, {})
        if self.generation >= 0 and not self._rebalance_pending:
            # sticky: reuse the live overrides until an explicit rebalance —
            # daily compaction must not migrate users as a side effect
            return PlacementMap(self.n_nodes, self.n_shards,
                                dict(self._live_placement.overrides))
        loads: Dict[int, int] = {}
        for (user_id, _group), stripes in tables.items():
            loads[user_id] = loads.get(user_id, 0) + sum(
                len(s.blob) for s in stripes)
        return PlacementMap(
            self.n_nodes, self.n_shards,
            length_aware_overrides(loads, self.n_nodes, self.n_shards,
                                   self.heavy_tail_fraction))

    def _gc_placements_locked(self) -> None:
        for g in list(self._placements):
            if g != self.generation and not self.nodes[0].has_generation(g):
                del self._placements[g]

    # -- generation leases -----------------------------------------------------
    def acquire_lease(
        self, generation: Optional[int] = None
    ) -> ShardedGenerationLease:
        """Pin one CONSISTENT generation on every node (epoch barrier: the
        flip lock orders this against ``bulk_load``, so all node leases name
        the same generation). Raises ``GenerationUnavailable`` — with no
        node lease left behind — if the generation is gone."""
        with self._flip_lock:
            node_leases = []
            try:
                for node in self.nodes:
                    node_leases.append(node.acquire_lease(generation))
            except GenerationUnavailable:
                for lease in node_leases:
                    lease.release()
                raise
            gen = node_leases[0].generation
            self._lease_refs[gen] = self._lease_refs.get(gen, 0) + 1
            self._lease_ls.acquired += 1
        return ShardedGenerationLease(self, gen, node_leases)

    def _release_client_lease(self, generation: int, node_leases) -> None:
        with self._flip_lock:
            for lease in node_leases:
                lease.release()
            self._lease_ls.released += 1
            refs = self._lease_refs.get(generation, 0) - 1
            if refs <= 0:
                self._lease_refs.pop(generation, None)
            else:
                self._lease_refs[generation] = refs
            self._gc_placements_locked()

    @property
    def lease_stats(self) -> LeaseStats:
        """Logical (client-level) acquire/release counts; retention/GC cycles
        are uniform across nodes, so node 0's counters stand for the tier."""
        n0 = self.nodes[0].lease_stats
        return LeaseStats(
            acquired=self._lease_ls.acquired,
            released=self._lease_ls.released,
            generations_retained=n0.generations_retained,
            generations_gc=n0.generations_gc,
        )

    def has_generation(self, generation: int) -> bool:
        # every bulk_load and every lease touches all nodes, so they agree
        return self.nodes[0].has_generation(generation)

    def leased_generations(self) -> Dict[int, int]:
        """generation -> outstanding LOGICAL lease refcount (one sharded
        lease counts once, not once per node)."""
        with self._flip_lock:
            return dict(self._lease_refs)

    def retained_generations(self) -> List[int]:
        out = set()
        for node in self.nodes:
            out.update(node.retained_generations())
        return sorted(out)

    # -- read path -------------------------------------------------------------
    def _effective_traits(self, req: ScanRequest) -> Tuple[str, ...]:
        return req.traits or self.schema.group_traits(req.group)

    def scan(self, req: ScanRequest) -> ev.EventBatch:
        return self._node_for(req.user_id, req.generation,
                              check_down=True).scan(req)

    def estimate_scan(self, req: ScanRequest) -> Tuple[int, int]:
        """Metadata-only cost walk (see the monolith): routed like the scan
        would be, but served even from a down node — estimates are control
        plane, not data I/O."""
        return self._node_for(req.user_id, req.generation).estimate_scan(req)

    def plan(self, reqs: Sequence[ScanRequest]) -> ScanPlan:
        """Client-side planning: dedupe + union-projection subsumption over
        the whole batch (a request answered by an in-plan twin or carved from
        a wider root never crosses the network at all), roots grouped by
        TARGET NODE — ``ScanPlan.shard_groups`` keys are node ids here."""
        return build_scan_plan(
            reqs,
            lambda r: self._node_of(r.user_id, r.generation),
            self._effective_traits)

    def execute_plan(
        self, plan: ScanPlan, out_stats: Optional[IOStats] = None
    ) -> List[ev.EventBatch]:
        """Execute node groups concurrently: ONE batched round-trip per node
        (the node replans its slice over its local shards and parallelizes
        there), subsumed requests carved client-side from the covering
        results. Results return in original request order."""
        results: List[Optional[ev.EventBatch]] = [None] * len(plan.unique)

        def run_node(pair) -> IOStats:
            nid, idxs = pair
            if self._down[nid]:
                raise NodeUnavailable(f"store node {nid} is down")
            local = IOStats()
            parts = self.nodes[nid].multi_range_scan(
                [plan.unique[j] for j in idxs], local)
            for j, part in zip(idxs, parts):
                results[j] = part
            return local

        groups = list(plan.shard_groups.items())
        if len(groups) <= 1:
            node_locals = [run_node(g) for g in groups]
        else:
            node_locals = list(self._pool.map(run_node, groups))
        for j, k in plan.derived.items():
            results[j] = ev.tail_view(results[k], plan.unique[j].max_events,
                                      self._effective_traits(plan.unique[j]))
        call = IOStats()
        for local in node_locals:
            call.merge(local)
        # plan-level counters are the CLIENT's: nodes each count their own
        # round-trip, and dedupe/subsumption already happened up here
        call.batched_requests = 1
        call.dedup_hits = plan.dedup_hits
        call.subsumed_hits = plan.subsumed
        with self._stats_lock:
            self._client_plan_stats.batched_requests += 1
            self._client_plan_stats.dedup_hits += plan.dedup_hits
            self._client_plan_stats.subsumed_hits += plan.subsumed
        if out_stats is not None:
            out_stats.merge(call)
        return [results[j] for j in plan.assignment]

    def multi_range_scan(
        self,
        reqs: Sequence[ScanRequest],
        out_stats: Optional[IOStats] = None,
    ) -> List[ev.EventBatch]:
        return self.execute_plan(self.plan(reqs), out_stats)

    # -- stats + introspection -------------------------------------------------
    @property
    def stats(self) -> IOStats:
        """Tier-wide view: physical I/O summed over nodes, plan-level
        counters (batched_requests / dedup_hits / subsumed_hits) from the
        client planner. ``parallel_shards`` sums the nodes' local shard
        fanout — the tier's real physical scan parallelism."""
        agg = IOStats()
        for node in self.nodes:
            agg.merge(node.stats)
        with self._stats_lock:
            agg.batched_requests = self._client_plan_stats.batched_requests
            agg.dedup_hits = self._client_plan_stats.dedup_hits
            agg.subsumed_hits = self._client_plan_stats.subsumed_hits
        return agg

    def node_stats(self) -> NodeStats:
        per_node = [node.stats.snapshot() for node in self.nodes]
        scan_load = [s.bytes_scanned for s in per_node]
        stored = [node.stored_bytes() for node in self.nodes]
        return NodeStats(
            per_node=per_node,
            scan_load=scan_load,
            seeks=[s.seeks for s in per_node],
            decodes=[s.stripes_read for s in per_node],
            stored=stored,
            max_mean_load_ratio=NodeStats._ratio(scan_load),
            max_mean_stored_ratio=NodeStats._ratio(stored),
        )

    @property
    def latency_model(self):
        return self.nodes[0].latency_model

    @latency_model.setter
    def latency_model(self, model) -> None:
        # each node charges its own remote-I/O latency; node groups overlap
        # on the client pool, so a batch's wall time is the max over nodes
        for node in self.nodes:
            node.latency_model = model

    @property
    def bulk_load_bytes(self) -> int:
        return sum(node.bulk_load_bytes for node in self.nodes)

    def stored_bytes(self) -> int:
        return sum(node.stored_bytes() for node in self.nodes)

    def retained_bytes(self) -> int:
        return sum(node.retained_bytes() for node in self.nodes)

    def stored_events(self, user_id: int, group: str) -> int:
        return self._node_for(user_id).stored_events(user_id, group)

    def watermark(self, user_id: int, group: str = "core",
                  generation: int = -1) -> int:
        return self._node_for(user_id, generation).watermark(
            user_id, group, generation)

    def fanout(self, reqs: Sequence[ScanRequest]) -> int:
        """Distinct NODES a batch touches (the cross-network fanout the
        affinity planner minimizes)."""
        return len({self._node_of(r.user_id, r.generation) for r in reqs})

    def close(self) -> None:
        self._pool.shutdown(wait=True)
        for node in self.nodes:
            node.close()

    def __enter__(self) -> "ShardedUIHStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
