"""Disaggregated Data PreProcessing (paper §4.2): workers that materialize
base batches, trainer-side slot-based rebatching client, pipelined I/O
prefetch, a double-buffered device feed, elastic autoscaling, and
data-affinity planning."""
