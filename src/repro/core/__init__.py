"""Core of the paper's contribution: the versioned late materialization
protocol (events/traits, version metadata, inference-time snapshotting,
training-time time-travel reconstruction, O2O consistency auditing,
multi-tenant projection, and the Fat Row baseline/cost model)."""

from repro.core import events
from repro.core.events import (  # noqa: F401
    EventBatch,
    StreamConfig,
    SyntheticEventStream,
    TraitSchema,
    TraitSpec,
    default_schema,
)
from repro.core.projection import TenantProjection, table1_tenants  # noqa: F401
from repro.core.versioning import TrainingExample, VersionMetadata  # noqa: F401
