"""Granite-8B-Code [arXiv:2405.04324]: llama-arch, 36L d4096 32H GQA(kv=8)
d_ff 14336 v49152."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="granite-8b", n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49_152, head_dim=128, qk_norm=False, rope_theta=1e4,
)

SMOKE = TransformerConfig(
    name="granite-8b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=191, head_dim=16, qk_norm=False, rope_theta=1e4,
    compute_dtype=jnp.float32, q_chunk=16, loss_chunk=16,
)


def spec() -> ArchSpec:
    return ArchSpec("granite-8b", "lm", FULL, SMOKE, LM_SHAPES)
