"""End-to-end trainer: DPP data plane -> jit'd train step -> checkpoints.

Integrates the full stack on one host (and, unchanged, on a pod via the mesh
argument): the VLM materialization pipeline feeds batches through the
rebatching client; the train step is jit'd with shardings; the checkpoint
manager gives crash-safe resume; gradient compression is optional.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.train.grad_compress import EFState, compress_with_feedback, ef_init
from repro.train.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
)


@dataclasses.dataclass
class TrainerConfig:
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    grad_accum: int = 1          # microbatch accumulation factor
    compress_grads: bool = False
    log_every: int = 10
    # double-buffered device feed: issue the host->device transfer for batch
    # N+1 while step N computes (0 disables; 2 = classic double buffering).
    # Ignored when ``fit`` is handed an already-wrapped DevicePrefetcher.
    prefetch_depth: int = 0


class Trainer:
    def __init__(
        self,
        loss_fn: Callable[[Any, Dict[str, Any]], jax.Array],
        params: Any,
        cfg: TrainerConfig,
        mesh=None,
    ):
        self.loss_fn = loss_fn
        self.cfg = cfg
        self.params = params
        self.opt_state = adamw_init(params)
        self.ef_state = ef_init(params) if cfg.compress_grads else None
        self.step = 0
        self.mesh = mesh
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)
                     if cfg.ckpt_dir else None)
        self.history = []
        self._jit_step = jax.jit(self._train_step)

    # -- one optimizer step (with optional microbatch accumulation) -----------
    def _train_step(self, params, opt_state, ef_state, microbatches):
        def accum(carry, mb):
            gacc, lacc = carry
            loss, grads = jax.value_and_grad(self.loss_fn)(params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jax.numpy.float32),
                                gacc, grads)
            return (gacc, lacc + loss), None

        zero = jax.tree.map(
            lambda p: jax.numpy.zeros(p.shape, jax.numpy.float32), params)
        (gsum, lsum), _ = jax.lax.scan(
            accum, (zero, jax.numpy.zeros((), jax.numpy.float32)), microbatches)
        n = self.cfg.grad_accum
        grads = jax.tree.map(lambda g: g / n, gsum)
        if ef_state is not None:
            grads, ef_state = compress_with_feedback(grads, ef_state)
        params, opt_state, stats = adamw_update(params, grads, opt_state,
                                                self.cfg.opt)
        stats["loss"] = lsum / n
        return params, opt_state, ef_state, stats

    def run_step(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        """batch rows are split into ``grad_accum`` microbatches."""
        n = self.cfg.grad_accum
        mbs = {}
        for k, v in batch.items():
            b = v.shape[0]
            assert b % n == 0, f"batch {b} not divisible by accum {n}"
            mbs[k] = v.reshape(n, b // n, *v.shape[1:])
        self.params, self.opt_state, self.ef_state, stats = self._jit_step(
            self.params, self.opt_state, self.ef_state, mbs)
        self.step += 1
        out = {k: float(v) for k, v in stats.items()}
        self.history.append(out)
        if self.ckpt and self.step % self.cfg.ckpt_every == 0:
            self.save()
        return out

    # -- checkpointing ----------------------------------------------------------
    def save(self) -> None:
        assert self.ckpt is not None
        state = {"params": self.params, "opt": self.opt_state}
        if self.ef_state is not None:
            state["ef"] = self.ef_state
        self.ckpt.save(self.step, state, extra={"step": self.step})

    def try_resume(self) -> bool:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        template = {"params": self.params, "opt": self.opt_state}
        if self.ef_state is not None:
            template["ef"] = self.ef_state
        state, step, _ = self.ckpt.restore(template)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.ef_state = state.get("ef", self.ef_state)
        self.step = step
        return True

    # -- full loop ---------------------------------------------------------------
    def fit(self, batches: Iterable[Dict[str, np.ndarray]],
            max_steps: Optional[int] = None) -> None:
        from repro.dpp.prefetch import DevicePrefetcher

        feed = batches
        if self.cfg.prefetch_depth > 0 and not isinstance(feed, DevicePrefetcher):
            feed = DevicePrefetcher(feed, depth=self.cfg.prefetch_depth)
        # GPU-busy accounting feeds the elastic controller's starvation signal
        record = getattr(feed, "record_train_step", None)
        t0 = time.perf_counter()
        try:
            for batch in feed:
                ts = time.perf_counter()
                stats = self.run_step(batch)
                if record is not None:
                    record(time.perf_counter() - ts)
                if self.step % self.cfg.log_every == 0:
                    dt = time.perf_counter() - t0
                    print(f"step {self.step:5d} loss={stats['loss']:.4f} "
                          f"gnorm={stats['grad_norm']:.3f} ({dt:.1f}s)",
                          flush=True)
                if max_steps and self.step >= max_steps:
                    break
        finally:
            # break AND exception paths: release the transfer thread and any
            # queued device batches (idempotent; harmless on exhaustion)
            if isinstance(feed, DevicePrefetcher):
                feed.stop()
