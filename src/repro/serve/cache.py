"""Per-user materialized-embedding cache (DESIGN.md §14.4).

A bounded, lock-protected LRU mapping ``user_id`` to the user-tower embedding
computed from that user's fully materialized UIH, tagged with the exact store
state it was computed against:

    (generation, freshness)  where
    freshness = (start_ts, end_ts, request_ts, mutable_version)

A lookup hits ONLY if both tags match the state the current request resolved
under its lease — a generation flip (compaction published a new immutable
view) or any change in the user's visible event set (new mutable events,
advanced watermark, shifted lookback window) makes the entry unusable and
evicts it on the spot, classified as ``invalidated_generation`` /
``invalidated_freshness``. ``mutable_version`` is the mutable tier's O(1)
per-user write-state counter (``MutableUIHStore.version``): an unchanged
version guarantees an unchanged merged view, so the probe needs NO mutable
read at all on a hit; a bump (append or eviction) is conservative — it can
only force a spurious recompute, never serve a stale slice. The immutable
window is pinned by ``(generation, start_ts, end_ts)`` and the mutable slice
by ``(end_ts, request_ts, mutable_version)``.

A hit therefore serves bytes identical to a fresh scan+featurize+encode —
the cache is a pure latency optimization, never a staleness trade.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class EmbedCacheStats:
    lookups: int = 0                  # get() calls
    hits: int = 0                     # tag-exact hits (embedding reused)
    misses: int = 0                   # absent or invalidated entries
    invalidated_generation: int = 0   # dropped: entry's generation superseded
    invalidated_freshness: int = 0    # dropped: user's visible event set changed
    evictions: int = 0                # dropped by LRU capacity pressure
    inserts: int = 0                  # put() calls that stored an embedding


class UserEmbeddingCache:
    """Bounded LRU of user-tower embeddings, validated by (generation,
    freshness) tags. Thread-safe: serving workers share one instance."""

    def __init__(self, capacity: int = 2048):
        assert capacity >= 1
        self.capacity = capacity
        self.stats = EmbedCacheStats()
        self._lock = threading.Lock()
        # user_id -> (generation, freshness, embedding)
        self._entries: "OrderedDict[int, Tuple[int, tuple, np.ndarray]]" = (
            OrderedDict())

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, user_id: int, generation: int,
            freshness: tuple) -> Tuple[Optional[np.ndarray], str]:
        """Return ``(embedding, "hit")`` iff the cached entry was computed
        against exactly this (generation, freshness); else ``(None, reason)``
        with reason in ``{"miss", "generation", "freshness"}`` (the two
        invalidation reasons also drop the dead entry)."""
        with self._lock:
            self.stats.lookups += 1
            entry = self._entries.get(user_id)
            if entry is None:
                self.stats.misses += 1
                return None, "miss"
            gen, fresh, emb = entry
            if gen != generation:
                del self._entries[user_id]
                self.stats.invalidated_generation += 1
                self.stats.misses += 1
                return None, "generation"
            if fresh != freshness:
                del self._entries[user_id]
                self.stats.invalidated_freshness += 1
                self.stats.misses += 1
                return None, "freshness"
            self._entries.move_to_end(user_id)  # true LRU: promote on hit
            self.stats.hits += 1
            return emb, "hit"

    def put(self, user_id: int, generation: int, freshness: tuple,
            embedding: np.ndarray) -> None:
        with self._lock:
            self._entries[user_id] = (generation, freshness, embedding)
            self._entries.move_to_end(user_id)
            self.stats.inserts += 1
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1

    def invalidate_user(self, user_id: int) -> bool:
        with self._lock:
            return self._entries.pop(user_id, None) is not None

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
