"""Request coalescing: concurrent retrieval calls -> latency-bounded
micro-batches (DESIGN.md §14.2).

The serving analogue of ``streaming.source.MicroBatchConfig``: requests
arrive one at a time from independent caller threads and are flushed to a worker as
one micro-batch when EITHER the batch is full (``max_batch``) OR the oldest
queued request has waited ``max_delay_s`` (the deadline is set by the FIRST
request of the forming batch, so a trickle of lonely requests still meets the
latency bound). Unlike the streaming source there is no polling loop — a
condition variable wakes the worker exactly on submit/deadline/close.

``close()`` drains: queued requests keep flushing (``drain_flushes``) until
the queue is empty, then ``next_batch`` returns ``(None, "closed")`` and the
workers exit. A submit after close is refused so no request can be enqueued
with nobody left to answer it.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, List, Optional, Tuple


@dataclasses.dataclass
class CoalesceStats:
    submitted: int = 0          # requests accepted into the queue
    rejected: int = 0           # submits refused because the coalescer closed
    batches: int = 0            # micro-batches handed to workers
    size_flushes: int = 0       # flushed because the batch filled (max_batch)
    deadline_flushes: int = 0   # flushed because the oldest request timed out
    drain_flushes: int = 0      # flushed during close() drain


class PendingRequest:
    """One in-flight retrieval request: a tiny single-use future.

    The submitting thread blocks in ``result()``; the serving worker fills it
    via ``_resolve``/``_fail``."""

    __slots__ = ("user_id", "k", "request_ts", "enqueue_t", "done_t",
                 "_event", "_result", "_error")

    def __init__(self, user_id: int, k: int, request_ts: int) -> None:
        self.user_id = user_id
        self.k = k
        self.request_ts = request_ts
        self.enqueue_t = 0.0
        self.done_t = 0.0   # resolve/fail time: done_t - enqueue_t = latency
        self._event = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"retrieval for user {self.user_id} not answered in {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    # -- worker side --------------------------------------------------------
    def _resolve(self, result) -> None:
        self._result = result
        self.done_t = time.monotonic()
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        if self._event.is_set():
            return
        self._error = exc
        self.done_t = time.monotonic()
        self._event.set()


class RequestCoalescer:
    """Thread-safe deadline + max-batch micro-batcher."""

    def __init__(self, max_batch: int = 16, max_delay_s: float = 0.002):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.stats = CoalesceStats()
        self._queue: Deque[PendingRequest] = collections.deque()
        self._cond = threading.Condition()
        self._closed = False

    def __len__(self) -> int:
        with self._cond:
            return len(self._queue)

    def submit(self, pending: PendingRequest) -> PendingRequest:
        with self._cond:
            if self._closed:
                self.stats.rejected += 1
                raise RuntimeError("coalescer is closed")
            pending.enqueue_t = time.monotonic()
            self._queue.append(pending)
            self.stats.submitted += 1
            self._cond.notify_all()
        return pending

    def next_batch(self) -> Tuple[Optional[List[PendingRequest]], str]:
        """Block until a micro-batch is ready; ``(None, "closed")`` once the
        coalescer is closed AND drained. Safe for multiple worker threads."""
        with self._cond:
            while True:
                if self._queue:
                    if self._closed:
                        flush = "drain"
                    elif len(self._queue) >= self.max_batch:
                        flush = "size"
                    else:
                        deadline = self._queue[0].enqueue_t + self.max_delay_s
                        now = time.monotonic()
                        if now < deadline:
                            self._cond.wait(timeout=deadline - now)
                            continue
                        flush = "deadline"
                    n = min(len(self._queue), self.max_batch)
                    batch = [self._queue.popleft() for _ in range(n)]
                    self.stats.batches += 1
                    if flush == "size":
                        self.stats.size_flushes += 1
                    elif flush == "deadline":
                        self.stats.deadline_flushes += 1
                    else:
                        self.stats.drain_flushes += 1
                    return batch, flush
                if self._closed:
                    return None, "closed"
                self._cond.wait()

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
