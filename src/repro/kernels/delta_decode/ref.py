"""Pure-jnp oracle for batched delta decoding of columnar timestamp stripes."""
import jax.numpy as jnp


def delta_decode(deltas: jnp.ndarray, bases: jnp.ndarray) -> jnp.ndarray:
    """deltas: (B, N) int32 per-stripe deltas (deltas[:, 0] == 0 by codec
    construction); bases: (B,) int32 stripe base offsets.
    Returns (B, N) int32 decoded offsets-from-epoch-base."""
    return jnp.cumsum(deltas, axis=1, dtype=jnp.int32) + bases[:, None]
