"""Public jit'd wrapper for the jagged->padded materialization kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.jagged.jagged import jagged_to_padded_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def jagged_to_padded(values: jax.Array, offsets: jax.Array, max_len: int
                     ) -> jax.Array:
    """values (N, D) + offsets (B+1,) -> (B, max_len, D), right-aligned.

    Front-pads values by max_len zero rows so the kernel's fixed-size DMA
    window is always in-bounds; lane-pads D to a multiple of 128."""
    n, d = values.shape
    dp = (128 - d % 128) % 128
    v = jnp.pad(values, ((max_len, 0), (0, dp)))
    out = jagged_to_padded_kernel(v, offsets.astype(jnp.int32), max_len,
                                  interpret=not _on_tpu())
    return out[:, :, :d]
