"""Serving driver: two-tower retrieval with batched requests.

Builds the candidate index once (item-tower forward over the corpus), then
serves batched user requests: UIH is materialized through the VLM pipeline at
request time (short projection — the 'model C' tenant), the user tower embeds
it, and retrieval scores the full corpus with one batched dot product.

Run:  PYTHONPATH=src python examples/serve_retrieval.py [--requests 512]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core.projection import TenantProjection
from repro.core.simulation import ProductionSim, SimConfig
from repro.dpp.featurize import FeatureSpec
from repro.dpp.worker import DPPWorker
from repro.models import recsys as R

CORPUS = 4_096
SEQ_LEN = 24
BATCH = 64


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=512)
    args = ap.parse_args()

    cfg = R.TwoTowerConfig(name="serve", embed_dim=32, tower_mlp=(64, 32),
                           item_vocab=CORPUS, user_vocab=1_024,
                           uih_len=SEQ_LEN, compute_dtype=jnp.float32)
    params = R.init_two_tower(jax.random.PRNGKey(0), cfg)

    # --- offline: build the candidate index (item tower over the corpus) ---
    item_fwd = jax.jit(lambda p, ids: R.two_tower_item(p, ids, cfg))
    index = item_fwd(params, jnp.arange(CORPUS, dtype=jnp.int32))
    print(f"candidate index: {index.shape} ({index.nbytes/1e6:.1f} MB)")

    # --- online: VLM pipeline feeds the user tower ---
    sim = ProductionSim(SimConfig(
        stream=ev.StreamConfig(n_users=64, n_items=CORPUS, days=4,
                               events_per_user_day_mean=40.0, seed=1),
        stripe_len=32, requests_per_user_day=4, seed=1))
    sim.run_days(3, capture_reference=False)
    tenant = TenantProjection("retrieval", seq_len=SEQ_LEN,
                              feature_groups=("core",),
                              traits_per_group={"core": ("timestamp", "item_id")})
    spec = FeatureSpec(seq_len=SEQ_LEN, uih_traits=("item_id",))
    mat = sim.materializer(validate_checksum=False)
    mat.window_cache_size = 256
    worker = DPPWorker(mat, tenant, spec, sim.schema)

    user_fwd = jax.jit(lambda p, uid, ids, mask: R.two_tower_user(
        p, uid, ids, mask, cfg))

    examples = (sim.examples * (args.requests // len(sim.examples) + 1))[
        : args.requests]
    served = 0
    topk_acc = []
    t0 = time.perf_counter()
    for lo in range(0, len(examples), BATCH):
        reqs = examples[lo : lo + BATCH]
        feats = worker.process(reqs)             # request-time materialization
        u = user_fwd(params,
                     jnp.asarray(feats["user_id"] % cfg.user_vocab, jnp.int32),
                     jnp.asarray(feats["uih_item_id"] % CORPUS, jnp.int32),
                     jnp.asarray(feats["uih_mask"]))
        scores = u @ index.T                     # (B, CORPUS)
        top = jax.lax.top_k(scores, 10)[1]
        top.block_until_ready()
        served += len(reqs)
        topk_acc.append(np.asarray(top))
    dt = time.perf_counter() - t0
    print(f"served {served} requests in {dt:.2f}s -> {served/dt:.0f} QPS "
          f"(batch={BATCH}, corpus={CORPUS})")
    print(f"immutable-store scans: {mat.immutable.stats.requests}, "
          f"bytes: {mat.immutable.stats.bytes_scanned/1e6:.2f} MB")
    print(f"sample top-10 for request 0: {topk_acc[0][0].tolist()}")


if __name__ == "__main__":
    main()
