"""Refreshable item-tower candidate index + batched top-k (DESIGN.md §14.3).

The item side of two-tower retrieval is embarrassingly precomputable: the
item tower depends only on model parameters, so serving keeps the full
corpus's item embeddings as one dense ``[N, d]`` matrix and answers a request
batch with a single ``scores = U @ V.T`` + ``jax.lax.top_k``. ``refresh``
recomputes the matrix from a (new) parameter set and swaps it atomically
under a lock — in-flight ``top_k`` calls finish against the matrix they
grabbed, the next batch sees the new one (the serving analogue of a
generation flip, and emitted as a ``serve_index_refresh`` event).

``top_k`` jit-compiles one scorer per requested ``k`` (k is a static shape
argument) and reuses it for every subsequent batch of the same shape.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import recsys as R


@dataclasses.dataclass
class IndexStats:
    refreshes: int = 0      # full item-tower recomputes + atomic swaps
    queries: int = 0        # top_k batch calls answered
    scored_rows: int = 0    # user rows scored across all queries
    refresh_s: float = 0.0  # cumulative wall seconds spent refreshing


class CandidateIndex:
    """Dense item-embedding matrix over a fixed candidate corpus."""

    def __init__(self, cfg: R.TwoTowerConfig,
                 item_ids: Optional[np.ndarray] = None,
                 telemetry=None, batch_size: int = 8192):
        self.cfg = cfg
        self.item_ids = (np.arange(cfg.item_vocab, dtype=np.int64)
                         if item_ids is None
                         else np.asarray(item_ids, np.int64))
        self.telemetry = telemetry
        self.batch_size = batch_size
        self.version = 0            # bumped on every refresh; 0 = never built
        self.stats = IndexStats()
        self._lock = threading.Lock()
        self._emb = None            # device [N, d], L2-normalized rows
        self._item_fn = jax.jit(lambda p, ids: R.two_tower_item(p, ids, cfg))
        self._topk_fns: Dict[int, any] = {}

    def __len__(self) -> int:
        return len(self.item_ids)

    def refresh(self, params) -> int:
        """Recompute every candidate's item-tower embedding from ``params``
        and atomically publish the new matrix. Returns the new version."""
        t0 = time.monotonic()
        chunks = []
        for lo in range(0, len(self.item_ids), self.batch_size):
            ids = jnp.asarray(self.item_ids[lo:lo + self.batch_size])
            chunks.append(self._item_fn(params, ids))
        emb = jnp.concatenate(chunks, axis=0) if len(chunks) > 1 else chunks[0]
        emb.block_until_ready()
        with self._lock:
            self._emb = emb
            self.version += 1
            version = self.version
            self.stats.refreshes += 1
            self.stats.refresh_s += time.monotonic() - t0
        if self.telemetry is not None:
            self.telemetry.events.emit(
                "serve_index_refresh", version=version,
                items=len(self.item_ids))
        return version

    def embeddings(self) -> np.ndarray:
        """Host copy of the current matrix (tests / report tooling)."""
        with self._lock:
            emb = self._emb
        if emb is None:
            raise RuntimeError("candidate index never refreshed")
        return np.asarray(emb)

    def top_k(self, user_emb: np.ndarray,
              k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Score ``[B, d]`` user embeddings against the corpus; returns
        ``(item_ids [B, k], scores [B, k])`` sorted best-first."""
        with self._lock:
            emb = self._emb
        if emb is None:
            raise RuntimeError(
                "candidate index never refreshed; call refresh(params) first")
        k = min(k, len(self.item_ids))
        fn = self._topk_fns.get(k)
        if fn is None:
            fn = jax.jit(
                lambda u, e: jax.lax.top_k(
                    (u @ e.T).astype(jnp.float32), k))
            self._topk_fns[k] = fn
        scores, idx = fn(jnp.asarray(user_emb), emb)
        self.stats.queries += 1
        self.stats.scored_rows += int(user_emb.shape[0])
        return self.item_ids[np.asarray(idx)], np.asarray(scores)
