"""Decoder-only transformer LM (GQA / MLA attention, dense / MoE FFN).

Layer parameters are *stacked* along a leading layer axis and the blocks run
under ``jax.lax.scan`` (+ optional remat), keeping the HLO size independent of
depth — essential for 512-device dry-run compiles. Cross-entropy is computed
in sequence chunks so (B, S, vocab) logits are never fully materialized.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.moe import MoEConfig, init_moe, moe_ffn

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    attention: str = "gqa"           # "gqa" | "mla"
    qk_norm: bool = False
    rope_theta: float = 1e6
    moe: Optional[MoEConfig] = None  # None = dense FFN
    # MLA geometry (attention == "mla")
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # execution
    compute_dtype: Any = jnp.bfloat16
    q_chunk: int = 512
    loss_chunk: int = 512
    remat: bool = True
    scan_layers: bool = True
    unroll_scans: bool = False

    @property
    def attn_cfg(self) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            qk_norm=self.qk_norm, rope_theta=self.rope_theta,
            q_chunk=self.q_chunk, unroll=self.unroll_scans,
        )

    @property
    def mla_cfg(self) -> L.MLAConfig:
        return L.MLAConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            kv_lora_rank=self.kv_lora_rank, qk_nope_dim=self.qk_nope_dim,
            qk_rope_dim=self.qk_rope_dim, v_head_dim=self.v_head_dim,
            rope_theta=self.rope_theta, q_chunk=self.q_chunk,
            unroll=self.unroll_scans,
        )

    def param_count(self) -> int:
        leaves = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), self))
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(leaves))

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k + shared experts count)."""
        total = self.param_count()
        if self.moe is None:
            return total
        e, k = self.moe.n_experts, self.moe.top_k
        expert_p = self.n_layers * (
            self.moe.n_experts * (3 * self.d_model * self.moe.d_ff)
        )
        active_expert_p = expert_p * k // e
        return total - expert_p + active_expert_p


def _init_block(key, cfg: TransformerConfig) -> Params:
    k1, k2 = jax.random.split(key)
    if cfg.attention == "mla":
        attn = L.init_mla(k1, cfg.mla_cfg)
    else:
        attn = L.init_gqa(k1, cfg.attn_cfg)
    if cfg.moe is not None:
        ffn = init_moe(k2, cfg.d_model, cfg.moe)
    else:
        ffn = L.init_swiglu(k2, cfg.d_model, cfg.d_ff)
    return {
        "attn": attn,
        "ffn": ffn,
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
    }


def init(key, cfg: TransformerConfig) -> Params:
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(layer_keys)  # stacked (L, ...)
    return {
        "embed": L._init(k_emb, (cfg.vocab, cfg.d_model), scale=0.02),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "unembed": L._init(k_out, (cfg.vocab, cfg.d_model), scale=0.02),
    }


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_fwd(cfg: TransformerConfig, mesh, data_axes, h, block, positions):
    hn = L.rms_norm(h, block["ln1"])
    if cfg.attention == "mla":
        attn_out = L.mla_attention_train(block["attn"], hn, positions, cfg.mla_cfg)
    else:
        attn_out = L.gqa_attention(block["attn"], hn, positions, cfg.attn_cfg)
    h = h + attn_out
    hn = L.rms_norm(h, block["ln2"])
    if cfg.moe is not None:
        ffn_out = moe_ffn(block["ffn"], hn, cfg.moe, mesh=mesh,
                          data_axes=data_axes)
    else:
        ffn_out = L.swiglu(block["ffn"], hn)
    return h + ffn_out


def hidden_states(params: Params, tokens: jax.Array, cfg: TransformerConfig,
                  mesh=None, data_axes=("data",)) -> jax.Array:
    b, s = tokens.shape
    dt = cfg.compute_dtype
    h = params["embed"].astype(dt)[tokens]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    body = partial(_block_fwd, cfg, mesh, data_axes)
    if cfg.remat:
        body = jax.checkpoint(body, static_argnums=())
    if cfg.scan_layers:
        def scan_fn(carry, block):
            return body(carry, block, positions), None
        h, _ = jax.lax.scan(scan_fn, h, params["blocks"])
    else:
        for i in range(cfg.n_layers):
            block = jax.tree.map(lambda x: x[i], params["blocks"])
            h = body(h, block, positions)
    return L.rms_norm(h, params["final_norm"])


def loss_fn(params: Params, tokens: jax.Array, targets: jax.Array,
            cfg: TransformerConfig, mesh=None, data_axes=("data",)) -> jax.Array:
    """Mean next-token cross-entropy; vocab projection in sequence chunks."""
    h = hidden_states(params, tokens, cfg, mesh, data_axes)  # (B, S, D)
    b, s, d = h.shape
    dt = cfg.compute_dtype
    unemb = params["unembed"].astype(dt)
    lc = min(cfg.loss_chunk, s)
    n_chunks = s // lc if s % lc == 0 else -1
    if n_chunks == -1:                                    # ragged: no chunking
        logits = h @ unemb.T
        return _xent(logits, targets)
    hs = h.reshape(b, n_chunks, lc, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, n_chunks, lc).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        hi, ti = inp
        logits = hi @ unemb.T                             # (B, lc, V)
        return carry + _xent_sum(logits, ti), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hs, ts),
                            unroll=cfg.unroll_scans)
    return total / (b * s)


def _xent_sum(logits: jax.Array, targets: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold)


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    return _xent_sum(logits, targets) / targets.size


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: TransformerConfig, batch: int, max_len: int,
                  dtype=None) -> Dict[str, jax.Array]:
    dt = dtype or cfg.compute_dtype
    nl = cfg.n_layers
    if cfg.attention == "mla":
        return {
            "c_kv": jnp.zeros((nl, batch, max_len, cfg.kv_lora_rank), dt),
            "k_pe": jnp.zeros((nl, batch, max_len, cfg.qk_rope_dim), dt),
        }
    return {
        "k": jnp.zeros((nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
    }


def prefill(params: Params, tokens: jax.Array, cfg: TransformerConfig,
            mesh=None, data_axes=("data",)
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Process a full prompt; return last-position logits + a populated cache.

    The cache is captured layer-by-layer inside the scan (stacked (L, ...))."""
    b, s = tokens.shape
    dt = cfg.compute_dtype
    h = params["embed"].astype(dt)[tokens]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(h, block):
        hn = L.rms_norm(h, block["ln1"])
        if cfg.attention == "mla":
            c_kv, k_pe = L.mla_new_cache_entries(block["attn"], hn, positions,
                                                 cfg.mla_cfg)
            attn_out = L.mla_attention_train(block["attn"], hn, positions,
                                             cfg.mla_cfg)
            cache = {"c_kv": c_kv, "k_pe": k_pe}
        else:
            q, k, v = L._qkv(block["attn"], hn, positions, cfg.attn_cfg)
            out = L._attend_chunked(q, k, v, positions, positions, None, True,
                                    cfg.q_chunk, cfg.unroll_scans)
            attn_out = out.reshape(b, s, cfg.n_heads * cfg.head_dim) @ \
                block["attn"]["wo"].astype(dt)
            cache = {"k": k, "v": v}
        h = h + attn_out
        hn = L.rms_norm(h, block["ln2"])
        if cfg.moe is not None:
            h = h + moe_ffn(block["ffn"], hn, cfg.moe, mesh=mesh,
                            data_axes=data_axes)
        else:
            h = h + L.swiglu(block["ffn"], hn)
        return h, cache

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        h, caches = jax.lax.scan(body, h, params["blocks"])
    else:
        cache_list = []
        for i in range(cfg.n_layers):
            block = jax.tree.map(lambda x: x[i], params["blocks"])
            h, c = body(h, block)
            cache_list.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
    h = L.rms_norm(h, params["final_norm"])
    logits = h[:, -1, :] @ params["unembed"].astype(dt).T
    return logits, caches


def decode_step(params: Params, cache: Dict[str, jax.Array],
                next_token: jax.Array,   # (B,) int32
                position: jax.Array,     # (B,) current position to write
                cfg: TransformerConfig,
                mesh=None, data_axes=("data",)
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One token of autoregressive decode against a (large) KV cache."""
    b = next_token.shape[0]
    dt = cfg.compute_dtype
    h = params["embed"].astype(dt)[next_token][:, None, :]  # (B, 1, D)
    pos = position[:, None]

    def body(h, inp):
        block, layer_cache = inp
        hn = L.rms_norm(h, block["ln1"])
        if cfg.attention == "mla":
            c_new, pe_new = L.mla_new_cache_entries(block["attn"], hn, pos,
                                                    cfg.mla_cfg)

            def upd(cachearr, entry, p):
                return jax.lax.dynamic_update_slice_in_dim(cachearr, entry, p, 0)

            c_kv = jax.vmap(upd)(layer_cache["c_kv"], c_new, position)
            k_pe = jax.vmap(upd)(layer_cache["k_pe"], pe_new, position)
            skv = c_kv.shape[1]
            kv_mask = jnp.arange(skv)[None, :] <= pos
            attn_out = L.mla_attention_decode(block["attn"], hn, pos, c_kv,
                                              k_pe, kv_mask, cfg.mla_cfg)
            new_cache = {"c_kv": c_kv, "k_pe": k_pe}
        else:
            attn_out, k_c, v_c = L.gqa_decode(block["attn"], hn, pos,
                                              layer_cache["k"],
                                              layer_cache["v"], cfg.attn_cfg)
            new_cache = {"k": k_c, "v": v_c}
        h = h + attn_out
        hn = L.rms_norm(h, block["ln2"])
        if cfg.moe is not None:
            h = h + moe_ffn(block["ffn"], hn, cfg.moe, mesh=mesh,
                            data_axes=data_axes)
        else:
            h = h + L.swiglu(block["ffn"], hn)
        return h, new_cache

    if cfg.scan_layers:
        h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache))
    else:
        cache_list = []
        for i in range(cfg.n_layers):
            blk = jax.tree.map(lambda x: x[i], params["blocks"])
            lc = jax.tree.map(lambda x: x[i], cache)
            h, c = body(h, (blk, lc))
            cache_list.append(c)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
    h = L.rms_norm(h, params["final_norm"])
    logits = h[:, 0, :] @ params["unembed"].astype(dt).T
    return logits, new_cache
