"""Streaming training session (paper §3.2): event → gradient, one object.

``StreamingSession`` closes the loop the batch pipeline leaves open: a
``StreamingSource`` (optionally fronted by a ``BackfillCoordinator`` for the
batch→stream catch-up handoff) feeds micro-batches into the existing
``DPPWorkerPool`` → ``RebatchingClient`` data plane, and the session itself
speaks the client's feed protocol (``get_full_batch`` / ``recycle`` /
``record_train_step`` / ``stats``) so a ``Trainer`` or ``DevicePrefetcher``
consumes it exactly like a batch feed.

Protocol duties handled here:

  * **lease release**: after a worker materializes+featurizes a micro-batch,
    its examples' generation leases are released (``TrainingExampleStream.ack``)
    — the store may then GC superseded generations ("GC once drained");
  * **freshness**: each example's publish wall clock rides from the stream
    through the source into a FIFO settlement queue; each
    ``record_train_step`` call (the trainer's step-completion signal, which a
    ``DevicePrefetcher`` delegates through) settles the OLDEST delivered
    batch's rows into event→gradient latency samples — correct even when the
    prefetcher pulls ``depth`` batches ahead of the gradient (FIFO
    row-matching is exact at full-batch granularity, approximate at row
    granularity under the reshuffle — documented, and irrelevant to the
    mean). A consumer that never records steps still gets all samples
    settled, late, at ``join()``.

Shutdown: close the stream; the source drains, the feeder finishes, workers
exit, the pool closes the client, the trainer sees end-of-stream. ``join()``
then surfaces any worker/feeder error.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.core.materialize import ChecksumMismatch
from repro.dpp.client import RebatchingClient
from repro.dpp.elastic import DPPWorkerPool, ElasticController
from repro.dpp.worker import DPPWorker, WorkerPlan
from repro.storage.stream import TrainingExampleStream, Warehouse
from repro.streaming.backfill import BackfillCoordinator, ReplayFilter
from repro.streaming.source import MicroBatchConfig, StreamingSource


@dataclasses.dataclass
class FreshnessStats:
    batches_delivered: int = 0
    rows_settled: int = 0
    samples: int = 0
    event_to_gradient_s_sum: float = 0.0
    event_to_gradient_s_max: float = 0.0

    @property
    def mean_event_to_gradient_s(self) -> float:
        if not self.samples:
            return 0.0
        return self.event_to_gradient_s_sum / self.samples


class _AckingWorker:
    """Wraps a ``DPPWorker``: after a micro-batch is materialized+featurized,
    release its generation leases and queue its publish clocks for freshness
    settlement. Duck-compatible with ``DPPWorkerPool`` (stats/process*).

    A ``ChecksumMismatch``/``StaleGeneration`` from the materializer is the
    protocol's *drop this example* signal (its window genuinely changed, e.g.
    right-to-delete): the worker triages the micro-batch per example, drops
    the offenders (counted in ``session.stale_dropped``, leases released),
    and featurizes the survivors — it must NOT die and take the session down.
    """

    def __init__(self, inner, session: "StreamingSession"):
        self._inner = inner
        self._session = session

    @property
    def stats(self):
        return self._inner.stats

    @property
    def materializer(self):
        return self._inner.materializer

    def process(self, examples):
        return self._process(examples, self._inner.process)

    def process_jagged(self, examples):
        return self._process(examples, self._inner.process_jagged)

    def _process(self, examples, fn):
        kept = list(examples)
        dropped_all: List = []
        while True:
            try:
                out = fn(kept) if kept else None
                break
            except ChecksumMismatch:
                kept, dropped = self._triage(kept)
                dropped_all.extend(dropped)
                if not dropped:
                    # fn raised but per-example triage passed everything: a
                    # flip landed between triage and the batch re-run. Drop
                    # the remainder rather than loop (or die) — rare double
                    # race, and dropping is always protocol-safe.
                    dropped_all.extend(kept)
                    kept = []
        self._session._on_item_done(kept, dropped=dropped_all, item=examples)
        return out

    def _triage(self, examples):
        keep, dropped = [], []
        mat, projection = self._inner.materializer, self._inner.projection
        for exm in examples:
            try:
                mat.materialize(exm, projection)
                keep.append(exm)
            except ChecksumMismatch:
                dropped.append(exm)
        return keep, dropped


class StreamingSession:
    def __init__(
        self,
        stream: TrainingExampleStream,
        make_worker,
        *,
        full_batch_size: int,
        micro_batch: Optional[MicroBatchConfig] = None,
        n_workers: int = 2,
        controller: Optional[ElasticController] = None,
        shuffle_seed: Optional[int] = 0,
        buffer_batches: int = 4,
        backfill_from: Optional[Warehouse] = None,
        jagged: bool = True,
        ordered: bool = False,
        max_item_retries: int = 0,
        retry_backoff=None,
        emit_seq_start: int = 0,
        resume_filters: Optional[List[ReplayFilter]] = None,
        backfill_start_hour: Optional[int] = None,
        backfill_end_hour: Optional[int] = None,
    ):
        self.source = StreamingSource(stream, micro_batch)
        mb = self.source.cfg.max_examples
        self.coordinator = (
            BackfillCoordinator(backfill_from, self.source, micro_batch=mb,
                                start_hour=backfill_start_hour,
                                end_hour=backfill_end_hour,
                                resume_filters=resume_filters or ())
            if backfill_from is not None else None
        )
        self.client = RebatchingClient(full_batch_size,
                                       buffer_batches=buffer_batches,
                                       shuffle_seed=shuffle_seed,
                                       emit_seq_start=emit_seq_start)
        self.freshness = FreshnessStats()
        self._pub_q: Deque[float] = collections.deque()
        self._pq_lock = threading.Lock()
        self._delivered: Deque[int] = collections.deque()  # rows per pulled batch
        self._n_workers = n_workers
        if isinstance(make_worker, WorkerPlan):
            # a spec-compiled plan (declarative read path): build the
            # per-thread worker factory from it
            plan = make_worker
            make_worker = lambda: DPPWorker.from_plan(plan)  # noqa: E731
        self.ordered = ordered
        self._resume_filters = list(resume_filters or [])
        # placement-order ledger (ordered mode): per PLACED row, its
        # ``(request_id, coord_pos, is_replay)`` — ``coord_pos`` is the count
        # of COORDINATOR-emitted rows consumed up to and including this row
        # (triage-dropped and abandoned rows count as consumed: protocol drops
        # stay dropped across a resume). Feed.checkpoint maps "rows trained"
        # to the replay-prefix cursor / live watermark through it; trimmed
        # lazily at checkpoint time.
        self._ledger: Deque[tuple] = collections.deque()
        self._ledger_base = 0          # placement position of _ledger[0]
        self._coord_consumed = 0       # coordinator rows placed or skipped
        self._ledger_lock = threading.Lock()
        # worker-completion-time survivor indices, keyed by work-item id:
        # _AckingWorker may drop stale examples, and the ledger must record
        # exactly the rows that were PLACED at their in-item offsets (the
        # pool's on_place hands back the original item, which stays
        # referenced until placement)
        self._kept_by_item: Dict[int, List[tuple]] = {}
        self.abandoned = 0             # examples dropped by crash recovery
        # resume bookkeeping only when a checkpoint is actually producible
        # (ordered + a durable warehouse leg) — a live-only ordered session
        # must not accrete a ledger nothing ever trims
        track = ordered and self.coordinator is not None
        self.pool = DPPWorkerPool(
            lambda: _AckingWorker(make_worker(), self),
            self.client, n_workers=n_workers, controller=controller,
            jagged=jagged, ordered=ordered, max_item_retries=max_item_retries,
            retry_backoff=retry_backoff,
            on_place=self._on_place if track else None,
            on_abandon=self._on_abandon if max_item_retries > 0 else None,
            on_skip=self._on_skip if track else None,
        )
        self._started = False
        self._joiner: Optional[threading.Thread] = None
        self._join_error: List[BaseException] = []
        # examples dropped by stale-generation triage (window truly changed)
        self.stale_dropped = 0

    # -- telemetry ---------------------------------------------------------------
    @property
    def telemetry(self):
        return self.client.telemetry

    @telemetry.setter
    def telemetry(self, tel) -> None:
        """Attach a ``repro.obs.Telemetry`` to every stage the session owns
        (client emit spans, pool item spans + worker events, source
        reconnects, backfill flip). Set BEFORE ``start()``."""
        self.client.telemetry = tel
        self.pool.telemetry = tel
        self.source.telemetry = tel
        if self.coordinator is not None:
            self.coordinator.telemetry = tel

    # -- lifecycle --------------------------------------------------------------
    def start(self) -> "StreamingSession":
        """Start draining. A background joiner waits out the pool so the
        client receives its end-of-stream sentinel the moment the stream
        drains — the consumer must never be the one who has to call
        ``pool.join()`` (it would deadlock waiting for batches meanwhile)."""
        if not self._started:
            self._started = True
            feed = self.coordinator or self.source
            # bound the in-flight micro-batches: backpressure keeps a fast
            # backfill replay from materializing the whole warehouse at once
            self.pool.start_stream(feed.micro_batches(),
                                   max_buffered=4 * self._n_workers + 8)

            def joiner() -> None:
                try:
                    self.pool.join()   # closes the client even on failure
                except BaseException as e:
                    self._join_error.append(e)

            self._joiner = threading.Thread(target=joiner, daemon=True,
                                            name="streaming-joiner")
            self._joiner.start()
        return self

    def join(self) -> None:
        """Wait for the drain (stream closed + queue empty) and re-raise any
        worker/feeder failure. Call only after consuming the whole stream —
        a consumer that walked away early must use ``stop()`` instead (the
        workers are blocked on the bounded client queue and need a drainer)."""
        self._settle_all()
        if self._joiner is not None:
            self._joiner.join()
        if self._join_error:
            raise self._join_error[0]

    def stop(self, timeout: Optional[float] = None) -> None:
        """Abandon training mid-stream: keep draining (and recycling) full
        batches WITHOUT training until the pipeline shuts down, then join.
        This unblocks workers parked on the bounded client queue after the
        trainer exits early (``max_wall_s`` / ``max_steps``). Termination
        still requires the producer to close the stream; ``timeout`` bounds
        the wait (on expiry the daemon threads are simply abandoned)."""
        if not self._started or self._joiner is None:
            return
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        while self._joiner.is_alive():
            if deadline is not None and time.perf_counter() > deadline:
                return
            b = self.client.get_full_batch(timeout=0.05, record=False)
            if b is not None:
                self.client.recycle(b)
        self.join()

    # -- worker-side callbacks ---------------------------------------------------
    def _on_item_done(self, examples, dropped=(), item=None) -> None:
        walls: List[float] = []
        for exm in examples:
            w = self.source.pop_pub_wall(exm.request_id)
            if w is not None:
                walls.append(w)
        if walls:
            with self._pq_lock:
                self._pub_q.extend(walls)
        self.source.ack(examples)
        if item is not None and self.ordered and self.coordinator is not None:
            # remember which rows survived triage AND their in-item offsets:
            # placement happens later (in item order) and the resume cursor
            # must count triage-dropped rows as consumed coordinator rows
            kept_ids = {e.request_id for e in examples}
            self._kept_by_item[id(item)] = [
                (e.request_id, idx) for idx, e in enumerate(item)
                if e.request_id in kept_ids]
        if dropped:
            # stale-drop path: release leases + clocks, but contribute no
            # freshness samples (these rows never reach a gradient)
            self.stale_dropped += len(dropped)
            self.source.ack(dropped)

    def _on_place(self, item) -> None:
        """Pool placer callback (ordered mode): rows of ``item`` just entered
        the client, in work-item sequence order."""
        kept = self._kept_by_item.pop(id(item), None)
        if kept is None:
            kept = [(e.request_id, idx) for idx, e in enumerate(item)]
        st = self.coordinator.stats if self.coordinator is not None else None
        with self._ledger_lock:
            base = self._coord_consumed
            # a replay item's rows were counted in warehouse_examples BEFORE
            # emission (and all replay rows are emitted, hence placed, before
            # any live row), so this classification cannot race wrong
            replay = st is not None and base < st.warehouse_examples
            self._ledger.extend((rid, base + idx + 1, replay)
                                for rid, idx in kept)
            self._coord_consumed = base + len(item)

    def _trim_ledger_locked(self, trained_rows: int) -> None:
        """Drop ledger entries before the LAST trained row (never needed
        again). Call with ``_ledger_lock`` held."""
        while self._ledger_base < trained_rows - 1 and self._ledger:
            self._ledger.popleft()
            self._ledger_base += 1

    def trim_ledger(self, trained_rows: int) -> None:
        """Steady-state ledger bound: the owning Feed calls this per trained
        batch, so ledger size tracks the in-flight window even when the
        trainer never checkpoints (no ckpt_dir)."""
        with self._ledger_lock:
            self._trim_ledger_locked(trained_rows)

    def _on_skip(self, item) -> None:
        """Pool placer callback for an ABANDONED item reaching its placement
        turn: its rows consumed coordinator positions without being placed
        (dropped by protocol — a resume must not shift later rows' cursor)."""
        with self._ledger_lock:
            self._coord_consumed += len(item)

    def _on_abandon(self, item, exc) -> None:
        """Pool crash-recovery callback: an item exhausted its retries. Drop
        its examples (protocol-safe, like a stale drop) and release their
        generation leases so a crashed worker can never leak a pinned
        generation."""
        self._kept_by_item.pop(id(item), None)
        self.source.ack(item)
        self.abandoned += len(item)
        self.pool.record_lease_recoveries(len(item))

    # -- feed protocol (Trainer / DevicePrefetcher face) --------------------------
    @property
    def stats(self):
        return self.client.stats

    @property
    def ended(self) -> bool:
        return self.client.ended

    @property
    def drained(self) -> bool:
        """Feed-protocol drain signal: the end-of-stream sentinel reached the
        consumer (stream closed, every batch delivered)."""
        return self.client.ended

    def close(self, timeout: Optional[float] = None) -> None:
        """Feed-protocol shutdown: drain the remaining stream untrained and
        join (see ``stop``)."""
        self.stop(timeout=timeout)

    def get_full_batch(self, timeout: Optional[float] = None,
                       record: bool = True):
        self.start()
        out = self.client.get_full_batch(timeout=timeout, record=record)
        if out is not None:
            self.freshness.batches_delivered += 1
            with self._pq_lock:
                self._delivered.append(len(next(iter(out.values()))))
        return out

    def _settle_one(self) -> None:
        """Convert the oldest delivered batch's publish clocks into
        event→gradient samples (FIFO at full-batch granularity)."""
        now = time.perf_counter()
        fr = self.freshness
        with self._pq_lock:
            if not self._delivered:
                return
            rows = self._delivered.popleft()
            take = min(rows, len(self._pub_q))
            for _ in range(take):
                dt = now - self._pub_q.popleft()
                fr.event_to_gradient_s_sum += dt
                if dt > fr.event_to_gradient_s_max:
                    fr.event_to_gradient_s_max = dt
                fr.samples += 1
            fr.rows_settled += rows

    def _settle_all(self) -> None:
        while self._delivered:
            self._settle_one()

    def recycle(self, batch: Dict[str, np.ndarray]) -> None:
        self.client.recycle(batch)

    def record_train_step(self, seconds: float) -> None:
        # the trainer (directly, or via DevicePrefetcher delegation) just
        # finished a step: the oldest delivered batch's gradient is applied
        self._settle_one()
        self.client.record_train_step(seconds)

    def __iter__(self):
        while True:
            b = self.get_full_batch()
            if b is None:
                return
            yield b

    # -- crash-safe resume -------------------------------------------------------
    def checkpoint_state(self, trained_rows: int) -> Dict:
        """Minimal cursor for exactly-once resume after ``trained_rows`` rows
        reached a gradient (``Feed.checkpoint`` supplies the count from its
        delivered/trained FIFO).

        Requires ``ordered`` placement and a backfill coordinator: the
        warehouse leg of the bifurcated pipeline is the durable replay source,
        and in-order placement makes "rows trained" identify an exact prefix
        of (replay order ++ live id order). The returned filter chain is this
        session's inherited filters plus one new ``ReplayFilter``:

        * ``skip_rows`` — COORDINATOR replay rows covered by training: the
          coord position of the last trained replay row, counting any
          triage-dropped / abandoned rows interleaved before it (protocol
          drops stay dropped across a resume, so they are "covered" too);
          once a live row has trained, every emitted replay row is covered
          and ``skip_rows`` is the coordinator's full pre-triage count;
        * ``(drop_lo, drop_hi]`` — the live-trained request-id interval:
          ``drop_lo`` is the flip watermark (every kept live id exceeds it),
          ``drop_hi`` the id of the last trained row, read from the
          placement-order ledger. Live ids arrive monotonically (request_ids
          are allocated in arrival order), so the interval is exact."""
        if not self.ordered:
            raise ValueError(
                "streaming checkpoint requires ordered placement "
                "(StreamingSession(ordered=True) / DatasetSpec.ordered)")
        if self.coordinator is None:
            raise ValueError(
                "streaming checkpoint requires the warehouse backfill leg "
                "(StreamSource(backfill=True)) — the stream alone is not a "
                "durable replay source")
        st = self.coordinator.stats
        skip = 0
        lo = hi = -1
        if trained_rows > 0:
            with self._ledger_lock:
                self._trim_ledger_locked(trained_rows)
                idx = trained_rows - 1 - self._ledger_base
                if idx < 0 or idx >= len(self._ledger):
                    raise RuntimeError(
                        f"placement ledger out of sync: trained_rows="
                        f"{trained_rows}, base={self._ledger_base}, "
                        f"len={len(self._ledger)}")
                last_id, coord_pos, is_replay = self._ledger[idx]
            if is_replay:
                skip = coord_pos
            else:                    # live rows reached a gradient
                skip = st.warehouse_examples   # final: flip preceded any live
                lo = st.watermark
                hi = last_id
        new = ReplayFilter(skip_rows=skip, drop_lo=lo, drop_hi=hi)
        return {
            "filters": [f.to_state() for f in self._resume_filters]
                       + [new.to_state()],
            "replay_range": [self.coordinator.start_hour,
                             self.coordinator.end_hour],
            "watermark": st.watermark,
        }

    # -- introspection -----------------------------------------------------------
    def merged_worker_stats(self):
        return self.pool.merged_worker_stats()

    @property
    def backfill_stats(self):
        return self.coordinator.stats if self.coordinator is not None else None
