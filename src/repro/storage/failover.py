"""Per-node health tracking for the replicated store tier (DESIGN.md §12).

Two small, self-contained primitives the ``ShardedUIHStore`` failover
executor composes:

  * ``CircuitBreaker`` — consecutive-failure breaker per store node. CLOSED
    admits every request; ``threshold`` consecutive failures OPEN it, and an
    open breaker sheds load instantly (the failover executor skips straight
    to a replica instead of paying a timeout per request). After ``reset_s``
    the breaker HALF-OPENs and admits exactly ONE probe: success closes it,
    failure re-opens it (and restarts the reset clock).
  * ``LatencyTracker`` — a bounded window of recent node round-trip times,
    pooled tier-wide. ``quantile(q)`` is the hedging trigger: a request still
    in flight past the tier's q-quantile is presumed slow and a hedge fires
    at a replica. Hedging stays off until ``min_samples`` round-trips have
    been observed — an empty tracker must not hedge on noise.

Both are thread-safe; the breaker takes an injectable ``clock`` so its state
machine is unit-testable without sleeping.
"""
from __future__ import annotations

import bisect
import collections
import threading
import time
from typing import Deque, Optional

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """Consecutive-failure circuit breaker: open -> probe half-open -> close."""

    def __init__(self, threshold: int = 3, reset_s: float = 0.05,
                 clock=time.monotonic, listener=None):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = threshold
        self.reset_s = reset_s
        self._clock = clock
        self._lock = threading.Lock()
        self._state = CLOSED
        self._failures = 0          # consecutive failures while closed
        self._opened_at = 0.0
        self._probing = False       # half-open probe currently admitted
        self.opens = 0              # lifetime closed/half-open -> open count
        # Optional ``listener(old_state, new_state)`` invoked outside the
        # breaker lock on every state transition — the sharded store points
        # this at the control-plane event log (DESIGN.md §13).
        self.listener = listener

    def _notify(self, old: str, new: str) -> None:
        if self.listener is not None and old != new:
            self.listener(old, new)

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """May a request be sent to this node right now? An OPEN breaker
        transitions to HALF_OPEN once ``reset_s`` has elapsed and admits a
        single probe; further requests are shed until the probe resolves."""
        with self._lock:
            old = self._state
            if self._state == CLOSED:
                return True
            if self._state == OPEN:
                if self._clock() - self._opened_at >= self.reset_s:
                    self._state = HALF_OPEN
                    self._probing = True
                else:
                    return False
            elif self._probing:
                # HALF_OPEN: one probe at a time
                return False
            else:
                self._probing = True
                return True
        self._notify(old, HALF_OPEN)
        return True

    def record_success(self) -> None:
        with self._lock:
            old = self._state
            self._state = CLOSED
            self._failures = 0
            self._probing = False
        self._notify(old, CLOSED)

    def record_failure(self) -> bool:
        """Record a failed request; returns True when THIS failure opened the
        breaker (so the caller can count ``breaker_opens`` exactly once)."""
        with self._lock:
            old = self._state
            if self._state == HALF_OPEN:
                self._state = OPEN
                self._opened_at = self._clock()
                self._probing = False
                self.opens += 1
            elif self._state == OPEN:
                return False
            else:
                self._failures += 1
                if self._failures < self.threshold:
                    return False
                self._state = OPEN
                self._opened_at = self._clock()
                self.opens += 1
        self._notify(old, OPEN)
        return True

    def reset(self) -> None:
        """Administrative close (node recovered out-of-band)."""
        with self._lock:
            old = self._state
            self._state = CLOSED
            self._failures = 0
            self._probing = False
        self._notify(old, CLOSED)

    def __repr__(self) -> str:
        return (f"CircuitBreaker(state={self.state}, "
                f"threshold={self.threshold}, opens={self.opens})")


class LatencyTracker:
    """Bounded sliding window of round-trip latencies with quantile reads."""

    def __init__(self, window: int = 256, min_samples: int = 16):
        self.min_samples = min_samples
        self._lock = threading.Lock()
        self._samples: Deque[float] = collections.deque(maxlen=window)

    def record(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(seconds)

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._samples)

    def quantile(self, q: float) -> Optional[float]:
        """The q-quantile of the window, or None while the window holds
        fewer than ``min_samples`` observations (hedging must not trigger
        off a cold tracker)."""
        with self._lock:
            if len(self._samples) < max(self.min_samples, 1):
                return None
            ordered = sorted(self._samples)
        idx = min(len(ordered) - 1, max(0, int(q * len(ordered))))
        return ordered[idx]

    def observed_at_least(self, seconds: float) -> int:
        """How many window samples are >= ``seconds`` (introspection)."""
        with self._lock:
            ordered = sorted(self._samples)
        return len(ordered) - bisect.bisect_left(ordered, seconds)
