"""Pallas TPU kernel: jagged -> padded-dense (right-aligned) UIH batch
materialization — the device-side hot path of training-time late
materialization (paper §4.2).

TPU mapping: the jagged values stay in HBM (pl.ANY); each grid step b DMAs the
L-row window ending at ``offsets[b+1]`` (front-padded by the wrapper so the
window is always in-bounds) into a VMEM scratch, masks the invalid prefix, and
writes the (1, L, D) output block. One sequential DMA per row-block; D is
lane-padded to 128 by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(offsets_ref, values_ref, out_ref, scratch, sem, *, max_len):
    b = pl.program_id(0)
    end = offsets_ref[b + 1] + max_len        # +max_len: wrapper front-pad
    start = offsets_ref[b]
    ln = jnp.minimum(end - max_len - start, max_len)
    copy = pltpu.make_async_copy(
        values_ref.at[pl.ds(end - max_len, max_len), :], scratch, sem)
    copy.start()
    copy.wait()
    j = jax.lax.broadcasted_iota(jnp.int32, scratch.shape, 0)
    valid = j >= (max_len - ln)
    out_ref[0] = jnp.where(valid, scratch[...], jnp.zeros((), scratch.dtype))


@functools.partial(jax.jit, static_argnames=("max_len", "interpret"))
def jagged_to_padded_kernel(
    values_padded: jax.Array,   # (N + max_len, D): front-padded by wrapper
    offsets: jax.Array,         # (B+1,) int32
    max_len: int,
    interpret: bool = False,
) -> jax.Array:
    bp1 = offsets.shape[0]
    b = bp1 - 1
    d = values_padded.shape[1]
    kern = functools.partial(_kernel, max_len=max_len)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # offsets (scalar loads)
            pl.BlockSpec(memory_space=pl.ANY),       # jagged values in HBM
        ],
        out_specs=pl.BlockSpec((1, max_len, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, max_len, d), values_padded.dtype),
        scratch_shapes=[
            pltpu.VMEM((max_len, d), values_padded.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(offsets, values_padded)
