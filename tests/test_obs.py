"""Unified telemetry (DESIGN.md §13): metrics registry, pipeline spans,
control-plane event timeline, report CLI.

Covers the PR's acceptance spine:
  * registry semantics — monotone counters, additive gauges, fixed-bucket
    histograms with LatencyTracker-compatible quantiles, exact merges, the
    ``publish_dataclass`` naming rule, Prometheus text exposition;
  * span completeness under chaos — a 4-node r=2 replicated tier run through
    a combined fault plan (worker crash, compaction-during-scan race, node
    flap) at ``sample_every=1``: every emitted batch carries a complete,
    monotonically-ordered span chain; zero orphan item spans survive the
    drain; the report shows the breaker transition, the worker restart and
    the generation flip, and >= 90% of measured starvation is attributed to
    a named stage;
  * overhead guard — the span ops added per pipeline item at the DEFAULT
    sampling rate cost well under the 2% rows/s budget enforced (as an
    end-to-end paired measurement) by ``benchmarks/bench_feed.py``.
"""
import dataclasses
import json
import sys
import time
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT) not in sys.path:  # make `benchmarks.*` importable
    sys.path.insert(0, str(REPO_ROOT))

from conftest import make_sim
from repro.core.projection import TenantProjection
from repro.data import DatasetSpec, WarehouseSource, open_feed, resume_fingerprint
from repro.dpp.featurize import FeatureSpec
from repro.obs import DEFAULT_SAMPLE_EVERY, EventLog, MetricsRegistry, Telemetry
from repro.obs.registry import Counter, Gauge, Histogram, publish_dataclass
from repro.obs.report import render_report
from repro.obs.spans import SpanTracker, critical_path, current_span
from repro.testing import FaultPlan, FaultSpec, wrap_sim

TENANT = TenantProjection(
    "t", 16, ("core",),
    traits_per_group={"core": ("timestamp", "item_id", "action_type")})
FEATURES = FeatureSpec(seq_len=16, uih_traits=("item_id", "action_type"))


def _spec(source, **kw):
    kw.setdefault("batch_size", 8)
    kw.setdefault("base_batch_size", 4)
    kw.setdefault("n_workers", 2)
    kw.setdefault("prefetch_depth", 0)
    # no cross-batch window cache: every work item issues at least one store
    # scan, so the fault schedule's scan ticks are always reached AND every
    # sampled item span carries a scan stage
    kw.setdefault("window_cache_size", 0)
    return DatasetSpec(tenant=TENANT, source=source, features=FEATURES, **kw)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_monotone_set_total_and_merge():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    c.set_total(10.0)
    assert c.value == 10.0
    c.set_total(4.0)          # republishing an older snapshot cannot regress
    assert c.value == 10.0
    other = Counter()
    other.inc(5.0)
    c.merge_from(other)       # counters add across workers
    assert c.value == 15.0


def test_gauge_last_write_and_additive_merge():
    g = Gauge()
    g.set(7.0)
    g.set(3.0)
    assert g.value == 3.0
    g.inc()
    g.dec(2.0)
    assert g.value == 2.0
    other = Gauge()
    other.set(5.0)
    g.merge_from(other)       # per-worker queue depths sum tier-wide
    assert g.value == 7.0


def test_histogram_bucket_quantiles_and_merge():
    h = Histogram(buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5):
        h.observe(v)
    assert h.count == 4
    assert h.sum == pytest.approx(0.605)
    # interpolated quantiles stay inside the populated buckets
    assert 0.0 < h.quantile(0.5) <= 0.1
    assert 0.1 < h.quantile(0.99) <= 1.0
    snap = h.to_dict()
    assert snap["count"] == 4 and snap["min"] == 0.005 and snap["max"] == 0.5
    assert snap["p50"] is not None and snap["p99"] is not None
    other = Histogram(buckets=(0.01, 0.1, 1.0))
    other.observe(0.05)
    h.merge_from(other)       # bucket vectors add exactly
    assert h.count == 5
    with pytest.raises(ValueError):
        h.merge_from(Histogram(buckets=(0.5, 5.0)))


def test_histogram_window_latency_tracker_compat():
    # window mode serves the legacy LatencyTracker contract: None below
    # min_samples, index-method quantile over the sorted window
    h = Histogram(window=64, min_samples=5)
    for v in (0.1, 0.2, 0.3):
        h.record(v)           # LatencyTracker-compatible alias
    assert h.quantile(0.5) is None
    h.record(0.4)
    h.record(0.5)
    assert h.quantile(0.5) == 0.3
    assert h.quantile(0.99) == 0.5
    assert h.observed_at_least(0.3) == 3


def test_family_label_validation_and_kind_conflicts():
    reg = MetricsRegistry()
    fam = reg.counter("repro_test_ops_total", labels=("node",))
    fam.labels(node=1).inc()
    fam.labels(node=1).inc()
    fam.labels(node=2).inc(3)
    by_node = {lbl["node"]: child.value for lbl, child in fam.series()}
    assert by_node == {"1": 2.0, "2": 3.0}
    with pytest.raises(ValueError):
        fam.labels()                       # missing the node label
    with pytest.raises(ValueError):
        fam.labels(node=1, extra="x")      # unknown label
    with pytest.raises(ValueError):
        reg.gauge("repro_test_ops_total")  # kind conflict
    with pytest.raises(ValueError):
        reg.counter("repro_test_ops_total", labels=("shard",))  # label conflict


def test_registry_merge_from_and_prometheus_text():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("repro_x_total", help="x ops").inc(2)
    b.counter("repro_x_total").inc(3)
    b.gauge("repro_depth").set(4)
    b.histogram("repro_rtt_seconds").observe(0.02)
    a.merge_from(b)
    assert a.counter("repro_x_total").value == 5.0
    assert a.gauge("repro_depth").value == 4.0
    assert a.histogram("repro_rtt_seconds").count == 1
    text = a.prometheus_text()
    assert "# HELP repro_x_total x ops" in text
    assert "# TYPE repro_x_total counter" in text
    assert "repro_x_total 5.0" in text
    assert 'repro_rtt_seconds_bucket{le="+Inf"} 1' in text
    assert "repro_rtt_seconds_count 1" in text


@dataclasses.dataclass
class _FakeStats:
    scans: int = 0
    bytes_scanned: int = 0
    depth: float = 0.0
    healthy: bool = True            # bools are skipped
    extra: dict = dataclasses.field(default_factory=dict)  # non-numeric: skipped


def test_publish_dataclass_naming_rule_and_monotonicity():
    reg = MetricsRegistry()
    st = _FakeStats(scans=10, bytes_scanned=4096, depth=2.0)
    publish_dataclass(reg, st, prefix="fake", labels={"node": 0},
                      gauge_fields=("depth",))
    names = {f.name: f.kind for f in reg.families()}
    assert names == {"repro_fake_scans_total": "counter",
                     "repro_fake_bytes_scanned_total": "counter",
                     "repro_fake_depth": "gauge"}
    # republish an OLDER snapshot: counters hold, the gauge follows
    publish_dataclass(reg, _FakeStats(scans=4, bytes_scanned=100, depth=1.0),
                      prefix="fake", labels={"node": 0},
                      gauge_fields=("depth",))
    assert reg.counter("repro_fake_scans_total",
                       labels=("node",)).labels(node=0).value == 10.0
    assert reg.gauge("repro_fake_depth",
                     labels=("node",)).labels(node=0).value == 1.0


# ---------------------------------------------------------------------------
# event log
# ---------------------------------------------------------------------------

def test_event_log_ring_seq_and_jsonl(tmp_path):
    log = EventLog(capacity=4)
    for i in range(6):
        log.emit("breaker_open", node=i)
    log.emit("failover", frm=1, to=2)
    events = log.snapshot()
    assert len(events) == 4                       # ring keeps the newest
    assert [e.seq for e in events] == [4, 5, 6, 7]  # seq never reused
    assert log.emitted == 7
    mono = [e.t_mono for e in events]
    assert mono == sorted(mono)
    assert log.counts() == {"breaker_open": 3, "failover": 1}
    p = tmp_path / "events.jsonl"
    log.write_jsonl(p)
    recs = [json.loads(line) for line in p.read_text().splitlines()]
    assert recs[-1]["kind"] == "failover" and recs[-1]["frm"] == 1
    assert {"seq", "t_mono", "t_wall", "kind"} <= set(recs[0])


# ---------------------------------------------------------------------------
# span tracker (synthetic pipeline)
# ---------------------------------------------------------------------------

def _run_item(tr, seq):
    sp = tr.mint(seq)
    tr.enter_item(seq)
    now = time.perf_counter()
    amb = current_span()
    if amb is not None:
        amb.stage("scan", now, now + 1e-4)
        amb.stage("featurize", now + 1e-4, now + 2e-4)
        amb.stage("place", now + 2e-4, now + 3e-4)
    tr.exit_item()
    tr.finish_item(seq)
    return sp


def test_span_tracker_full_lifecycle_and_registry_export():
    reg = MetricsRegistry()
    tr = SpanTracker(sample_every=1, registry=reg)
    spans = [_run_item(tr, i) for i in range(4)]
    tr.emit_batch(0, spans[:2], rows=8)
    tr.emit_batch(1, spans[2:], rows=8)
    assert tr.mark_delivered() is not None
    assert tr.record_train(0.001) is not None
    assert tr.mark_delivered() is not None
    assert tr.record_train(0.001) is not None
    tr.drain()
    assert tr.orphan_items() == []
    lc = tr.lifecycle_counts()
    assert lc["minted"] == 4 and lc["emitted_batches"] == 2
    assert lc["delivered_batches"] == 2 and lc["completed"] == 2
    assert lc["dropped_in_flight"] == 0 and lc["abandoned"] == 0
    for bs in tr.completed:
        assert bs.sampled and bs.t_deliver is not None
        assert bs.t_deliver >= bs.t_emit
        assert bs.latency_s() > 0
        assert "train" in bs.stages
        for sp in bs.items:
            assert sp.stages["scan"][0] <= sp.stages["featurize"][0] \
                <= sp.stages["place"][0]
    # per-stage histogram observed into the registry at finalize time
    hist = reg.histogram("repro_stage_seconds", labels=("stage",))
    by_stage = {lbl["stage"]: child.count for lbl, child in hist.series()}
    assert by_stage["scan"] == 4 and by_stage["train"] == 2


def test_span_sampling_placeholders_keep_fifos_aligned():
    tr = SpanTracker(sample_every=2)
    spans = [_run_item(tr, seq) for seq in range(6)]
    assert tr.minted == 3           # seqs 0,2,4 sampled; 1,3,5 not
    assert spans[1] is None and spans[2] is not None
    assert current_span() is None   # TLS cleared after every item
    # batches alternate sampled / placeholder; the FIFO stays in lockstep
    tr.emit_batch(0, [], rows=8)    # placeholder
    tr.emit_batch(1, [spans[0], spans[2]], rows=8)
    ph = tr.mark_delivered()
    assert ph is not None and not ph.sampled and ph.t_deliver is None
    bs = tr.mark_delivered()
    assert bs is not None and bs.sampled
    tr.record_train(0.0)            # placeholder: no finalize
    tr.record_train(0.0)
    assert len(tr.completed) == 1 and tr.delivered_batches == 2


def test_span_abandon_and_drop_accounting():
    tr = SpanTracker(sample_every=1)
    tr.mint(0)
    tr.enter_item(0)
    tr.exit_item()
    tr.abandon(0)                   # retries exhausted: accounted, not orphaned
    sp = _run_item(tr, 1)
    tr.emit_batch(0, [sp], rows=4)  # emitted but never delivered
    tr.drain()
    lc = tr.lifecycle_counts()
    assert lc["abandoned"] == 1 and lc["dropped_in_flight"] == 1
    assert tr.orphan_items() == [] and lc["live_items"] == 0


def test_span_tracker_rejects_bad_sampling():
    with pytest.raises(ValueError):
        SpanTracker(sample_every=0)


def test_critical_path_attribution_math():
    totals = {"scan": 3.0, "featurize": 1.0, "place": 0.0}
    cp = critical_path(totals, starved_host_s=2.0, starved_h2d_s=1.0,
                       starved_time_s=3.0)
    assert cp["attribution_s"]["h2d"] == pytest.approx(1.0)
    assert cp["attribution_s"]["scan"] == pytest.approx(1.5)   # 3/4 of host
    assert cp["attribution_s"]["featurize"] == pytest.approx(0.5)
    assert cp["attributed_frac"] == pytest.approx(1.0)
    assert cp["dominant_stage"] == "scan"
    # no sampled host spans: the host share falls back to scan (the stage
    # owning the store round-trip)
    cp = critical_path({}, starved_host_s=2.0, starved_time_s=2.0)
    assert cp["attribution_s"] == {"scan": 2.0}
    # nothing starved: vacuously fully attributed
    assert critical_path(totals)["attributed_frac"] == 1.0


# ---------------------------------------------------------------------------
# telemetry facade + run dir + report CLI
# ---------------------------------------------------------------------------

def test_write_run_dir_and_report_render(tmp_path):
    tel = Telemetry(sample_every=1)
    tr = tel.spans
    sp = _run_item(tr, 0)
    tr.emit_batch(0, [sp], rows=8)
    tr.mark_delivered()
    tr.record_train(0.002)
    tel.events.emit("generation_flip", store="immutable", generation=3)
    tel.events.emit("breaker_open", node=1, prev="closed")
    tel.publish_stats(_FakeStats(scans=7), "fake")
    run_dir = tel.write_run_dir(tmp_path / "run")
    for name in ("metrics.json", "metrics.prom", "events.jsonl",
                 "spans.jsonl", "summary.json"):
        assert (run_dir / name).exists(), name
    summary = json.loads((run_dir / "summary.json").read_text())
    assert summary["spans"]["completed"] == 1
    assert summary["events"] == {"generation_flip": 1, "breaker_open": 1}
    out = render_report(run_dir)
    assert "per-stage breakdown" in out and "scan" in out
    assert "starvation attribution" in out
    assert "generation_flip" in out and "breaker_open" in out
    assert "span lifecycle" in out


def test_report_cli_main(tmp_path, capsys):
    from repro.obs import report as report_mod

    tel = Telemetry()
    tel.events.emit("worker_restart")
    run_dir = tel.write_run_dir(tmp_path / "run")
    assert report_mod.main([str(run_dir), "--top-k", "3"]) == 0
    out = capsys.readouterr().out
    assert "telemetry report" in out and "worker_restart" in out
    with pytest.raises(FileNotFoundError):
        render_report(tmp_path / "missing")


def test_dataset_spec_telemetry_excluded_from_identity():
    # the telemetry handle must not perturb spec equality or the resume
    # fingerprint (a resumed run constructs a FRESH Telemetry)
    a = _spec(WarehouseSource())
    b = dataclasses.replace(a, telemetry=Telemetry())
    assert a == b
    assert resume_fingerprint(a) == resume_fingerprint(b)


# ---------------------------------------------------------------------------
# chaos integration: span completeness + acceptance report
# ---------------------------------------------------------------------------

CHAOS_FAULTS = [
    FaultSpec("worker_crash", 1),               # pool self-healing + restart
    FaultSpec("compaction_during_scan", 2),     # generation flip races a read
    FaultSpec("node_flap", 3, node=1, duration=2),  # replica failover + breaker
]


@pytest.fixture(scope="module")
def chaos_run(tmp_path_factory):
    """One chaotic 4-node r=2 run, every item sampled, shared by the
    completeness and acceptance-report tests."""
    sim = make_sim(users=6, days=2, seed=5, nodes=4, replication=2)
    # a single failure must flip the breaker: the flap lasts 2 scan ticks, so
    # the default threshold of 3 consecutive failures may never be reached
    for b in sim.immutable._breakers:
        b.threshold = 1
    plan = FaultPlan(
        CHAOS_FAULTS,
        on_compact=lambda: sim.run_compaction(sim.compaction_watermark,
                                              evict=False))
    tel = Telemetry(sample_every=1)
    spec = _spec(WarehouseSource(), consistency="audit", telemetry=tel)
    feed = open_feed(spec, wrap_sim(sim, plan))
    batches = []
    for b in feed:
        batches.append(b)
        feed.record_train_step(0.001)   # close each chain with a train stage
    feed.join()
    feed.close()
    assert plan.n_fired == len(CHAOS_FAULTS)
    run_dir = tel.write_run_dir(tmp_path_factory.mktemp("obs") / "chaos")
    return {"tel": tel, "feed": feed, "batches": batches, "sim": sim,
            "run_dir": run_dir}


def test_chaos_every_batch_has_complete_monotonic_span_chain(chaos_run):
    tel, batches = chaos_run["tel"], chaos_run["batches"]
    tr = tel.spans
    rows = sum(len(b["user_id"]) for b in batches)
    assert rows == len(chaos_run["sim"].examples)

    # zero orphans: every minted span was placed or abandoned by the drain
    assert tr.orphan_items() == []
    lc = tr.lifecycle_counts()
    assert lc["abandoned"] == 0 and lc["live_items"] == 0
    assert lc["emitted_batches"] == len(batches)
    assert lc["delivered_batches"] == len(batches)
    assert lc["dropped_in_flight"] == 0
    assert lc["completed"] == len(batches)

    completed = list(tr.completed)
    seen_seqs = set()
    for bs in completed:
        assert bs.sampled and bs.items, "sampled batch lost its item spans"
        assert bs.t_deliver is not None and bs.t_deliver >= bs.t_emit
        assert bs.t_train_end is not None and bs.t_train_end >= bs.t_deliver
        assert bs.latency_s() > 0
        for sp in bs.items:
            seen_seqs.add(sp.seq)
            # complete chain: every surviving attempt scanned the store
            # (window cache off), featurized, and was placed — in that order
            for name in ("scan", "featurize", "place"):
                assert name in sp.stages, (bs.emit_seq, sp.seq, sp.stages)
            assert sp.t_mint <= sp.stages["scan"][0]
            assert sp.stages["scan"][0] <= sp.stages["featurize"][0]
            assert sp.stages["featurize"][0] <= sp.stages["place"][0]
            # the commit that stamped t_emit happens INSIDE the final
            # contributor's place window, so only the start ordering holds
            assert sp.stages["place"][0] <= bs.t_emit
            assert sp.attempts >= 1
            # the scan stage carries its IOStats delta (an item whose users
            # have no history yet legitimately scans zero bytes)
            assert "bytes_scanned" in sp.meta and "bytes_decoded" in sp.meta
    assert sum(sp.meta["bytes_scanned"]
               for bs in completed for sp in bs.items) > 0
    # every minted work item contributed rows to some emitted batch
    assert len(seen_seqs) == lc["minted"]
    # the crashed item's surviving chain records the retry
    assert max(sp.attempts for bs in completed for sp in bs.items) >= 2

    # the control-plane timeline saw the whole story
    counts = tel.events.counts()
    assert counts.get("worker_crash", 0) >= 1
    assert counts.get("item_requeued", 0) >= 1
    assert counts.get("worker_restart", 0) >= 1
    assert counts.get("generation_flip", 0) >= 1
    assert counts.get("breaker_open", 0) >= 1
    assert counts.get("node_down", 0) >= 1
    assert counts.get("node_recover", 0) >= 1
    # event seqs strictly increase (the timeline is ordered)
    seqs = [e.seq for e in tel.events.snapshot()]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


def test_chaos_report_meets_acceptance(chaos_run):
    tel, run_dir = chaos_run["tel"], chaos_run["run_dir"]
    # >= 90% of measured starvation attributed to a named stage
    cp = tel.summary()["critical_path"]
    assert cp["attributed_frac"] >= 0.9
    if cp["starved_time_s"] > 0:
        assert cp["dominant_stage"] in ("scan", "featurize", "place", "h2d")
    out = render_report(run_dir)
    assert "breaker_open" in out            # >= 1 breaker transition
    assert "worker_restart" in out          # >= 1 worker restart
    assert "generation_flip" in out         # >= 1 generation flip
    assert "starvation attribution" in out
    assert "attributed: 100.0%" in out or "attributed: 9" in out
    # store counters flushed through Feed.close() -> publish_telemetry()
    metrics = json.loads((run_dir / "metrics.json").read_text())
    assert any(name.startswith("repro_client_") for name in metrics)
    assert any(name.startswith("repro_worker_") for name in metrics)
    assert any(name.startswith("repro_io_") for name in metrics)


def test_feed_snapshot_members_are_copies(chaos_run):
    feed = chaos_run["feed"]
    snap = feed.stats()
    assert snap.workers is not None
    live = feed.client_stats.full_batches
    snap.client.full_batches += 1000
    assert feed.client_stats.full_batches == live
    # and the legacy attribute contract still reads through live
    assert feed.stats.full_batches == live


# ---------------------------------------------------------------------------
# overhead guard (<= 2% budget at the default sampling rate)
# ---------------------------------------------------------------------------

def test_telemetry_overhead_budget():
    """Deterministic form of the bench_feed guard: the span ops added per
    pipeline item at DEFAULT_SAMPLE_EVERY must cost well under 2% of the
    telemetry-off pipeline wall time for the same workload.  (bench_feed's
    feed/telemetry_overhead measures the same budget end-to-end with paired
    order-alternating runs; this test bounds the op cost directly so a hot-
    path regression fails CI without depending on a quiet machine.)"""
    from benchmarks.bench_feed import _feed_slot, _synth

    seq_len, base, full = 256, 16, 64
    n = 16 * full
    spec = FeatureSpec(seq_len=seq_len,
                       uih_traits=("item_id", "action_type", "watch_time_ms",
                                   "like"),
                       candidate_fields=("item_id",), label_fields=("click",))
    examples, uihs = _synth(n, seq_len)
    chunks = [(examples[i:i + base], uihs[i:i + base])
              for i in range(0, n, base)]

    # telemetry-off pipeline time (the denominator): best of 3
    t_off = min(_time_once(lambda: _feed_slot(chunks, spec, full,
                                              recycle=True))
                for _ in range(3))

    # pure telemetry op cost for the same item/batch counts, default sampling
    n_items, n_batches = len(chunks), n // full
    tel = Telemetry()   # DEFAULT_SAMPLE_EVERY
    tr = tel.spans
    assert tr.sample_every == DEFAULT_SAMPLE_EVERY

    def _ops():
        pending = []
        for i in range(n_items):
            tr.mint(i)
            tr.enter_item(i)
            sp = current_span()
            if sp is not None:
                now = time.perf_counter()
                sp.stage("scan", now, now)
                sp.stage("featurize", now, now)
                sp.stage("place", now, now)
                pending.append(sp)
            tr.exit_item()
            tr.finish_item(i)
            if (i + 1) % (n_items // n_batches) == 0:
                tr.emit_batch(i, pending, full)
                pending = []
                tr.mark_delivered()
                tr.record_train(0.0)
        tr.drain()

    t_ops = min(_time_once(_ops) for _ in range(5))
    # the ops are ~100x below budget; even heavy scheduler noise on t_off
    # cannot flip this assertion
    assert t_ops <= 0.02 * t_off, (
        f"telemetry op cost {1e3 * t_ops:.3f}ms exceeds 2% of the "
        f"{1e3 * t_off:.1f}ms telemetry-off pipeline time "
        f"(sample_every={DEFAULT_SAMPLE_EVERY})")


def _time_once(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
