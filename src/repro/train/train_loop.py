"""End-to-end trainer: DPP data plane -> jit'd train step -> checkpoints.

Integrates the full stack on one host (and, unchanged, on a pod via the mesh
argument): the VLM materialization pipeline feeds batches through the
rebatching client; the train step is jit'd with shardings; the checkpoint
manager gives crash-safe resume; gradient compression is optional.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.train.grad_compress import EFState, compress_with_feedback, ef_init
from repro.train.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
)


@dataclasses.dataclass
class TrainerConfig:
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    grad_accum: int = 1          # microbatch accumulation factor
    compress_grads: bool = False
    log_every: int = 10
    # double-buffered device feed: issue the host->device transfer for batch
    # N+1 while step N computes (0 disables; 2 = classic double buffering).
    # Ignored when ``fit`` is handed an already-wrapped DevicePrefetcher.
    prefetch_depth: int = 0
    # device-side late materialization (DESIGN §3): when fit auto-wraps the
    # feed in a DevicePrefetcher, attach a DeviceMaterializer so compact
    # jagged payloads (from a ``RebatchingClient(emit_jagged=True)``) densify
    # and delta-decode ON DEVICE. Dense host batches pass through untouched,
    # so the flag is safe to leave on. Requires prefetch_depth > 0.
    device_materialize: bool = False
    # streaming feed mode: bound ``fit`` by wall clock instead of (or in
    # addition to) max_steps — an online trainer's stream never exhausts.
    max_wall_s: Optional[float] = None
    # unified telemetry (§13): a ``repro.obs.Telemetry`` — ``fit`` observes a
    # per-step ``repro_train_step_seconds`` histogram, ``save``/``try_resume``
    # emit checkpoint_save / checkpoint_resume events. Falls back to the
    # feed's own telemetry when None.
    telemetry: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)


class Trainer:
    def __init__(
        self,
        loss_fn: Callable[[Any, Dict[str, Any]], jax.Array],
        params: Any,
        cfg: TrainerConfig,
        mesh=None,
    ):
        self.loss_fn = loss_fn
        self.cfg = cfg
        self.params = params
        self.opt_state = adamw_init(params)
        self.ef_state = ef_init(params) if cfg.compress_grads else None
        self.step = 0
        self.mesh = mesh
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)
                     if cfg.ckpt_dir else None)
        self.history = []
        self._jit_step = jax.jit(self._train_step)
        # set by fit(): the active Feed whose cursor rides along with model
        # checkpoints (feed_state sidecar, exactly-once resume). While a feed
        # is active, run_step defers its periodic autosave to fit — the save
        # must happen AFTER record_train_step so the feed's trained-row
        # counter includes the step being checkpointed.
        self._fit_feed = None

    # -- one optimizer step (with optional microbatch accumulation) -----------
    def _train_step(self, params, opt_state, ef_state, microbatches):
        def accum(carry, mb):
            gacc, lacc = carry
            loss, grads = jax.value_and_grad(self.loss_fn)(params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jax.numpy.float32),
                                gacc, grads)
            return (gacc, lacc + loss), None

        zero = jax.tree.map(
            lambda p: jax.numpy.zeros(p.shape, jax.numpy.float32), params)
        (gsum, lsum), _ = jax.lax.scan(
            accum, (zero, jax.numpy.zeros((), jax.numpy.float32)), microbatches)
        n = self.cfg.grad_accum
        grads = jax.tree.map(lambda g: g / n, gsum)
        if ef_state is not None:
            grads, ef_state = compress_with_feedback(grads, ef_state)
        params, opt_state, stats = adamw_update(params, grads, opt_state,
                                                self.cfg.opt)
        stats["loss"] = lsum / n
        return params, opt_state, ef_state, stats

    def run_step(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        """batch rows are split into ``grad_accum`` microbatches."""
        n = self.cfg.grad_accum
        mbs = {}
        for k, v in batch.items():
            b = v.shape[0]
            assert b % n == 0, f"batch {b} not divisible by accum {n}"
            mbs[k] = v.reshape(n, b // n, *v.shape[1:])
        self.params, self.opt_state, self.ef_state, stats = self._jit_step(
            self.params, self.opt_state, self.ef_state, mbs)
        self.step += 1
        out = {k: float(v) for k, v in stats.items()}
        self.history.append(out)
        if (self.ckpt and self.step % self.cfg.ckpt_every == 0
                and self._fit_feed is None):
            self.save()
        return out

    # -- checkpointing ----------------------------------------------------------
    def save(self) -> None:
        assert self.ckpt is not None
        state = {"params": self.params, "opt": self.opt_state}
        if self.ef_state is not None:
            state["ef"] = self.ef_state
        feed_state = None
        feed = self._fit_feed
        if feed is not None and getattr(feed, "can_checkpoint", False):
            feed_state = feed.checkpoint()
        self.ckpt.save(self.step, state, extra={"step": self.step},
                       feed_state=feed_state)
        tel = self._telemetry()
        if tel is not None:
            tel.events.emit("checkpoint_save", step=self.step,
                            has_feed_state=feed_state is not None)

    def try_resume(self) -> bool:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        template = {"params": self.params, "opt": self.opt_state}
        if self.ef_state is not None:
            template["ef"] = self.ef_state
        state, step, _ = self.ckpt.restore(template)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.ef_state = state.get("ef", self.ef_state)
        self.step = step
        tel = self._telemetry()
        if tel is not None:
            tel.events.emit("checkpoint_resume", step=step)
        return True

    def _telemetry(self):
        """The active telemetry: the config's, else the fit feed's."""
        if self.cfg.telemetry is not None:
            return self.cfg.telemetry
        return getattr(self._fit_feed, "telemetry", None)

    # -- full loop ---------------------------------------------------------------
    def fit(self, batches: Iterable[Dict[str, np.ndarray]],
            max_steps: Optional[int] = None) -> None:
        from repro.data.feed import Feed
        from repro.dpp.prefetch import DevicePrefetcher

        feed = batches
        if (self.cfg.prefetch_depth > 0
                and not isinstance(feed, (DevicePrefetcher, Feed))):
            materialize = None
            if self.cfg.device_materialize:
                from repro.dpp.device_mat import DeviceMaterializer
                materialize = DeviceMaterializer()
            feed = DevicePrefetcher(feed, depth=self.cfg.prefetch_depth,
                                    materialize=materialize)
        # GPU-busy accounting feeds the elastic controller's starvation signal
        record = getattr(feed, "record_train_step", None)
        self._fit_feed = feed if isinstance(feed, Feed) else None
        tel = self._telemetry()
        step_hist = (tel.registry.histogram(
            "repro_train_step_seconds",
            help="device train-step wall time") if tel is not None else None)
        t0 = time.perf_counter()

        def batches():
            """Feed iterator honoring ``max_wall_s`` even while BLOCKED on an
            idle-but-open stream: with a timeout-capable getter, poll with a
            bounded wait so the wall budget can fire between batches; the
            feed's ``ended`` flag distinguishes end-of-stream from a timeout."""
            wall = self.cfg.max_wall_s
            get = getattr(feed, "get", None) or getattr(feed, "get_full_batch",
                                                        None)
            if wall is None or get is None:
                yield from feed
                return
            # the live mutable ClientStats: a Feed exposes it as
            # ``client_stats`` (its ``stats`` is the composite snapshot
            # method); legacy feeds expose the object directly as ``stats``
            stats = getattr(feed, "client_stats", None)
            if stats is None:
                stats = getattr(feed, "stats", None)
                if callable(stats):
                    stats = None
            pending_wait = 0.0   # timed-out poll waits, unrecorded by the feed
            while True:
                remaining = wall - (time.perf_counter() - t0)
                if remaining <= 0:
                    return
                t_poll = time.perf_counter()
                b = get(timeout=min(0.25, max(remaining, 0.01)))
                if b is None:
                    if getattr(feed, "ended", False):
                        return
                    pending_wait += time.perf_counter() - t_poll
                    continue   # timed out; re-check the wall budget
                if pending_wait > 0.0 and stats is not None:
                    # the feed only records waits ending in a delivered batch;
                    # fold the preceding timed-out polls back into starvation
                    # (host-attributed: that is the scale-the-workers signal)
                    # or the controller would see a starving feed as healthy.
                    # Waits with NO eventual batch (stream over) stay
                    # unrecorded, matching the feed's own rule.
                    stats.starved_time_s += pending_wait
                    stats.starved_host_s += pending_wait
                if pending_wait:
                    pending_wait = 0.0
                yield b

        try:
            for batch in batches():
                ts = time.perf_counter()
                stats = self.run_step(batch)
                dt_step = time.perf_counter() - ts
                if record is not None:
                    record(dt_step)
                if step_hist is not None:
                    step_hist.observe(dt_step)
                if (self.ckpt and self._fit_feed is not None
                        and self.step % self.cfg.ckpt_every == 0):
                    # deferred from run_step: the feed's trained-row counter
                    # advanced in record() above, so the feed_state sidecar
                    # now names exactly this step's training frontier
                    self.save()
                if self.step % self.cfg.log_every == 0:
                    dt = time.perf_counter() - t0
                    print(f"step {self.step:5d} loss={stats['loss']:.4f} "
                          f"gnorm={stats['grad_norm']:.3f} ({dt:.1f}s)",
                          flush=True)
                if max_steps and self.step >= max_steps:
                    break
                if (self.cfg.max_wall_s is not None
                        and time.perf_counter() - t0 >= self.cfg.max_wall_s):
                    break
        finally:
            self._fit_feed = None
            # break AND exception paths: release the transfer thread and any
            # queued device batches (idempotent; harmless on exhaustion).
            # A Feed's stop() releases ONLY its device-prefetch stage — the
            # host pipeline stays up for the caller to close()/drain.
            if isinstance(feed, (DevicePrefetcher, Feed)):
                feed.stop()
