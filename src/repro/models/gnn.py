"""MeshGraphNet (encode-process-decode, arXiv:2010.03409) in pure JAX.

Message passing uses ``jax.ops.segment_sum`` over an edge list (senders /
receivers) — the JAX-native scatter formulation. For pod-scale meshes the edge
arrays shard across all devices while node states stay replicated (vertex-cut
partitioning: local partial segment-sums + one all-reduce per block).

Includes a real fanout neighbor sampler for the ``minibatch_lg`` regime.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.embedding import mlp_apply, mlp_init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MeshGraphNetConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 16
    d_edge_in: int = 8
    d_out: int = 3
    aggregator: str = "sum"
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_blocks: bool = True

    def param_count(self) -> int:
        leaves = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), self))
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(leaves))


def _mlp_dims(d_in: int, d_hidden: int, n_layers: int, d_out: int):
    return [d_in] + [d_hidden] * (n_layers - 1) + [d_out]


def init(key, cfg: MeshGraphNetConfig) -> Params:
    k_ne, k_ee, k_blocks, k_dec = jax.random.split(key, 4)
    h, m = cfg.d_hidden, cfg.mlp_layers

    def block_init(k):
        k1, k2 = jax.random.split(k)
        return {
            # edge update: MLP([e, h_src, h_dst])
            "edge_mlp": mlp_init(k1, _mlp_dims(3 * h, h, m, h)),
            # node update: MLP([h, agg_msgs])
            "node_mlp": mlp_init(k2, _mlp_dims(2 * h, h, m, h)),
            "edge_ln": jnp.ones((h,), jnp.float32),
            "node_ln": jnp.ones((h,), jnp.float32),
        }

    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    return {
        "node_encoder": mlp_init(k_ne, _mlp_dims(cfg.d_node_in, h, m, h)),
        "edge_encoder": mlp_init(k_ee, _mlp_dims(cfg.d_edge_in, h, m, h)),
        "blocks": jax.vmap(block_init)(block_keys),
        "decoder": mlp_init(k_dec, _mlp_dims(h, h, m, cfg.d_out)),
    }


def _ln(x: jax.Array, w: jax.Array, eps=1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * w).astype(dt)


def forward(
    params: Params,
    node_feats: jax.Array,    # (N, d_node_in)
    edge_feats: jax.Array,    # (E, d_edge_in)
    senders: jax.Array,       # (E,) int32
    receivers: jax.Array,     # (E,) int32
    cfg: MeshGraphNetConfig,
    edge_mask: Optional[jax.Array] = None,   # (E,) for padded edges
) -> jax.Array:
    dt = cfg.compute_dtype
    n = node_feats.shape[0]
    m = cfg.mlp_layers
    h = mlp_apply(params["node_encoder"], node_feats.astype(dt), m)
    e = mlp_apply(params["edge_encoder"], edge_feats.astype(dt), m)
    if edge_mask is not None:
        e = e * edge_mask[:, None].astype(dt)

    def block(carry, bp):
        h, e = carry
        msg_in = jnp.concatenate([e, h[senders], h[receivers]], axis=-1)
        e_new = mlp_apply(bp["edge_mlp"], msg_in, m)
        if edge_mask is not None:
            e_new = e_new * edge_mask[:, None].astype(dt)
        e = _ln(e + e_new, bp["edge_ln"])
        agg = jax.ops.segment_sum(e, receivers, num_segments=n)
        h_new = mlp_apply(bp["node_mlp"], jnp.concatenate([h, agg], -1), m)
        h = _ln(h + h_new, bp["node_ln"])
        return (h, e), None

    blk = jax.checkpoint(block) if cfg.remat else block
    if cfg.scan_blocks:
        (h, e), _ = jax.lax.scan(blk, (h, e), params["blocks"])
    else:
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda x: x[i], params["blocks"])
            (h, e), _ = blk((h, e), bp)
    return mlp_apply(params["decoder"], h, m)


def loss_fn(params, node_feats, edge_feats, senders, receivers, targets,
            cfg: MeshGraphNetConfig, node_mask=None, edge_mask=None) -> jax.Array:
    pred = forward(params, node_feats, edge_feats, senders, receivers, cfg,
                   edge_mask)
    err = (pred.astype(jnp.float32) - targets.astype(jnp.float32)) ** 2
    if node_mask is not None:
        err = err * node_mask[:, None]
        return jnp.sum(err) / (jnp.maximum(jnp.sum(node_mask), 1) * cfg.d_out)
    return jnp.mean(err)


# ---------------------------------------------------------------------------
# Neighbor sampler (host-side, for minibatch_lg): fanout-(f1, f2) sampling
# ---------------------------------------------------------------------------

class CSRGraph:
    """Host-side CSR adjacency for sampling."""

    def __init__(self, n_nodes: int, senders: np.ndarray, receivers: np.ndarray):
        self.n_nodes = n_nodes
        order = np.argsort(receivers, kind="stable")
        self.src_sorted = senders[order]
        self.indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        counts = np.bincount(receivers, minlength=n_nodes)
        np.cumsum(counts, out=self.indptr[1:])

    def neighbors(self, v: int) -> np.ndarray:
        return self.src_sorted[self.indptr[v] : self.indptr[v + 1]]


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanouts: Tuple[int, ...],
    rng: np.random.Generator,
) -> Dict[str, np.ndarray]:
    """GraphSAGE-style fixed-fanout sampling producing FIXED-SHAPE padded
    arrays (jit-stable): layer l samples ``fanouts[l]`` in-neighbors per
    frontier slot, so hop l contributes exactly batch * prod(fanouts[:l+1])
    edges; empty slots are masked out. Frontier slots keep duplicates — shape
    stability is what lets every minibatch reuse one compiled step."""
    frontier = seeds.astype(np.int64)
    frontier_mask = np.ones(len(frontier), dtype=bool)
    all_src, all_dst, all_mask = [], [], []
    for f in fanouts:
        n_f = len(frontier)
        src = np.zeros((n_f, f), dtype=np.int64)
        msk = np.zeros((n_f, f), dtype=bool)
        for i, v in enumerate(frontier):
            if not frontier_mask[i]:
                continue
            nbr = graph.neighbors(int(v))
            if len(nbr) == 0:
                continue
            take = rng.choice(nbr, size=f, replace=len(nbr) < f)
            src[i] = take
            msk[i] = True
        all_src.append(np.where(msk.reshape(-1), src.reshape(-1), 0))
        all_dst.append(np.repeat(frontier, f))
        all_mask.append(msk.reshape(-1))
        frontier = src.reshape(-1)
        frontier_mask = msk.reshape(-1)

    senders = np.concatenate(all_src)
    receivers = np.concatenate(all_dst)
    edge_mask = np.concatenate(all_mask)
    # compact node ids
    nodes, inv = np.unique(np.concatenate([senders, receivers, seeds]),
                           return_inverse=True)
    senders_c = inv[: len(senders)]
    receivers_c = inv[len(senders) : 2 * len(senders)]
    seed_local = inv[2 * len(senders):]
    return {
        "nodes": nodes,
        "senders": senders_c.astype(np.int32),
        "receivers": receivers_c.astype(np.int32),
        "edge_mask": edge_mask,
        "seed_local": seed_local.astype(np.int32),
    }
