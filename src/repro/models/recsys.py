"""RecSys model zoo — the tenants of the VLM data plane.

  * TwoTowerRetrieval  — sampled-softmax retrieval (YouTube RecSys'19)
  * DCNv2              — cross-network CTR (arXiv:2008.13535)
  * DIEN               — GRU + AUGRU interest evolution (arXiv:1809.03672)
  * BERT4Rec           — bidirectional masked item prediction (arXiv:1904.06690)
  * DLRMUIH            — the paper's own flagship: DLRM + target-aware
                         transformer encoder over ultra-long UIH sequences

All consume padded UIH arrays exactly as emitted by the DPP featurizer
(``uih_item_id``, ``uih_mask`` ...), so the data plane and the models share one
contract. Embedding tables are huge (1e6–1e8 rows) and row-sharded at dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.embedding import (
    bag_rowsharded,
    embedding_bag,
    init_table,
    lookup_rowsharded,
    mlp_apply,
    mlp_init,
    seq_rowsharded,
)

Params = Dict[str, Any]


def _count(cfg, init_fn) -> int:
    leaves = jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0), cfg))
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(leaves))


def _lookup(table, ids, cfg, dt):
    """Candidate/field lookup; row-sharded shard_map path on a mesh."""
    if cfg.mesh is not None:
        return lookup_rowsharded(table, ids, cfg.mesh, cfg.data_axes, dtype=dt)
    return table.astype(dt)[ids]


def _seq_lookup(table, ids, cfg, dt):
    """Per-position sequence lookup (B, S) -> (B, S, D)."""
    if cfg.mesh is not None:
        return seq_rowsharded(table, ids, cfg.mesh, cfg.data_axes, dtype=dt)
    return table.astype(dt)[ids]


def _bag(table, ids, mask, combiner, cfg, dt):
    if cfg.mesh is not None:
        return bag_rowsharded(table, ids, mask, combiner, cfg.mesh,
                              cfg.data_axes, dtype=dt)
    return embedding_bag(table, ids, mask, combiner, dt)


def _shard_batch_all(x, cfg):
    """Recsys encoders have no model-parallel dims, so the ``model`` axis
    would otherwise idle while per-chip attention/GRU activations blow up
    16x: re-shard the batch over (data x model) for the encoder section
    (one cheap all-to-all in, one out)."""
    if cfg.mesh is None:
        return x
    from jax.sharding import PartitionSpec as P

    axes = tuple(cfg.data_axes) + ("model",)
    return jax.lax.with_sharding_constraint(
        x, P(axes, *([None] * (x.ndim - 1))))


def bce_with_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def normalized_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """NE (paper §5.2, He et al. 2014): CE normalized by the entropy of the
    base rate — the paper's model-quality metric."""
    ce = bce_with_logits(logits, labels)
    p = jnp.clip(jnp.mean(labels.astype(jnp.float32)), 1e-6, 1 - 1e-6)
    h = -(p * jnp.log(p) + (1 - p) * jnp.log(1 - p))
    return ce / h


# ===========================================================================
# Two-tower retrieval
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    item_vocab: int = 10_000_000
    user_vocab: int = 20_000_000
    uih_len: int = 100
    temperature: float = 0.05
    compute_dtype: Any = jnp.bfloat16
    mesh: Any = None              # row-sharded lookups when set
    data_axes: Tuple[str, ...] = ("data",)

    def param_count(self) -> int:
        return _count(self, init_two_tower)


def init_two_tower(key, cfg: TwoTowerConfig) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.embed_dim
    return {
        "item_table": init_table(ks[0], cfg.item_vocab, d),
        "user_table": init_table(ks[1], cfg.user_vocab, d),
        # user tower input: user id emb + history bag emb
        "user_mlp": mlp_init(ks[2], [2 * d, *cfg.tower_mlp]),
        # item tower input: item emb
        "item_mlp": mlp_init(ks[3], [d, *cfg.tower_mlp]),
    }


def two_tower_user(params, user_id, uih_ids, uih_mask, cfg) -> jax.Array:
    dt = cfg.compute_dtype
    u = _lookup(params["user_table"], user_id, cfg, dt)
    hist = _bag(params["item_table"], uih_ids, uih_mask, "mean", cfg, dt)
    z = _shard_batch_all(jnp.concatenate([u, hist], axis=-1), cfg)
    z = mlp_apply(params["user_mlp"], z, len(cfg.tower_mlp))
    return z / (jnp.linalg.norm(z.astype(jnp.float32), axis=-1, keepdims=True)
                + 1e-6).astype(dt)


def two_tower_item(params, item_id, cfg) -> jax.Array:
    dt = cfg.compute_dtype
    z = _shard_batch_all(_lookup(params["item_table"], item_id, cfg, dt), cfg)
    z = mlp_apply(params["item_mlp"], z, len(cfg.tower_mlp))
    return z / (jnp.linalg.norm(z.astype(jnp.float32), axis=-1, keepdims=True)
                + 1e-6).astype(dt)


def two_tower_loss(params, batch, cfg: TwoTowerConfig,
                   log_q: Optional[jax.Array] = None) -> jax.Array:
    """In-batch sampled softmax with logQ correction."""
    u = two_tower_user(params, batch["user_id"], batch["uih_item_id"],
                       batch["uih_mask"], cfg)
    v = two_tower_item(params, batch["cand_item_id"], cfg)
    logits = (u @ v.T).astype(jnp.float32) / cfg.temperature   # (B, B)
    if log_q is not None:  # correct for in-batch sampling bias
        logits = logits - log_q[None, :]
    labels = jnp.arange(logits.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def two_tower_score_candidates(params, batch, cand_ids, cfg) -> jax.Array:
    """retrieval_cand: one query vs N candidates as a single batched dot."""
    u = two_tower_user(params, batch["user_id"], batch["uih_item_id"],
                       batch["uih_mask"], cfg)                 # (1, d)
    v = two_tower_item(params, cand_ids, cfg)                  # (N, d)
    return (u @ v.T) / cfg.temperature                         # (1, N)


# ===========================================================================
# DCN-v2
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class DCNv2Config:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp: Tuple[int, ...] = (1024, 1024, 512)
    field_vocab: int = 1_000_000
    compute_dtype: Any = jnp.bfloat16
    mesh: Any = None              # row-sharded lookups when set
    data_axes: Tuple[str, ...] = ("data",)

    @property
    def d_interact(self) -> int:
        return self.n_sparse * self.embed_dim + self.n_dense

    def param_count(self) -> int:
        return _count(self, init_dcn_v2)


def init_dcn_v2(key, cfg: DCNv2Config) -> Params:
    ks = jax.random.split(key, 3 + cfg.n_cross_layers)
    d = cfg.d_interact
    p: Params = {
        # one big table: field f uses rows [f*vocab, (f+1)*vocab)
        "embed": init_table(ks[0], cfg.n_sparse * cfg.field_vocab, cfg.embed_dim),
        "mlp": mlp_init(ks[1], [d, *cfg.mlp]),
        "head": mlp_init(ks[2], [cfg.mlp[-1] + d, 1]),
    }
    for i in range(cfg.n_cross_layers):
        p[f"cross_w{i}"] = jax.random.normal(ks[3 + i], (d, d), jnp.float32) / np.sqrt(d)
        p[f"cross_b{i}"] = jnp.zeros((d,), jnp.float32)
    return p


def dcn_v2_forward(params, batch, cfg: DCNv2Config) -> jax.Array:
    dt = cfg.compute_dtype
    ids = batch["sparse_ids"]                                  # (B, F)
    offsets = jnp.arange(cfg.n_sparse) * cfg.field_vocab
    emb = _seq_lookup(params["embed"], ids + offsets[None, :], cfg, dt)  # (B,F,D)
    x0 = _shard_batch_all(jnp.concatenate(
        [emb.reshape(ids.shape[0], -1), batch["dense"].astype(dt)], axis=-1
    ), cfg)
    x = x0
    for i in range(cfg.n_cross_layers):                        # x_{l+1} = x0*(W x_l + b) + x_l
        xw = x @ params[f"cross_w{i}"].astype(dt) + params[f"cross_b{i}"].astype(dt)
        x = x0 * xw + x
    deep = mlp_apply(params["mlp"], x0, len(cfg.mlp), final_act=True)
    z = jnp.concatenate([x, deep], axis=-1)
    return mlp_apply(params["head"], z, 1)[:, 0]


def dcn_v2_loss(params, batch, cfg) -> jax.Array:
    return bce_with_logits(dcn_v2_forward(params, batch, cfg), batch["label"])


# ===========================================================================
# DIEN (GRU interest extractor + AUGRU interest evolution)
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp: Tuple[int, ...] = (200, 80)
    item_vocab: int = 1_000_000
    cat_vocab: int = 10_000
    compute_dtype: Any = jnp.bfloat16
    mesh: Any = None              # row-sharded lookups when set
    data_axes: Tuple[str, ...] = ("data",)
    unroll_scans: bool = False

    @property
    def d_in(self) -> int:
        return 2 * self.embed_dim  # item emb ++ category emb

    def param_count(self) -> int:
        return _count(self, init_dien)


def _gru_init(key, d_in, d_h):
    ks = jax.random.split(key, 3)
    s_in, s_h = 1.0 / np.sqrt(d_in), 1.0 / np.sqrt(d_h)
    return {
        "wx": jax.random.normal(ks[0], (d_in, 3 * d_h), jnp.float32) * s_in,
        "wh": jax.random.normal(ks[1], (d_h, 3 * d_h), jnp.float32) * s_h,
        "b": jnp.zeros((3 * d_h,), jnp.float32),
    }


def _gru_cell(p, h, x, att: Optional[jax.Array] = None):
    """GRU step; ``att`` (B, 1) turns it into AUGRU (attention-gated update)."""
    dt = x.dtype
    gx = x @ p["wx"].astype(dt) + p["b"].astype(dt)
    gh = h @ p["wh"].astype(dt)
    rx, zx, nx = jnp.split(gx, 3, axis=-1)
    rh, zh, nh = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    if att is not None:
        z = z * att  # AUGRU: scale update gate by attention weight
    return (1 - z) * h + z * n


def init_dien(key, cfg: DIENConfig) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "item_table": init_table(ks[0], cfg.item_vocab, cfg.embed_dim),
        "cat_table": init_table(ks[1], cfg.cat_vocab, cfg.embed_dim),
        "gru1": _gru_init(ks[2], cfg.d_in, cfg.gru_dim),
        "augru": _gru_init(ks[3], cfg.gru_dim, cfg.gru_dim),
        "att_w": jax.random.normal(ks[4], (cfg.gru_dim, cfg.d_in), jnp.float32)
        * (1.0 / np.sqrt(cfg.gru_dim)),
        "mlp": mlp_init(ks[5], [cfg.gru_dim + 2 * cfg.d_in, *cfg.mlp, 1]),
    }


def dien_forward(params, batch, cfg: DIENConfig) -> jax.Array:
    dt = cfg.compute_dtype
    ids, cats = batch["uih_item_id"], batch["uih_category"]
    mask = batch["uih_mask"].astype(dt)                        # (B, S)
    e = jnp.concatenate(
        [_seq_lookup(params["item_table"], ids, cfg, dt),
         _seq_lookup(params["cat_table"], cats, cfg, dt)],
        axis=-1,
    )                                                          # (B, S, 2D)
    tgt = jnp.concatenate(
        [_lookup(params["item_table"], batch["cand_item_id"], cfg, dt),
         _lookup(params["cat_table"], batch["cand_category"], cfg, dt)], axis=-1,
    )                                                          # (B, 2D)
    e = _shard_batch_all(e, cfg)
    mask = _shard_batch_all(mask, cfg)
    tgt = _shard_batch_all(tgt, cfg)
    b, s, _ = e.shape
    h0 = jnp.zeros((b, cfg.gru_dim), dt)

    def step1(h, inp):
        x, mk = inp
        h_new = _gru_cell(params["gru1"], h, x)
        h = jnp.where(mk[:, None] > 0, h_new, h)
        return h, h

    _, interests = jax.lax.scan(step1, h0, (e.transpose(1, 0, 2), mask.T),
                                unroll=cfg.unroll_scans)
    interests = interests.transpose(1, 0, 2)                   # (B, S, H)

    # attention of target vs interest states
    att_logits = jnp.einsum(
        "bsh,hd,bd->bs", interests, params["att_w"].astype(dt), tgt,
        preferred_element_type=jnp.float32,
    )
    att = jax.nn.softmax(
        jnp.where(mask > 0, att_logits, -1e30), axis=-1
    ).astype(dt)                                               # (B, S)

    def step2(h, inp):
        x, a, mk = inp
        h_new = _gru_cell(params["augru"], h, x, a[:, None])
        h = jnp.where(mk[:, None] > 0, h_new, h)
        return h, None

    final, _ = jax.lax.scan(
        step2, h0, (interests.transpose(1, 0, 2), att.T, mask.T),
        unroll=cfg.unroll_scans,
    )                                                          # (B, H)
    hist_sum = jnp.sum(e * mask[..., None], axis=1)
    z = jnp.concatenate([final, tgt, hist_sum], axis=-1)
    return mlp_apply(params["mlp"], z, len(cfg.mlp) + 1)[:, 0]


def dien_loss(params, batch, cfg) -> jax.Array:
    return bce_with_logits(dien_forward(params, batch, cfg), batch["label"])


# ===========================================================================
# BERT4Rec
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class BERT4RecConfig:
    name: str = "bert4rec"
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    item_vocab: int = 1_000_000
    mask_token: int = 0
    compute_dtype: Any = jnp.bfloat16
    mesh: Any = None              # row-sharded lookups when set
    data_axes: Tuple[str, ...] = ("data",)
    loss_chunk: int = 0   # 0 = no chunking
    unroll_scans: bool = False

    def param_count(self) -> int:
        return _count(self, init_bert4rec)


def init_bert4rec(key, cfg: BERT4RecConfig) -> Params:
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    d = cfg.embed_dim
    attn_cfg = L.AttnConfig(d_model=d, n_heads=cfg.n_heads,
                            n_kv_heads=cfg.n_heads, head_dim=d // cfg.n_heads,
                            rope_theta=1e4, q_chunk=1 << 30)

    def block_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn": L.init_gqa(k1, attn_cfg),
            "ffn": L.init_swiglu(k2, d, 4 * d),
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
        }

    return {
        "item_table": init_table(ks[0], cfg.item_vocab, d),
        "pos_table": init_table(ks[1], cfg.seq_len, d),
        "blocks": jax.vmap(block_init)(jax.random.split(ks[-1], cfg.n_blocks)),
        "final_ln": jnp.ones((d,), jnp.float32),
    }


def bert4rec_encode(params, ids, mask, cfg: BERT4RecConfig) -> jax.Array:
    dt = cfg.compute_dtype
    b, s = ids.shape
    attn_cfg = L.AttnConfig(d_model=cfg.embed_dim, n_heads=cfg.n_heads,
                            n_kv_heads=cfg.n_heads,
                            head_dim=cfg.embed_dim // cfg.n_heads,
                            rope_theta=1e4, q_chunk=1 << 30,
                            unroll=cfg.unroll_scans,
                            scores_f32=(cfg.mesh is None))
    h = _seq_lookup(params["item_table"], ids, cfg, dt) \
        + params["pos_table"].astype(dt)[None]
    h = _shard_batch_all(h, cfg)
    mask = _shard_batch_all(mask, cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(h, block):
        hn = L.rms_norm(h, block["ln1"])
        h = h + L.gqa_attention(block["attn"], hn, positions, attn_cfg,
                                causal=False, kv_mask=mask)   # bidirectional
        hn = L.rms_norm(h, block["ln2"])
        return h + L.swiglu(block["ffn"], hn), None

    h, _ = jax.lax.scan(body, h, params["blocks"], unroll=cfg.unroll_scans)
    return L.rms_norm(h, params["final_ln"])


def bert4rec_loss(params, batch, cfg: BERT4RecConfig) -> jax.Array:
    """Cloze objective: predict items at masked positions.

    At production vocab (1e6 items) a full softmax over (B, S, V) is
    infeasible; when the batch carries shared sampled negatives (``neg_ids``)
    we use a sampled softmax, chunked over the sequence axis."""
    ids = batch["uih_item_id"]
    mask = batch["uih_mask"]
    mask_pos = batch["mask_pos"].astype(bool)                 # (B, S) to predict
    inputs = jnp.where(mask_pos, cfg.mask_token, ids)
    h = bert4rec_encode(params, inputs, mask, cfg)            # (B, S, D)
    table = params["item_table"].astype(h.dtype)
    neg_ids = batch.get("neg_ids")
    if neg_ids is None:                                       # smoke path: full softmax
        logits = jnp.einsum("bsd,vd->bsv", h, table).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ids[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mask_pos
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask_pos), 1)

    neg_emb = table[neg_ids]                                  # (N, D) small
    gold_emb = _seq_lookup(params["item_table"], ids, cfg, h.dtype)  # (B,S,D)
    gold_logit = jnp.sum(h * gold_emb, axis=-1).astype(jnp.float32)  # (B, S)
    b, s, d = h.shape
    lc = cfg.loss_chunk if cfg.loss_chunk and s % cfg.loss_chunk == 0 else s
    n_chunks = s // lc
    hs = h.reshape(b, n_chunks, lc, d).transpose(1, 0, 2, 3)
    gl = gold_logit.reshape(b, n_chunks, lc).transpose(1, 0, 2)
    mp = mask_pos.reshape(b, n_chunks, lc).transpose(1, 0, 2)

    def chunk(carry, inp):
        hi, gi, mi = inp
        neg_logits = jnp.einsum("bsd,nd->bsn", hi, neg_emb).astype(jnp.float32)
        # sampled softmax over [gold | negatives]; max per (b, s) position
        m = jnp.maximum(jnp.max(neg_logits, -1), gi)
        z = jnp.exp(gi - m) + jnp.sum(jnp.exp(neg_logits - m[..., None]), -1)
        nll = (m + jnp.log(z) - gi) * mi
        return carry + jnp.sum(nll), None

    total, _ = jax.lax.scan(chunk, jnp.zeros((), jnp.float32), (hs, gl, mp),
                            unroll=cfg.unroll_scans)
    return total / jnp.maximum(jnp.sum(mask_pos), 1)


def bert4rec_forward(params, batch, cfg: BERT4RecConfig) -> jax.Array:
    """Serving: score the candidate item for the next position."""
    h = bert4rec_encode(params, batch["uih_item_id"], batch["uih_mask"], cfg)
    user_repr = h[:, -1]                                      # (B, D)
    cand = _lookup(params["item_table"], batch["cand_item_id"], cfg, h.dtype)
    return jnp.sum(user_repr * cand, axis=-1)


# ===========================================================================
# DLRM-UIH — the paper's flagship long-sequence ranking model
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class DLRMUIHConfig:
    name: str = "dlrm-uih"
    seq_len: int = 2048
    d_seq: int = 128              # sequence-encoder width
    n_seq_layers: int = 2
    n_heads: int = 4
    n_dense: int = 13
    n_sparse: int = 4
    embed_dim: int = 64           # sparse field embedding dim
    item_vocab: int = 10_000_000
    field_vocab: int = 1_000_000
    top_mlp: Tuple[int, ...] = (512, 256)
    compute_dtype: Any = jnp.bfloat16
    mesh: Any = None
    data_axes: Tuple[str, ...] = ("data",)
    remat: bool = True
    unroll_scans: bool = False
    q_chunk: int = 512

    def param_count(self) -> int:
        return _count(self, init_dlrm_uih)


def init_dlrm_uih(key, cfg: DLRMUIHConfig) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_seq
    attn_cfg = L.AttnConfig(d_model=d, n_heads=cfg.n_heads,
                            n_kv_heads=cfg.n_heads, head_dim=d // cfg.n_heads,
                            rope_theta=1e4, q_chunk=512)

    def block_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn": L.init_gqa(k1, attn_cfg),
            "ffn": L.init_swiglu(k2, d, 4 * d),
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
        }

    n_inter = 3 + cfg.n_sparse   # user_seq, target, dense_proj + sparse fields
    d_pairs = n_inter * (n_inter - 1) // 2
    return {
        "item_table": init_table(ks[0], cfg.item_vocab, d),
        "action_table": init_table(ks[1], 16, d),
        "sparse_tables": init_table(ks[2], cfg.n_sparse * cfg.field_vocab,
                                    cfg.embed_dim),
        "dense_proj": mlp_init(ks[3], [cfg.n_dense, cfg.embed_dim]),
        "seq_blocks": jax.vmap(block_init)(
            jax.random.split(ks[4], cfg.n_seq_layers)
        ),
        "seq_ln": jnp.ones((d,), jnp.float32),
        "seq_proj": mlp_init(ks[5], [d, cfg.embed_dim]),
        "target_proj": mlp_init(ks[6], [d, cfg.embed_dim]),
        "top_mlp": mlp_init(ks[7], [d_pairs + cfg.embed_dim, *cfg.top_mlp, 1]),
    }


def dlrm_uih_forward(params, batch, cfg: DLRMUIHConfig) -> jax.Array:
    dt = cfg.compute_dtype
    b, s = batch["uih_item_id"].shape
    attn_cfg = L.AttnConfig(d_model=cfg.d_seq, n_heads=cfg.n_heads,
                            n_kv_heads=cfg.n_heads,
                            head_dim=cfg.d_seq // cfg.n_heads,
                            rope_theta=1e4, q_chunk=cfg.q_chunk,
                            unroll=cfg.unroll_scans,
                            scores_f32=(cfg.mesh is None))
    # --- UIH sequence encoder (causal, target-aware last token) ---
    e = (_seq_lookup(params["item_table"], batch["uih_item_id"], cfg, dt)
         + params["action_table"].astype(dt)[batch["uih_action_type"]])
    e = _shard_batch_all(e, cfg)
    mask = _shard_batch_all(batch["uih_mask"], cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(h, block):
        hn = L.rms_norm(h, block["ln1"])
        h = h + L.gqa_attention(block["attn"], hn, positions, attn_cfg,
                                causal=True, kv_mask=mask)
        hn = L.rms_norm(h, block["ln2"])
        return h + L.swiglu(block["ffn"], hn), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, e, params["seq_blocks"], unroll=cfg.unroll_scans)
    h = L.rms_norm(h, params["seq_ln"])

    # target-aware pooling: attention of the candidate over history (DIN-style)
    tgt = _lookup(params["item_table"], batch["cand_item_id"], cfg, dt)  # (B, D)
    att = jnp.einsum("bsd,bd->bs", h, tgt,
                     preferred_element_type=jnp.float32)
    att = jax.nn.softmax(
        jnp.where(mask, att / np.sqrt(cfg.d_seq), -1e30), axis=-1
    ).astype(dt)
    user_seq = jnp.einsum("bs,bsd->bd", att, h)                        # (B, D)

    # --- DLRM-style feature interaction ---
    offsets = jnp.arange(cfg.n_sparse) * cfg.field_vocab
    sparse = _seq_lookup(params["sparse_tables"],
                         batch["sparse_ids"] + offsets, cfg, dt)
    dense = mlp_apply(params["dense_proj"], batch["dense"].astype(dt), 1)
    feats = jnp.stack(
        [
            mlp_apply(params["seq_proj"], user_seq, 1),
            mlp_apply(params["target_proj"], tgt, 1),
            dense,
        ]
        + [sparse[:, i] for i in range(cfg.n_sparse)],
        axis=1,
    )                                                                  # (B, F, D)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(feats.shape[1], k=1)
    pairs = inter[:, iu, ju]                                           # (B, F*(F-1)/2)
    z = jnp.concatenate([pairs, dense], axis=-1)
    return mlp_apply(params["top_mlp"], z, len(cfg.top_mlp) + 1)[:, 0]


def dlrm_uih_loss(params, batch, cfg) -> jax.Array:
    return bce_with_logits(dlrm_uih_forward(params, batch, cfg), batch["label"])


# ===========================================================================
# retrieval_cand paths: 1 query scored against N candidates (no python loops)
# ===========================================================================

def bert4rec_score_candidates(params, batch, cand_ids, cfg) -> jax.Array:
    h = bert4rec_encode(params, batch["uih_item_id"], batch["uih_mask"], cfg)
    user_repr = h[:, -1]                                       # (1, D)
    cand = params["item_table"].astype(h.dtype)[cand_ids]      # (N, D)
    return user_repr @ cand.T                                  # (1, N)


def dcn_v2_score_candidates(params, batch, cand_ids, cfg: DCNv2Config) -> jax.Array:
    """Offline bulk scoring: broadcast the user context across N candidates;
    sparse field 0 is the candidate item."""
    n = cand_ids.shape[0]
    sparse = jnp.broadcast_to(batch["sparse_ids"], (n, cfg.n_sparse))
    sparse = sparse.at[:, 0].set(cand_ids)
    dense = jnp.broadcast_to(batch["dense"], (n, cfg.n_dense))
    return dcn_v2_forward(params, {"sparse_ids": sparse, "dense": dense}, cfg)


def dien_score_candidates(params, batch, cand_ids, cand_cats,
                          cfg: DIENConfig) -> jax.Array:
    """GRU-1 interest extraction runs ONCE; target-aware attention + AUGRU run
    batched over the N candidates."""
    dt = cfg.compute_dtype
    ids, cats = batch["uih_item_id"], batch["uih_category"]    # (1, S)
    mask = batch["uih_mask"].astype(dt)
    e = jnp.concatenate(
        [_seq_lookup(params["item_table"], ids, cfg, dt),
         _seq_lookup(params["cat_table"], cats, cfg, dt)],
        axis=-1,
    )                                                          # (1, S, 2D)
    h0 = jnp.zeros((1, cfg.gru_dim), dt)

    def step1(h, inp):
        x, mk = inp
        h_new = _gru_cell(params["gru1"], h, x)
        h = jnp.where(mk[:, None] > 0, h_new, h)
        return h, h

    _, interests = jax.lax.scan(step1, h0, (e.transpose(1, 0, 2), mask.T),
                                unroll=cfg.unroll_scans)
    interests = interests[:, 0]                                # (S, H)

    n = cand_ids.shape[0]
    tgt = jnp.concatenate(
        [params["item_table"].astype(dt)[cand_ids],
         params["cat_table"].astype(dt)[cand_cats]], axis=-1,
    )                                                          # (N, 2D)
    att_logits = jnp.einsum("sh,hd,nd->ns", interests,
                            params["att_w"].astype(dt), tgt,
                            preferred_element_type=jnp.float32)
    att = jax.nn.softmax(
        jnp.where(mask[0][None, :] > 0, att_logits, -1e30), axis=-1
    ).astype(dt)                                               # (N, S)

    hn0 = jnp.zeros((n, cfg.gru_dim), dt)

    def step2(h, inp):
        x, a, mk = inp                                         # (H,), (N,), ()
        xb = jnp.broadcast_to(x[None, :], (n, cfg.gru_dim))
        h_new = _gru_cell(params["augru"], h, xb, a[:, None])
        return jnp.where(mk > 0, h_new, h), None

    final, _ = jax.lax.scan(step2, hn0, (interests, att.T, mask[0]),
                            unroll=cfg.unroll_scans)
    hist_sum = jnp.sum(e[0] * mask[0][:, None], axis=0)        # (2D,)
    z = jnp.concatenate(
        [final, tgt, jnp.broadcast_to(hist_sum[None, :], (n, tgt.shape[1]))],
        axis=-1,
    )
    return mlp_apply(params["mlp"], z, len(cfg.mlp) + 1)[:, 0]


def dlrm_uih_score_candidates(params, batch, cand_ids,
                              cfg: DLRMUIHConfig) -> jax.Array:
    """Sequence encoder runs ONCE; target-aware pooling + interaction + top
    MLP run batched over N candidates."""
    dt = cfg.compute_dtype
    b, s = batch["uih_item_id"].shape
    assert b == 1
    attn_cfg = L.AttnConfig(d_model=cfg.d_seq, n_heads=cfg.n_heads,
                            n_kv_heads=cfg.n_heads,
                            head_dim=cfg.d_seq // cfg.n_heads,
                            rope_theta=1e4, q_chunk=cfg.q_chunk,
                            unroll=cfg.unroll_scans)
    e = (_seq_lookup(params["item_table"], batch["uih_item_id"], cfg, dt)
         + params["action_table"].astype(dt)[batch["uih_action_type"]])
    mask = batch["uih_mask"]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (1, s))

    def body(h, block):
        hn = L.rms_norm(h, block["ln1"])
        h = h + L.gqa_attention(block["attn"], hn, positions, attn_cfg,
                                causal=True, kv_mask=mask)
        hn = L.rms_norm(h, block["ln2"])
        return h + L.swiglu(block["ffn"], hn), None

    h, _ = jax.lax.scan(body, e, params["seq_blocks"], unroll=cfg.unroll_scans)
    h = L.rms_norm(h, params["seq_ln"])[0]                     # (S, D)

    n = cand_ids.shape[0]
    tgt = _lookup(params["item_table"], cand_ids, cfg, dt)     # (N, D)
    att = jnp.einsum("sd,nd->ns", h, tgt, preferred_element_type=jnp.float32)
    att = jax.nn.softmax(
        jnp.where(mask[0][None, :], att / np.sqrt(cfg.d_seq), -1e30), axis=-1
    ).astype(dt)
    user_seq = att @ h                                         # (N, D)

    offsets = jnp.arange(cfg.n_sparse) * cfg.field_vocab
    sparse = _seq_lookup(params["sparse_tables"],
                         batch["sparse_ids"] + offsets, cfg, dt)
    sparse = jnp.broadcast_to(sparse, (n, cfg.n_sparse, cfg.embed_dim))
    dense = mlp_apply(params["dense_proj"], batch["dense"].astype(dt), 1)
    dense = jnp.broadcast_to(dense, (n, cfg.embed_dim))
    feats = jnp.stack(
        [
            mlp_apply(params["seq_proj"], user_seq, 1),
            mlp_apply(params["target_proj"], tgt, 1),
            dense,
        ]
        + [sparse[:, i] for i in range(cfg.n_sparse)],
        axis=1,
    )
    inter = jnp.einsum("nfd,ngd->nfg", feats, feats)
    iu, ju = jnp.triu_indices(feats.shape[1], k=1)
    pairs = inter[:, iu, ju]
    z = jnp.concatenate([pairs, dense], axis=-1)
    return mlp_apply(params["top_mlp"], z, len(cfg.top_mlp) + 1)[:, 0]
