"""Streaming probe side (paper §3.2): stream -> deadline/size-bounded
micro-batches.

``StreamingSource`` drains a ``TrainingExampleStream`` into micro-batches that
flush on whichever bound trips first:

  * **size** — ``max_examples`` reached (throughput mode under backlog);
  * **deadline** — ``max_delay_s`` elapsed since the batch's first example
    (freshness mode under trickle traffic: a lone example never waits longer
    than the deadline for company);
  * **drain** — the stream is closed and empty (``TrainingExampleStream.drained``
    disambiguates this from a consume timeout), flushing the remainder.

The emitted micro-batches are the work items the ``DPPWorkerPool`` feeds to
``DPPWorker.process_jagged`` — the streaming trainer reuses the batch data
plane unchanged. The source also tracks the freshness signals the session
aggregates: per-example publish→drain latency and the stream backlog (lag).

``ack()`` releases the examples' generation leases once they have been
materialized — the "drained" transition that lets the store GC superseded
generations.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional

from repro.core.versioning import TrainingExample
from repro.storage.stream import StreamDisconnect, TrainingExampleStream


@dataclasses.dataclass
class MicroBatchConfig:
    max_examples: int = 32     # size bound (flush when reached)
    max_delay_s: float = 0.05  # deadline bound from the batch's FIRST example
    poll_s: float = 0.02       # consume-wait granularity (drain/deadline checks)


@dataclasses.dataclass
class SourceStats:
    examples: int = 0
    micro_batches: int = 0
    size_flushes: int = 0
    deadline_flushes: int = 0
    drain_flushes: int = 0
    reconnects: int = 0               # transient StreamDisconnects healed
    publish_to_drain_s: float = 0.0   # summed over latency_samples
    latency_samples: int = 0
    max_lag: int = 0                  # peak stream backlog observed

    @property
    def mean_publish_to_drain_s(self) -> float:
        if not self.latency_samples:
            return 0.0
        return self.publish_to_drain_s / self.latency_samples


class StreamingSource:
    def __init__(self, stream: TrainingExampleStream,
                 cfg: Optional[MicroBatchConfig] = None):
        self.stream = stream
        self.cfg = cfg or MicroBatchConfig()
        self.stats = SourceStats()
        # optional repro.obs.Telemetry (control-plane events)
        self.telemetry = None
        # attach: examples published from here on get freshness clocks (the
        # pre-attach backlog is catch-up traffic — latency samples would only
        # measure how old the backlog is, not the live loop)
        stream.track_freshness = True
        # publish wall clocks held until the session settles event->gradient
        self._pub_wall: Dict[int, float] = {}

    # -- micro-batching ---------------------------------------------------------
    def micro_batches(self) -> Iterator[List[TrainingExample]]:
        cfg = self.cfg
        buf: List[TrainingExample] = []
        deadline = 0.0
        while True:
            if buf:
                timeout = min(cfg.poll_s,
                              max(0.0, deadline - time.perf_counter()))
            else:
                timeout = cfg.poll_s
            try:
                exm = self.stream.consume(timeout=timeout)
            except StreamDisconnect:
                # transient broker failure: the stream retains unacked
                # messages, so reconnect-and-repoll loses nothing (and the
                # buffered micro-batch keeps its deadline)
                self.stats.reconnects += 1
                if self.telemetry is not None:
                    self.telemetry.events.emit(
                        "stream_reconnect", reconnects=self.stats.reconnects)
                continue
            now = time.perf_counter()
            if exm is not None:
                if not buf:
                    deadline = now + cfg.max_delay_s
                buf.append(exm)
                pw = self.stream.publish_wall(exm.request_id)
                if pw is not None:
                    self._pub_wall[exm.request_id] = pw
                    self.stats.publish_to_drain_s += now - pw
                    self.stats.latency_samples += 1
                lag = self.stream.lag()
                if lag > self.stats.max_lag:
                    self.stats.max_lag = lag
                if len(buf) >= cfg.max_examples:
                    self.stats.size_flushes += 1
                    yield self._emit(buf)
                    buf = []
                elif now >= deadline:
                    # a steady trickle keeps consume() succeeding — the
                    # deadline must flush here too, not only on a timeout
                    self.stats.deadline_flushes += 1
                    yield self._emit(buf)
                    buf = []
                continue
            # consume returned None: end of stream, deadline, or plain timeout
            if self.stream.drained:
                if buf:
                    self.stats.drain_flushes += 1
                    yield self._emit(buf)
                return
            if buf and now >= deadline:
                self.stats.deadline_flushes += 1
                yield self._emit(buf)
                buf = []

    def _emit(self, buf: List[TrainingExample]) -> List[TrainingExample]:
        self.stats.examples += len(buf)
        self.stats.micro_batches += 1
        return list(buf)

    # -- lease + freshness bookkeeping ------------------------------------------
    def ack(self, examples) -> None:
        """Release generation leases of materialized examples (drained), and
        drop any publish clocks nobody harvested — a session pops them first
        via ``pop_pub_wall``; a session-less consumer (e.g. a streaming
        audit) must not accrete them forever."""
        for exm in examples:
            self.stream.ack(exm)
            self._pub_wall.pop(getattr(exm, "request_id", exm), None)

    def pop_pub_wall(self, request_id: int) -> Optional[float]:
        return self._pub_wall.pop(request_id, None)

    def discard(self, example) -> None:
        """Forget a skipped example entirely (lease + freshness clock) — the
        backfill coordinator's duplicate filter uses this."""
        self.ack([example])
