"""O2O consistency + future-leakage properties of the VLM protocol (paper §3.3).

The central claims under test:
  * VLM reconstruction == Fat Row snapshot == inference-time UIH, exactly;
  * no future leakage: materialized UIH never contains events > T_request;
  * checksum validation catches immutable-window drift;
  * the protocol is training-paradigm agnostic (stream vs warehouse replay).
"""
import numpy as np
import pytest

from repro.core import events as ev
from repro.core.consistency import (
    audit,
    batches_equal,
    future_leakage_count,
    project_reference,
)
from repro.core.materialize import ChecksumMismatch, Materializer
from repro.core.projection import table1_tenants
from repro.core.simulation import ProductionSim, SimConfig
from repro.core.versioning import TrainingExample


def _small_sim(mode="vlm", days=3, users=6, seed=0):
    cfg = SimConfig(
        stream=ev.StreamConfig(
            n_users=users, n_items=2_000, days=days + 1,
            events_per_user_day_mean=30.0, seed=seed,
        ),
        stripe_len=16,
        requests_per_user_day=3,
        mode=mode,
        seed=seed,
    )
    sim = ProductionSim(cfg)
    sim.run_days(days)
    return sim


@pytest.fixture(scope="module")
def vlm_sim():
    return _small_sim("vlm")


@pytest.fixture(scope="module")
def fat_sim():
    return _small_sim("fatrow")


def test_o2o_exact_reconstruction(vlm_sim):
    report = audit(
        vlm_sim.examples,
        vlm_sim.references,
        vlm_sim.materializer(),
        vlm_sim.schema,
    )
    assert report.examples == len(vlm_sim.examples) > 0
    assert report.o2o_mismatches == 0
    assert report.leaked_events == 0


def test_o2o_under_every_tenant_projection(vlm_sim):
    mat = vlm_sim.materializer()
    for tenant in table1_tenants(long_len=256, mid_len=64, short_len=8).values():
        report = audit(
            vlm_sim.examples, vlm_sim.references, mat, vlm_sim.schema, tenant
        )
        assert report.o2o_mismatches == 0, tenant.name
        assert report.leaked_events == 0, tenant.name


def test_fatrow_baseline_equals_reference(fat_sim):
    mat = fat_sim.materializer()
    report = audit(fat_sim.examples, fat_sim.references, mat, fat_sim.schema)
    assert report.o2o_mismatches == 0
    assert report.leaked_events == 0


def test_vlm_matches_fatrow_payload():
    """Same traffic, two snapshotters -> identical training-time UIH."""
    a = _small_sim("vlm", seed=7)
    b = _small_sim("fatrow", seed=7)
    mat_a = a.materializer()
    mat_b = b.materializer()
    assert len(a.examples) == len(b.examples)
    for ex_a, ex_b in zip(a.examples, b.examples):
        assert ex_a.request_ts == ex_b.request_ts
        ua = mat_a.materialize(ex_a)
        ub = mat_b.materialize(ex_b)
        assert batches_equal(ua, ub)


def test_no_future_leakage_even_with_later_ingestion(vlm_sim):
    """Events ingested after T_request (including T_request..T_train interval)
    must be excluded by the versioned window."""
    mat = vlm_sim.materializer()
    for exm in vlm_sim.examples[:50]:
        uih = mat.materialize(exm)
        assert future_leakage_count(uih, exm.request_ts) == 0


def test_replay_after_more_days_is_stable():
    """Batch training replays days-old examples AFTER additional compactions
    have run; reconstruction must still match the inference-time state."""
    sim = _small_sim("vlm", days=2, seed=3)
    examples = list(sim.examples)
    references = list(sim.references)
    sim.run_day(2)  # extra traffic + compaction cycles after logging
    report = audit(examples, references, sim.materializer(), sim.schema)
    assert report.o2o_mismatches == 0
    assert report.leaked_events == 0


def test_checksum_catches_window_drift():
    """If a scrub changes the immutable window, the checksum must fire."""
    sim = _small_sim("vlm", days=2, seed=11)
    # find an example with a non-trivial immutable part
    target = next(e for e in sim.examples if e.version.seq_len > 4)
    # re-compact with a scrub that deletes that user's most common item
    mat_ok = sim.materializer()
    uih = mat_ok.materialize(target)
    item = int(np.bincount(uih["item_id"]).argmax())
    from repro.storage.compaction import make_scrub

    sim.run_compaction(sim.immutable.watermark(target.user_id),
                       scrub=make_scrub(deleted_items=[item]))
    mat = sim.materializer(validate_checksum=True)
    with pytest.raises(ChecksumMismatch):
        mat.materialize(target)


def test_stream_and_warehouse_yield_same_examples(vlm_sim):
    """Bifurcated protocol (§3.2): streaming consumers and warehouse replay
    observe byte-identical example payloads."""
    hours = vlm_sim.warehouse.hours()
    assert hours
    wh_examples = []
    for h in hours:
        wh_examples.extend(vlm_sim.warehouse.read_partition(h))
    by_id = {e.request_id: e for e in wh_examples}
    assert len(by_id) == len(vlm_sim.examples)
    mat = vlm_sim.materializer()
    for exm in vlm_sim.examples[:25]:
        replayed = by_id[exm.request_id]
        assert replayed.user_id == exm.user_id
        assert replayed.version == exm.version
        assert batches_equal(mat.materialize(exm), mat.materialize(replayed))


def test_vlm_examples_are_much_smaller():
    """With realistic lookbacks the immutable tier dominates the sequence, so
    removing it from the primary row must collapse the example payload."""

    def _long_sim(mode):
        cfg = SimConfig(
            stream=ev.StreamConfig(
                n_users=3, n_items=2_000, days=8,
                events_per_user_day_mean=80.0, seed=5,
            ),
            stripe_len=32,
            requests_per_user_day=2,
            mode=mode,
            seed=5,
        )
        sim = ProductionSim(cfg)
        sim.run_days(7, capture_reference=False)
        return sim

    vlm, fat = _long_sim("vlm"), _long_sim("fatrow")
    # compare only the mature days (day>=4) where history has accumulated
    vlm_bytes = sum(e.payload_bytes(vlm.schema) for e in vlm.examples
                    if e.request_ts >= 4 * ev.MS_PER_DAY)
    fat_bytes = sum(e.payload_bytes(fat.schema) for e in fat.examples
                    if e.request_ts >= 4 * ev.MS_PER_DAY)
    assert vlm_bytes < 0.5 * fat_bytes  # UIH payload removed from primary data
