"""Deterministic fault injection for the data plane (§10).

Chaos scenarios in this repo are *planned*, not raced: a ``FaultPlan`` is a
schedule of injected faults keyed to operation ticks (the Nth store scan, the
Nth stream consume), built either explicitly (``FaultSpec(kind, at)``) or from
a seed + per-kind rates (``FaultPlan.seeded``). ``FaultyStore`` /
``FaultyStream`` / ``FaultySim`` wrap the real objects and consult the plan at
every operation — any sim, store, or feed accepts the wrapper unchanged, so
every chaos test is a reproducible seed instead of a sleep-race.

Injectable kinds:

  * ``scan_ioerror``        — the Nth store scan raises ``InjectedIOError``
                              (transient remote-I/O failure);
  * ``decode_corruption``   — the Nth store scan raises ``DecodeCorruption``
                              (a stripe's payload failed its decode CRC; real
                              decoders detect this, they don't return garbage);
  * ``worker_crash``        — the Nth store scan raises ``WorkerCrash``,
                              killing the DPP worker thread mid-item;
  * ``compaction_during_scan`` — the plan's ``on_compact`` callback (e.g.
                              ``sim.run_compaction``) runs immediately before
                              the Nth scan: a generation flip races the read;
  * ``node_unavailable``    — the Nth store scan finds one store node of the
                              disaggregated tier down and raises
                              ``NodeUnavailable`` (retryable: the node is back
                              for the retry, no lease is leaked);
  * ``node_flap``           — store node ``spec.node`` goes DOWN at the Nth
                              scan tick and comes back (``recover()``: missed
                              loads replayed, orphan leases settled) after
                              ``spec.duration`` further ticks. Requires the
                              sharded tier; with replicas the flap is absorbed
                              by failover, at r=1 it degrades to the retry
                              path;
  * ``node_slow``           — store node ``spec.node`` serves every round-trip
                              ``spec.factor`` x slower for ``spec.duration``
                              ticks (a stuck disk / hot neighbor, not an
                              error): correctness is unaffected, hedged reads
                              are the mitigation;
  * ``stream_disconnect``   — the Nth stream consume raises
                              ``StreamDisconnect`` (healed in place by
                              ``StreamingSource``).

What is *recoverable*: all of the above. Scan-level faults surface as a dead
worker; ``DPPWorkerPool`` self-healing (``max_item_retries``) requeues the
item and respawns the worker, and ordered placement keeps the output
byte-identical to a fault-free run. Determinism caveat: the schedule (which
tick fires) is exact; with multiple worker threads, *which work item* owns a
given tick depends on scheduling — the harness's guarantee is that the output
is byte-identical regardless, which is precisely what the chaos tests assert.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.storage.protocol import NodeUnavailable
from repro.storage.stream import StreamDisconnect


class InjectedFault(Exception):
    """Marker base for harness-injected failures."""


class InjectedIOError(InjectedFault, IOError):
    """Transient store-side I/O failure (remote scan timed out / reset)."""


class DecodeCorruption(InjectedFault, IOError):
    """A stripe blob failed its payload CRC during decode."""


class WorkerCrash(InjectedFault, RuntimeError):
    """Simulated hard death of the DPP worker processing the current item."""


SCAN_KINDS = ("compaction_during_scan", "scan_ioerror", "decode_corruption",
              "worker_crash", "node_unavailable", "node_flap", "node_slow")
CONSUME_KINDS = ("stream_disconnect",)
ALL_KINDS = SCAN_KINDS + CONSUME_KINDS
# kinds that flip durable node health state instead of raising at the caller
NODE_STATE_KINDS = ("node_flap", "node_slow")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: ``kind`` fires at 0-based tick ``at`` of its
    scope's operation counter (scan kinds count store scans, stream kinds
    count consumes). ``node``/``duration``/``factor`` only apply to the
    node-state kinds (``node_flap``, ``node_slow``): the state flips at tick
    ``at`` and restores ``duration`` ticks later."""

    kind: str
    at: int
    node: int = 0
    duration: int = 2
    factor: float = 8.0

    def __post_init__(self):
        if self.kind not in ALL_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {ALL_KINDS}")
        if self.at < 0:
            raise ValueError(f"fault tick must be >= 0, got {self.at}")
        if self.kind in NODE_STATE_KINDS and self.duration < 1:
            raise ValueError(
                f"{self.kind} duration must be >= 1 tick, got {self.duration}")
        if self.kind == "node_slow" and self.factor < 1.0:
            raise ValueError(
                f"node_slow factor must be >= 1, got {self.factor}")


class FaultPlan:
    """A thread-safe, reproducible schedule of injected faults.

    ``fired`` records every fault actually injected (for assertions);
    ``on_compact`` is the callback ``compaction_during_scan`` invokes
    (typically ``lambda: sim.run_compaction(...)``)."""

    def __init__(self, faults: Iterable[FaultSpec] = (),
                 on_compact: Optional[Callable[[], None]] = None):
        self.on_compact = on_compact
        # kind -> {tick: spec}: node-state kinds carry parameters, so the
        # full spec is kept (iterating a kind's entry still yields ticks)
        self._ticks: Dict[str, Dict[int, FaultSpec]] = {
            k: {} for k in ALL_KINDS}
        for f in faults:
            self._ticks[f.kind][f.at] = f
        self._counters = {"scan": 0, "consume": 0}
        self._lock = threading.Lock()
        self.fired: List[FaultSpec] = []

    @classmethod
    def seeded(cls, seed: int, rates: Dict[str, float], horizon: int,
               on_compact: Optional[Callable[[], None]] = None) -> "FaultPlan":
        """Draw a schedule from per-kind fault rates over ``horizon`` ticks:
        e.g. ``rates={"scan_ioerror": 0.01}`` fires at ~1% of scans. The same
        seed always produces the same schedule."""
        rng = np.random.default_rng(seed)
        faults: List[FaultSpec] = []
        for kind in sorted(rates):           # draw order fixed -> reproducible
            hits = np.nonzero(rng.random(horizon) < rates[kind])[0]
            faults.extend(FaultSpec(kind, int(t)) for t in hits)
        return cls(faults, on_compact=on_compact)

    def _fire(self, scope: str,
              kinds: Sequence[str]) -> Tuple[int, List[FaultSpec]]:
        with self._lock:
            t = self._counters[scope]
            self._counters[scope] = t + 1
            due = [self._ticks[k][t] for k in kinds if t in self._ticks[k]]
            self.fired.extend(due)
            return t, due

    def scan_tick(self) -> Tuple[int, List[FaultSpec]]:
        """Advance the scan-op counter; returns (tick, faults due at it)."""
        return self._fire("scan", SCAN_KINDS)

    def consume_tick(self) -> Tuple[int, List[FaultSpec]]:
        return self._fire("consume", CONSUME_KINDS)

    @property
    def n_fired(self) -> int:
        with self._lock:
            return len(self.fired)


class _Delegate:
    """Transparent wrapper base: unknown attribute reads AND writes pass
    through to the wrapped object (e.g. ``StreamingSource`` setting
    ``stream.track_freshness`` must reach the real stream)."""

    _OWN = ("inner", "fault_plan")

    def __init__(self, inner, fault_plan: FaultPlan):
        object.__setattr__(self, "inner", inner)
        object.__setattr__(self, "fault_plan", fault_plan)

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def __setattr__(self, name, value):
        if name in type(self)._OWN:
            object.__setattr__(self, name, value)
        else:
            setattr(self.inner, name, value)


class FaultyStore(_Delegate):
    """Wraps an ``ImmutableUIHStore``: every scan entry point first consults
    the plan (one tick per call — a batched multi-range scan is one remote
    round-trip, hence one failure domain).

    Node-state kinds (``node_flap``, ``node_slow``) do not raise here: they
    flip durable health state on the wrapped SHARDED store
    (``set_node_down``/``recover``/``set_node_slow``) and schedule their own
    restore ``duration`` ticks later — the failure surfaces (or doesn't)
    through the store's replica failover, exactly like production."""

    _OWN = ("inner", "fault_plan", "_restores", "_restore_lock")

    def __init__(self, inner, fault_plan: FaultPlan):
        super().__init__(inner, fault_plan)
        # [(restore_tick, fn)]: pending node-state restores
        object.__setattr__(self, "_restores", [])
        object.__setattr__(self, "_restore_lock", threading.Lock())

    def _flip_node_state(self, f: FaultSpec) -> None:
        store = self.inner
        if not hasattr(store, "set_node_down"):
            raise ValueError(
                f"fault kind {f.kind!r} needs the sharded store tier "
                f"(n_store_nodes > 0); got {type(store).__name__}")
        if f.kind == "node_flap":
            store.set_node_down(f.node)
            restore = lambda n=f.node: store.recover(n)   # noqa: E731
        else:   # node_slow
            store.set_node_slow(f.node, f.factor)
            restore = lambda n=f.node: store.set_node_slow(n, 1.0)  # noqa: E731
        self._restores.append((f.at + f.duration, restore))

    def _maybe_fault(self) -> None:
        tick, due = self.fault_plan.scan_tick()
        with self._restore_lock:
            # settle expired node-state faults BEFORE this tick's new ones:
            # a flap scheduled [at, at + duration) is back up at restore time
            still = [(at, fn) for at, fn in self._restores if tick < at]
            expired = [fn for at, fn in self._restores if tick >= at]
            self._restores[:] = still
            for fn in expired:
                fn()
            for f in due:
                if f.kind in NODE_STATE_KINDS:
                    self._flip_node_state(f)
        for f in due:
            if f.kind == "compaction_during_scan":
                cb = self.fault_plan.on_compact
                if cb is not None:
                    cb()
            elif f.kind == "scan_ioerror":
                raise InjectedIOError(
                    f"injected store IOError (scan tick {f.at})")
            elif f.kind == "decode_corruption":
                raise DecodeCorruption(
                    f"injected stripe decode corruption (scan tick {f.at})")
            elif f.kind == "worker_crash":
                raise WorkerCrash(
                    f"injected worker crash (scan tick {f.at})")
            elif f.kind == "node_unavailable":
                raise NodeUnavailable(
                    f"injected store-node outage (scan tick {f.at})")

    def settle_node_state(self) -> int:
        """Force-run node-state restores still pending (a flap/slow whose
        restore tick was never reached because the run ended first); returns
        how many were settled. Post-run audits that bypass the wrapper need
        the tier healthy."""
        with self._restore_lock:
            pending = [fn for _at, fn in self._restores]
            self._restores[:] = []
        for fn in pending:
            fn()
        return len(pending)

    def scan(self, req):
        self._maybe_fault()
        return self.inner.scan(req)

    def multi_range_scan(self, reqs, out_stats=None):
        self._maybe_fault()
        return self.inner.multi_range_scan(reqs, out_stats)

    def execute_plan(self, plan, out_stats=None):
        self._maybe_fault()
        return self.inner.execute_plan(plan, out_stats)


class FaultyStream(_Delegate):
    """Wraps a ``TrainingExampleStream``: the Nth ``consume`` raises
    ``StreamDisconnect`` (the broker keeps unacked messages; nothing is
    lost — the consumer reconnects and re-polls)."""

    def consume(self, timeout=None):
        _tick, due = self.fault_plan.consume_tick()
        for f in due:
            if f.kind == "stream_disconnect":
                raise StreamDisconnect(
                    f"injected stream disconnect (consume tick {f.at})")
        return self.inner.consume(timeout=timeout)


class FaultySim:
    """Chaos view of a ``ProductionSim``: the training read path (``immutable``
    store, ``stream``) goes through the fault wrappers; everything else —
    schema, warehouse, examples, snapshotter, compaction — delegates to the
    real sim, so inference and ingestion stay clean. Hand it to ``open_feed``
    in place of the sim."""

    def __init__(self, sim, fault_plan: FaultPlan):
        self.sim = sim
        self.fault_plan = fault_plan
        self.immutable = FaultyStore(sim.immutable, fault_plan)
        self.stream = FaultyStream(sim.stream, fault_plan)

    def __getattr__(self, name):
        return getattr(self.sim, name)


def wrap_sim(sim, fault_plan: FaultPlan) -> FaultySim:
    """Convenience: ``open_feed(spec, wrap_sim(sim, plan))``."""
    return FaultySim(sim, fault_plan)
