"""DCN-v2 [arXiv:2008.13535]: 13 dense + 26 sparse, embed 16, 3 full-rank
cross layers, deep MLP 1024-1024-512."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import DCNv2Config

FULL = DCNv2Config(
    name="dcn-v2", n_dense=13, n_sparse=26, embed_dim=16, n_cross_layers=3,
    mlp=(1024, 1024, 512), field_vocab=1_000_448,
)

SMOKE = DCNv2Config(
    name="dcn-v2-smoke", n_dense=13, n_sparse=5, embed_dim=4,
    n_cross_layers=2, mlp=(32, 16), field_vocab=100,
    compute_dtype=jnp.float32,
)


def spec() -> ArchSpec:
    return ArchSpec("dcn-v2", "recsys", FULL, SMOKE, RECSYS_SHAPES)
