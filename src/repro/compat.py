"""Version-portability shims for the jax API surface this repo uses.

The repo targets the modern jax API (``jax.set_mesh``, ``jax.shard_map``);
these helpers fall back to the older spellings so the same code runs on
jax 0.4.x (``jax.experimental.shard_map``, ``with mesh:``) through current
releases without scattering version checks across call sites.
"""
from __future__ import annotations

import contextlib

import jax


def set_mesh(mesh: "jax.sharding.Mesh"):
    """Context manager installing ``mesh`` as the ambient mesh so ``jax.jit``
    accepts bare ``PartitionSpec`` shardings.

      * jax >= 0.6:   ``jax.set_mesh(mesh)``
      * jax ~= 0.5:   ``jax.sharding.use_mesh(mesh)``
      * jax <= 0.4.x: the legacy ``with mesh:`` context manager
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)

    @contextlib.contextmanager
    def _legacy():
        with mesh:
            yield mesh

    return _legacy()


def as_shardings(mesh: "jax.sharding.Mesh", tree):
    """Make a ``PartitionSpec`` pytree acceptable to ``jax.jit`` shardings.

    Modern jax resolves bare specs against the ambient mesh (``set_mesh``);
    jax <= 0.4.x requires concrete ``Sharding`` objects, so spec leaves are
    wrapped into ``NamedSharding(mesh, spec)`` there. Non-spec leaves (already
    shardings, or ``None`` subtrees) pass through untouched.
    """
    if hasattr(jax, "set_mesh"):
        return tree
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.tree_util.tree_map(
        lambda leaf: (NamedSharding(mesh, leaf)
                      if isinstance(leaf, PartitionSpec) else leaf),
        tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` when present, else ``jax.experimental.shard_map``.

    The old API calls the replication-checking flag ``check_rep``; the new one
    calls it ``check_vma``. Pass ``check_vma`` and it is translated.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
