"""Training-time versioned late materialization ("Time-Travel", paper §3.3).

Given a logged training example, the materializer:
  1. extracts the version metadata + the snapshotted mutable slice;
  2. issues a bounded multi-range scan against the immutable store using the
     logged temporal boundaries, with the tenant's projection pushed down
     (sequence-length / feature-group / trait);
  3. concatenates immutable + mutable components into the complete UIH that
     exactly reproduces the inference-time state;
  4. optionally validates the checksum logged at inference time.

The logic depends only on the logged metadata, never on the training paradigm,
so streaming and batch training share it unchanged (§3.2).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import events as ev
from repro.core.projection import TenantProjection
from repro.core.versioning import TrainingExample, window_checksum
from repro.storage.immutable_store import ImmutableUIHStore, ScanRequest


class ChecksumMismatch(RuntimeError):
    pass


class StaleGeneration(RuntimeError):
    """The example references an immutable generation whose window is no longer
    reconstructible (e.g. right-to-delete scrubs changed the event set)."""


@dataclasses.dataclass
class MaterializeStats:
    examples: int = 0
    checksum_validated: int = 0
    checksum_failures: int = 0
    immutable_events: int = 0
    mutable_events: int = 0


class Materializer:
    def __init__(
        self,
        immutable: ImmutableUIHStore,
        schema: ev.TraitSchema,
        validate_checksum: bool = False,
        strict: bool = True,
        window_cache_size: int = 0,
    ):
        self.immutable = immutable
        self.schema = schema
        self.validate_checksum = validate_checksum
        self.strict = strict
        self.stats = MaterializeStats()
        # LRU cache of immutable windows persisting ACROSS batches (the DPP
        # worker analogue of the store-side block cache, §4.2.3): all of a
        # user's same-day requests share one immutable window, so streaming
        # and user-bucketed batch jobs both hit heavily.
        self.window_cache_size = window_cache_size
        self._window_cache: "dict" = {}

    # -- single example -------------------------------------------------------
    def materialize(
        self,
        example: TrainingExample,
        projection: Optional[TenantProjection] = None,
    ) -> ev.EventBatch:
        if example.is_fat:
            # Fat Row path: UIH is already materialized; apply projection only.
            return self._project_fat(example, projection)

        meta = example.version
        assert meta is not None, "VLM example missing version metadata"
        mutable_part = example.mutable_uih or ev.empty_batch(self.schema)
        n_mut = ev.batch_len(mutable_part)

        groups = (
            projection.feature_groups
            if projection is not None
            else tuple(self.schema.feature_groups)
        )
        # Sequence-length projection: the tenant wants the *most recent*
        # projection.seq_len events of the full UIH. The immutable fetch uses
        # the full tenant budget (not seq_len - n_mut) so the fetched window is
        # shareable across same-user examples whose mutable slices differ; the
        # final concat+trim keeps exactly seq_len events.
        max_events = -1
        if projection is not None:
            max_events = projection.seq_len

        full_fetch = self._wants_full_window(projection, meta.seq_len, max_events)
        reqs = [
            ScanRequest(
                user_id=example.user_id,
                group=g,
                start_ts=meta.start_ts,
                end_ts=meta.end_ts,
                max_events=meta.seq_len if max_events < 0 else max_events,
                traits=None if projection is None else projection.traits_for(self.schema, g),
            )
            for g in groups
        ]
        parts = self.immutable.multi_range_scan(reqs)
        immutable_part = self._join_groups(parts)

        if self.validate_checksum and meta.checksum and full_fetch:
            self._check(example, immutable_part, meta)

        out = self._concat_and_project(immutable_part, mutable_part, projection)
        self.stats.examples += 1
        self.stats.immutable_events += ev.batch_len(immutable_part)
        self.stats.mutable_events += n_mut
        return out

    def materialize_batch(
        self,
        examples: Sequence[TrainingExample],
        projection: Optional[TenantProjection] = None,
    ) -> List[ev.EventBatch]:
        """Batch path with **data-affinity amortization** (paper §4.2.3): when
        temporally-adjacent examples of the same user share an identical
        immutable window (same version metadata), the range scan is issued once
        and shared across the batch."""
        cache = {}
        out: List[Optional[ev.EventBatch]] = [None] * len(examples)
        for i, ex in enumerate(examples):
            if ex.is_fat or ex.version is None:
                out[i] = self.materialize(ex, projection)
                continue
            # key pins the *content* of the immutable window: same watermark +
            # same length + same checksum => identical event set, even when the
            # lookback start_ts differs slightly between adjacent requests
            key = (
                ex.user_id,
                ex.version.end_ts,
                ex.version.seq_len,
                ex.version.checksum,
                ex.version.generation,
                id(projection),
            )
            imm = cache.get(key)
            if imm is None and self.window_cache_size:
                imm = self._window_cache.get(key)
            if imm is None:
                imm = self._fetch_immutable(ex, projection)
                cache[key] = imm
                if self.window_cache_size:
                    self._window_cache[key] = imm
                    while len(self._window_cache) > self.window_cache_size:
                        self._window_cache.pop(next(iter(self._window_cache)))
            mutable_part = ex.mutable_uih or ev.empty_batch(self.schema)
            out[i] = self._concat_and_project(imm, mutable_part, projection)
            self.stats.examples += 1
            self.stats.immutable_events += ev.batch_len(imm)
            self.stats.mutable_events += ev.batch_len(mutable_part)
        return out  # type: ignore[return-value]

    # -- helpers ---------------------------------------------------------------
    def _fetch_immutable(
        self, example: TrainingExample, projection: Optional[TenantProjection]
    ) -> ev.EventBatch:
        meta = example.version
        assert meta is not None
        groups = (
            projection.feature_groups
            if projection is not None
            else tuple(self.schema.feature_groups)
        )
        max_events = -1 if projection is None else projection.seq_len
        reqs = [
            ScanRequest(
                user_id=example.user_id,
                group=g,
                start_ts=meta.start_ts,
                end_ts=meta.end_ts,
                max_events=meta.seq_len if max_events < 0 else max_events,
                traits=None if projection is None else projection.traits_for(self.schema, g),
            )
            for g in groups
        ]
        parts = self.immutable.multi_range_scan(reqs)
        imm = self._join_groups(parts)
        full = self._wants_full_window(projection, meta.seq_len, max_events)
        if self.validate_checksum and meta.checksum and full:
            self._check(example, imm, meta)
        return imm

    def _wants_full_window(self, projection, snap_len: int, max_events: int) -> bool:
        return projection is None or max_events >= snap_len

    def _join_groups(self, parts: Sequence[ev.EventBatch]) -> ev.EventBatch:
        """Feature groups are horizontal partitions of the SAME event sequence
        (compaction cuts one history into per-group stripes), so after applying
        identical temporal bounds + length budget they are position-aligned."""
        joined: ev.EventBatch = {}
        n = None
        for p in parts:
            if n is None:
                n = ev.batch_len(p)
            else:
                assert ev.batch_len(p) == n, "feature groups misaligned"
                if n and "timestamp" in joined:
                    assert np.array_equal(joined["timestamp"], p["timestamp"])
            joined.update(p)
        return joined

    def _check(self, example, immutable_part: ev.EventBatch, meta) -> None:
        need = {"timestamp", "item_id"}
        if not need <= set(immutable_part):
            return  # projection dropped identity columns; cannot validate
        self.stats.checksum_validated += 1
        got = window_checksum(immutable_part)
        if got != meta.checksum or ev.batch_len(immutable_part) != meta.seq_len:
            self.stats.checksum_failures += 1
            if self.strict:
                raise ChecksumMismatch(
                    f"request {example.request_id}: immutable window changed "
                    f"(gen {meta.generation} -> {self.immutable.generation}); "
                    f"len {meta.seq_len} -> {ev.batch_len(immutable_part)}"
                )

    def _concat_and_project(
        self,
        immutable_part: ev.EventBatch,
        mutable_part: ev.EventBatch,
        projection: Optional[TenantProjection],
    ) -> ev.EventBatch:
        if projection is not None:
            traits = projection.all_traits(self.schema)
            mutable_part = ev.project_traits(mutable_part, [t for t in traits if t in mutable_part])
            if immutable_part:
                immutable_part = ev.project_traits(
                    immutable_part, [t for t in traits if t in immutable_part]
                )
        full = ev.concat_batches([immutable_part, mutable_part])
        if not full:
            cols = (
                projection.all_traits(self.schema)
                if projection is not None
                else self.schema.trait_names
            )
            return ev.empty_batch(self.schema, cols)
        if projection is not None:
            n = ev.batch_len(full)
            if n > projection.seq_len:
                full = ev.slice_batch(full, n - projection.seq_len, n)
        return full

    def _project_fat(
        self, example: TrainingExample, projection: Optional[TenantProjection]
    ) -> ev.EventBatch:
        """Fat Row tenants must filter client-side — the monolithic row has
        already been read in full (this is the multi-tenant penalty)."""
        fat = example.fat_uih or ev.empty_batch(self.schema)
        if projection is None:
            return fat
        traits = [t for t in projection.all_traits(self.schema) if t in fat]
        out = ev.project_traits(fat, traits)
        n = ev.batch_len(out)
        if n > projection.seq_len:
            out = ev.slice_batch(out, n - projection.seq_len, n)
        return out
