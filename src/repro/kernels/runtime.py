"""Shared kernel execution policy: where do the Pallas kernels run?

Every public wrapper in ``kernels/*/ops.py`` asks :func:`interpret_default`
whether to pass ``interpret=True`` to ``pl.pallas_call``. Off-TPU that is
the Pallas **interpreter** executing the *same* kernel body (DMA windows,
masks, sequential-grid carries) on CPU — NOT a numpy reference fallback.
``ref.py`` modules exist only as oracles for the test sweeps; no wrapper
ever routes through them, so tier-1 CI exercises the real kernel logic on
every run (tests/test_kernels.py monkeypatches the refs to raise and proves
it).

``REPRO_KERNELS_FORCE_INTERPRET=1`` forces interpret mode even on a TPU
backend — the parity-debugging escape hatch when a Mosaic lowering is
suspected of diverging from the kernel's semantics.
"""
from __future__ import annotations

import os

import jax


def on_tpu() -> bool:
    """True iff the default jax backend is a real TPU."""
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    """Whether ``pl.pallas_call`` should run in interpret mode by default."""
    if os.environ.get("REPRO_KERNELS_FORCE_INTERPRET"):
        return True
    return not on_tpu()
