"""Disaggregated Data PreProcessing (paper §4.2): workers that materialize
base batches, trainer-side rebatching client, pipelined I/O prefetch, elastic
autoscaling, and data-affinity planning."""
