"""§4.2.3 data-affinity: user bucketing + symmetric sharding for batch
training. Paper: ~60% lookup-bandwidth reduction, +28% per-worker throughput."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import BenchResult, standard_sim
from repro.core.projection import TenantProjection
from repro.dpp.affinity import plan_affine, plan_arrival_order
from repro.dpp.featurize import FeatureSpec
from repro.dpp.worker import DPPWorker

TENANT = TenantProjection("t", seq_len=256,
                          feature_groups=("core", "engagement"))
SPEC = FeatureSpec(seq_len=256, uih_traits=("item_id",))


def _run_plan(sim, plan, emulate_io: bool):
    mat = sim.materializer(validate_checksum=False)
    if emulate_io:
        # remote-storage latency model: per-seek + per-byte + per-shard-hop
        mat.immutable.latency_model = (
            lambda seeks, nbytes, fanout:
            2e-4 * seeks + nbytes / 2e9 + 5e-4 * max(fanout - 1, 0))
    worker = DPPWorker(mat, TENANT, SPEC, sim.schema)
    before = sim.immutable.stats.snapshot()
    t0 = time.perf_counter()
    for item in plan.items:
        worker.process(item)
    wall = time.perf_counter() - t0
    mat.immutable.latency_model = None
    d = sim.immutable.stats.delta(before)
    n = sum(len(i) for i in plan.items)
    return d, n / wall, wall


def run(quick: bool = False) -> List[BenchResult]:
    sim = standard_sim("vlm", users=8, days=2, req_per_day=3) if quick \
        else standard_sim("vlm", users=32, days=6, req_per_day=6)
    n_shards = sim.immutable.router.n_shards
    affine = plan_affine(sim.examples, n_shards, 16)
    arrival = plan_arrival_order(sim.examples, n_shards, 16)

    d_arr, thr_arr, _ = _run_plan(sim, arrival, emulate_io=True)
    d_aff, thr_aff, _ = _run_plan(sim, affine, emulate_io=True)

    bw_delta = 100.0 * (d_aff.bytes_scanned - d_arr.bytes_scanned) \
        / d_arr.bytes_scanned
    thr_delta = 100.0 * (thr_aff - thr_arr) / thr_arr
    return [
        BenchResult(
            "affinity/lookup_bandwidth", 0.0,
            {"ours_pct": round(bw_delta, 1), "paper_pct": -60.0,
             "arrival_bytes": d_arr.bytes_scanned,
             "affine_bytes": d_aff.bytes_scanned,
             "arrival_fanout": round(arrival.expected_fanout, 2),
             "affine_fanout": round(affine.expected_fanout, 2)},
        ),
        BenchResult(
            "affinity/worker_throughput", 0.0,
            {"ours_pct": round(thr_delta, 1), "paper_pct": +28.0,
             "arrival_ex_per_s": round(thr_arr, 1),
             "affine_ex_per_s": round(thr_aff, 1)},
        ),
    ]


if __name__ == "__main__":
    for r in run():
        print(r.csv())
