"""Serving driver: snapshot-consistent two-tower retrieval over the live sim.

The online half of the O2O story, on the real serving tier (`repro.serve`):
a ``RetrievalServer`` coalesces concurrent requests into latency-bounded
micro-batches, materializes each user's UIH under a transient generation
lease (checksum validation ON — a compaction racing the loop can no longer
frankenstein a request), encodes with the two-tower user tower, and answers
batched top-k against a refreshable item-tower candidate index. Repeat users
are served from the per-user embedding cache.

Run:  PYTHONPATH=src python examples/serve_retrieval.py [--requests 512]
"""
import argparse
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.simulation import ProductionSim, SimConfig
from repro.models import recsys as R
from repro.obs import Telemetry
from repro.serve import RetrievalServer, ServeConfig

CORPUS = 4_096
SEQ_LEN = 24
USERS = 64


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=512)
    args = ap.parse_args()

    cfg = R.TwoTowerConfig(name="serve", embed_dim=32, tower_mlp=(64, 32),
                           item_vocab=CORPUS, user_vocab=1_024,
                           uih_len=SEQ_LEN, compute_dtype=jnp.float32)
    params = R.init_two_tower(jax.random.PRNGKey(0), cfg)

    sim = ProductionSim(SimConfig(
        stream=ev.StreamConfig(n_users=USERS, n_items=CORPUS, days=4,
                               events_per_user_day_mean=40.0, seed=1),
        stripe_len=32, requests_per_user_day=4, seed=1))
    sim.run_days(3, capture_reference=False)

    telemetry = Telemetry()
    server = RetrievalServer.from_sim(
        sim, params, cfg, telemetry=telemetry,
        cfg=ServeConfig(max_batch=64, max_delay_s=0.005,
                        lookback_ms=sim.cfg.lookback_ms))
    print(f"candidate index: {len(server.index)} items "
          f"(v{server.index.version})")

    # request mix: live traffic — every request asks for the user's UIH as
    # of NOW (the last logged request time), with the logged user sequence
    # replayed round-robin to --requests and issued from 8 concurrent caller
    # threads (the coalescer re-batches them; a user's second request finds
    # their embedding cached and skips scan+featurize+encode entirely)
    now = max(e.request_ts for e in sim.examples)
    users = [e.user_id for e in
             (sim.examples * (args.requests // len(sim.examples) + 1))[
                 : args.requests]]
    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=8) as pool:
        results = list(pool.map(
            lambda u: server.retrieve(u, now, k=10), users))
    dt = time.perf_counter() - t0
    server.close()

    st, cs = server.stats, server.cache.stats
    print(f"served {st.requests} requests in {dt:.2f}s -> "
          f"{st.requests/dt:.0f} QPS "
          f"({server.coalescer.stats.batches} micro-batches, "
          f"corpus={CORPUS})")
    print(f"cold path: {st.cold_requests}, embedding-cache hits: "
          f"{cs.hits} ({cs.hits / max(1, cs.lookups):.0%})")
    # StoreProtocol stats work for monolith AND sharded backends
    io = server.materializer.io_stats
    print(f"immutable-store scans: {io.requests}, "
          f"bytes: {io.bytes_scanned/1e6:.2f} MB")
    print(f"no leaked leases: {sim.immutable.leased_generations() == {}}")
    r = results[0]
    print(f"sample top-10 for request 0 (gen {r.generation}, "
          f"cached={r.cached}): {r.item_ids.tolist()}")


if __name__ == "__main__":
    main()
