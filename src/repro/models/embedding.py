"""Sharded embedding tables + EmbeddingBag (JAX has no native EmbeddingBag —
implemented as gather + masked segment reduction, as the assignment requires).

Tables are row(vocab)-sharded across the whole mesh for the dry-run; lookups
lower to masked local gathers + an all-reduce under GSPMD (the TPU analogue of
DLRM's model-parallel embedding all-to-all)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map

Params = Dict[str, Any]


def init_table(key, vocab: int, dim: int, scale: float = 0.01) -> jax.Array:
    return jax.random.normal(key, (vocab, dim), jnp.float32) * scale


def bag_rowsharded(
    table: jax.Array,          # (V, D) — sharded P(model_axis, None)
    ids: jax.Array,            # (B, L) — sharded P(data_axes, None)
    mask: Optional[jax.Array],
    combiner: str,
    mesh: jax.sharding.Mesh,
    data_axes=("data",),
    model_axis: str = "model",
    dtype=None,
) -> jax.Array:
    """Row(vocab)-sharded EmbeddingBag with the reduction BEFORE the collective.

    GSPMD's default lowering of a gather from a sharded table all-reduces the
    full (B, L, D) pre-reduction gather output; here each model-rank gathers
    hits among its local rows, reduces the bag locally, and psums only the
    (B_local, D) bag result — O(L) less collective traffic. The table is
    replicated over ``data`` (optimizer states stay ZeRO-sharded)."""
    from jax.sharding import PartitionSpec as P

    v, d = table.shape
    dt = dtype or table.dtype
    table = table.astype(dt)   # cast BEFORE shard_map: collectives move bf16
    b, l = ids.shape
    mask_arr = (jnp.ones_like(ids, jnp.bool_) if mask is None else mask)

    def inner(tab, idx, mk):
        rank = jax.lax.axis_index(model_axis)
        v_loc = tab.shape[0]
        lo = rank * v_loc
        local = idx - lo
        hit = (local >= 0) & (local < v_loc) & mk
        emb = tab.astype(dt)[jnp.clip(local, 0, v_loc - 1)]   # (B_loc, L, D)
        emb = emb * hit[..., None].astype(dt)
        return jax.lax.psum(jnp.sum(emb, axis=-2), model_axis)

    dp = tuple(data_axes) if data_axes else None
    out = shard_map(
        inner, mesh=mesh,
        in_specs=(P(model_axis, None), P(dp, None), P(dp, None)),
        out_specs=P(dp, None),
        check_vma=False,
    )(table, ids, mask_arr)
    if combiner == "mean":
        denom = jnp.maximum(jnp.sum(mask_arr, -1, keepdims=True), 1).astype(dt)
        out = out / denom
    return out


def lookup_rowsharded(table, ids, mesh, data_axes=("data",),
                      model_axis="model", dtype=None) -> jax.Array:
    """Single-id row-sharded lookup: (B,) ids -> (B, D)."""
    out = bag_rowsharded(table, ids[:, None], None, "sum", mesh, data_axes,
                         model_axis, dtype)
    return out


def seq_rowsharded(table, ids, mesh, data_axes=("data",),
                   model_axis="model", dtype=None) -> jax.Array:
    """Per-position sequence lookup from a row-sharded table: (B, S) ids ->
    (B, S, D). Each model-rank gathers hits among its local rows (compute
    dtype, typically bf16) and the partials are psum'd — half the traffic of
    GSPMD's default f32 partial all-reduce and no stray resharding copies."""
    from jax.sharding import PartitionSpec as P

    dt = dtype or table.dtype
    table = table.astype(dt)   # cast BEFORE shard_map: collectives move bf16

    def inner(tab, idx):
        rank = jax.lax.axis_index(model_axis)
        v_loc = tab.shape[0]
        local = idx - rank * v_loc
        hit = (local >= 0) & (local < v_loc)
        emb = tab.astype(dt)[jnp.clip(local, 0, v_loc - 1)]
        emb = emb * hit[..., None].astype(dt)
        return jax.lax.psum(emb, model_axis)

    dp = tuple(data_axes) if data_axes else None
    return shard_map(
        inner, mesh=mesh,
        in_specs=(P(model_axis, None), P(dp, None)),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(table, ids)


def embedding_bag(
    table: jax.Array,          # (V, D)
    ids: jax.Array,            # (B, L) padded multi-hot ids
    mask: Optional[jax.Array] = None,   # (B, L) validity
    combiner: str = "sum",     # sum | mean | none
    dtype=None,
) -> jax.Array:
    """EmbeddingBag: ragged gather + segment reduction over the bag axis."""
    dt = dtype or table.dtype
    emb = table.astype(dt)[ids]                    # (B, L, D)
    if mask is not None:
        emb = emb * mask[..., None].astype(dt)
    if combiner == "none":
        return emb
    s = jnp.sum(emb, axis=-2)
    if combiner == "sum":
        return s
    if combiner == "mean":
        denom = (
            jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1).astype(dt)
            if mask is not None
            else jnp.asarray(ids.shape[-1], dt)
        )
        return s / denom
    raise ValueError(combiner)


def field_embeddings(
    tables: Dict[str, jax.Array],
    ids: jax.Array,            # (B, F) one id per sparse field
    field_names,
    dtype=None,
) -> jax.Array:
    """Per-field single-hot lookup -> (B, F, D)."""
    cols = [tables[f].astype(dtype or tables[f].dtype)[ids[:, i]]
            for i, f in enumerate(field_names)]
    return jnp.stack(cols, axis=1)


def mlp_init(key, dims, scale=None) -> Params:
    ks = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": jax.random.normal(ks[i], (dims[i], dims[i + 1]), jnp.float32)
        * (scale or 1.0 / np.sqrt(dims[i]))
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), jnp.float32)
        for i in range(len(dims) - 1)
    }


def mlp_apply(params: Params, x: jax.Array, n_layers: int,
              final_act: bool = False) -> jax.Array:
    dt = x.dtype
    for i in range(n_layers):
        x = x @ params[f"w{i}"].astype(dt) + params[f"b{i}"].astype(dt)
        if i < n_layers - 1 or final_act:
            x = jax.nn.relu(x)
    return x
