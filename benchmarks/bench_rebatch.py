"""§4.2.1 trainer-side rebatching: DPP workers process small base batches
(bounded memory, high thread concurrency); the trainer-side client merges them
into the model's full batch. Paper: ~15% per-worker preprocessing throughput
from tuning the base batch size."""
from __future__ import annotations

import threading
import time
from typing import List

from benchmarks.common import BenchResult, standard_sim
from repro.core.projection import TenantProjection
from repro.dpp.client import RebatchingClient
from repro.dpp.featurize import FeatureSpec
from repro.dpp.worker import DPPWorker

TENANT = TenantProjection("t", seq_len=192, feature_groups=("core",))
SPEC = FeatureSpec(seq_len=192, uih_traits=("item_id",))
FULL_BATCH = 128
THREADS = 4
# worker memory budget: materializing ultra-long sequences makes threads
# memory-bound (paper §4.2.1) — working set beyond the budget pays a
# swap/allocator stall, which is what caps the base batch size in production
MEM_BUDGET_BYTES = 72 * 192 * 24 * THREADS
STALL_S_PER_BYTE = 1e-7


def _throughput(sim, base_batch: int) -> float:
    """4 worker threads produce base batches -> rebatching client -> trainer."""
    examples = sim.examples[: (len(sim.examples) // FULL_BATCH) * FULL_BATCH]
    client = RebatchingClient(FULL_BATCH, buffer_batches=64, shuffle_seed=0)
    chunks = [examples[i : i + base_batch]
              for i in range(0, len(examples), base_batch)]
    lock = threading.Lock()
    idx = [0]
    working_set = [0]

    def worker_loop():
        mat = sim.materializer(validate_checksum=False)
        # per-item latency: fixed per-batch overhead + per-example cost
        mat.immutable.latency_model = (
            lambda seeks, nbytes, fanout: 1.5e-3 + nbytes / 3e9)
        w = DPPWorker(mat, TENANT, SPEC, sim.schema)
        while True:
            with lock:
                if idx[0] >= len(chunks):
                    return
                mine = chunks[idx[0]]
                idx[0] += 1
                est = len(mine) * TENANT.seq_len * 24  # decoded working set
                working_set[0] += est
                overflow = max(0, working_set[0] - MEM_BUDGET_BYTES)
            if overflow:
                time.sleep(overflow * STALL_S_PER_BYTE)  # memory pressure
            client.put(w.process(mine))
            with lock:
                working_set[0] -= est

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker_loop) for _ in range(THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return len(examples) / wall


def run(quick: bool = False) -> List[BenchResult]:
    if quick:
        sim = standard_sim("vlm", users=16, days=2, req_per_day=6)
        sizes = [4, FULL_BATCH]
    else:
        sim = standard_sim("vlm", users=32, days=5, req_per_day=6)
        sizes = [4, 16, 64, FULL_BATCH]
    thr = {s: _throughput(sim, s) for s in sizes}
    best = max(thr, key=thr.get)
    # the paper's claim: tuned base batches + trainer-side rebatching beat the
    # naive design (workers emit the model's full batch directly) by ~15%
    gain = 100.0 * (thr[best] - thr[FULL_BATCH]) / thr[FULL_BATCH]
    return [BenchResult(
        "rebatch/base_batch_tuning", 0.0,
        {**{f"thr_b{s}": round(thr[s], 1) for s in sizes},
         "best_base_batch": best,
         "gain_vs_full_batch_pct": round(gain, 1),
         "paper_pct": +15.0},
    )]


if __name__ == "__main__":
    for r in run():
        print(r.csv())
