"""Inference-time snapshotting (paper §3.3, Fig. 3).

During ranking, the service fetches the mutable tier (recent events) and the
immutable tier (long-term history) to assemble the complete UIH for model
inference. Under versioned late materialization, the logged training example
persists only:

  * the **mutable** slice (small: events newer than the immutable watermark),
    physically snapshotted at T_request so no late-arriving event can
    contaminate it; and
  * O(1) **version metadata** for the immutable window (start_ts = lookback
    bound, end_ts = immutable watermark, seq_len, checksum, generation).

The Fat Row baseline snapshotter logs the complete merged UIH instead.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import events as ev
from repro.core.versioning import TrainingExample, VersionMetadata, window_checksum
from repro.storage.immutable_store import ScanRequest
from repro.storage.protocol import StoreProtocol
from repro.storage.mutable_store import MutableUIHStore


@dataclasses.dataclass
class SnapshotterConfig:
    lookback_ms: int = 365 * ev.MS_PER_DAY
    max_seq_len: int = 1 << 20          # union-dataset maximum requirement
    with_checksum: bool = True
    nonseq_bytes: int = 1024            # opaque non-sequence feature payload


class BaseSnapshotter:
    def __init__(
        self,
        mutable: MutableUIHStore,
        immutable: StoreProtocol,
        schema: ev.TraitSchema,
        cfg: Optional[SnapshotterConfig] = None,
    ):
        self.mutable = mutable
        self.immutable = immutable
        self.schema = schema
        self.cfg = cfg or SnapshotterConfig()
        self._next_request_id = 0

    def _fetch_both_tiers(self, user_id: int, request_ts: int):
        """The inference read path: assemble complete UIH at T_request.

        The whole fetch runs under a transient **generation lease** on the
        live generation: the per-feature-group scans and the watermark read
        all resolve the SAME generation even if compaction publishes a new one
        mid-fetch (otherwise the groups could straddle a flip and the logged
        checksum/seq_len would describe a window no generation ever held).
        The leased generation id is what the version metadata records.

        Edge: before the first compaction the lease lands on generation -1,
        which pins nothing (-1 means "live" to scans) — if the FIRST flip
        races the fetch, we refetch against the now-live generation.

        Retention-coupling caveat (§4.1.1): the mutable tier is read after
        the immutable scans; an eviction whose watermark has advanced past
        the leased generation's would silently drop the gap from BOTH the
        example and its reference (consistently — leak-free but lossy).
        Production orders eviction a full cycle behind consolidation; the
        simulator's compactions are either sequential with traffic or run
        with ``evict=False``."""
        start_ts = max(0, request_ts - self.cfg.lookback_ms)
        while True:
            with self.immutable.acquire_lease() as lease:
                gen = lease.generation
                watermark = self.immutable.watermark(user_id, generation=gen)
                end_ts = min(watermark, request_ts)
                reqs = [
                    ScanRequest(user_id=user_id, group=g, start_ts=start_ts,
                                end_ts=end_ts, generation=gen)
                    for g in self.schema.feature_groups
                ]
                parts = self.immutable.multi_range_scan(reqs)
            if gen >= 0 or self.immutable.generation < 0:
                break   # leased fetch was generation-consistent
        immutable_part: ev.EventBatch = {}
        n = None
        for p in parts:
            if n is None:
                n = ev.batch_len(p)
            else:
                assert ev.batch_len(p) == n, "feature groups straddled a flip"
            immutable_part.update(p)
        # mutable tier: strictly newer than the immutable watermark, <= T_request
        # — but never older than the lookback start. When the watermark trails
        # start_ts (a user returning after idling past the lookback window),
        # the immutable scan is empty and an unclamped (watermark, request_ts]
        # read would feed the model mutable events OLDER than the lookback
        # bound no active user's UIH can ever contain (read is exclusive-lo,
        # so start_ts - 1 keeps start_ts itself in-window).
        mutable_part = self.mutable.read(
            user_id, max(end_ts, start_ts - 1), request_ts)
        return immutable_part, mutable_part, start_ts, end_ts, gen

    def inference_uih(self, user_id: int, request_ts: int) -> ev.EventBatch:
        """Complete UIH as seen by the ranking model at T_request (ground truth
        for O2O-consistency checks)."""
        tiers = self._fetch_both_tiers(user_id, request_ts)
        return ev.concat_batches(tiers[:2]) or ev.empty_batch(self.schema)

    def snapshot_with_reference(
        self,
        user_id: int,
        request_ts: int,
        candidate: Dict[str, int],
        labels: Optional[Dict[str, float]] = None,
        label_ts: Optional[int] = None,
        labels_fn=None,
    ):
        """(training example, inference-time ground-truth UIH) from ONE
        two-tier fetch — the pair is consistent by construction, which is what
        makes consistency audits deterministic even when compaction runs
        concurrently with snapshotting (a second fetch could land on the
        other side of a generation flip).

        ``labels_fn(reference_uih) -> labels`` lets label synthesis that
        depends on the inference-time UIH reuse the SAME fetch instead of
        issuing its own (which could straddle a flip)."""
        tiers = self._fetch_both_tiers(user_id, request_ts)
        imm, mut = tiers[0], tiers[1]
        ref = ev.concat_batches([imm, mut]) or ev.empty_batch(self.schema)
        if labels_fn is not None:
            labels = labels_fn(ref)
        return self._build(user_id, request_ts, candidate, labels or {},
                           label_ts, tiers), ref

    def snapshot(
        self,
        user_id: int,
        request_ts: int,
        candidate: Dict[str, int],
        labels: Dict[str, float],
        label_ts: Optional[int] = None,
    ) -> TrainingExample:
        return self._build(user_id, request_ts, candidate, labels, label_ts,
                           self._fetch_both_tiers(user_id, request_ts))

    def _build(self, user_id, request_ts, candidate, labels, label_ts, tiers
               ) -> TrainingExample:
        raise NotImplementedError

    def _alloc_id(self) -> int:
        rid = self._next_request_id
        self._next_request_id += 1
        return rid

    def _context(self, request_id: int) -> bytes:
        """Deterministic stand-in for the non-sequence feature payload
        (identical across VLM and Fat Row snapshotters for fair accounting)."""
        import numpy as _np

        return _np.random.default_rng(request_id).bytes(self.cfg.nonseq_bytes)


class VLMSnapshotter(BaseSnapshotter):
    """Versioned late materialization: log mutable slice + version metadata."""

    def _build(self, user_id, request_ts, candidate, labels, label_ts, tiers
               ) -> TrainingExample:
        imm, mut, start_ts, end_ts, gen = tiers
        seq_len = ev.batch_len(imm)
        checksum = (
            window_checksum(imm) if (self.cfg.with_checksum and seq_len) else 0
        )
        return TrainingExample(
            request_id=self._alloc_id(),
            user_id=user_id,
            request_ts=request_ts,
            label_ts=label_ts if label_ts is not None else request_ts,
            candidate=dict(candidate),
            labels=dict(labels),
            mutable_uih=mut,
            context=self._context(self._next_request_id - 1),
            version=VersionMetadata(
                start_ts=start_ts,
                end_ts=end_ts,
                seq_len=seq_len,
                checksum=checksum,
                generation=gen,   # the generation the scan actually ran on
            ),
        )


class FatRowSnapshotter(BaseSnapshotter):
    """Industry-standard baseline: physically pre-materialize the full UIH."""

    def _build(self, user_id, request_ts, candidate, labels, label_ts, tiers
               ) -> TrainingExample:
        imm, mut = tiers[0], tiers[1]
        fat = ev.concat_batches([imm, mut]) or ev.empty_batch(self.schema)
        return TrainingExample(
            request_id=self._alloc_id(),
            user_id=user_id,
            request_ts=request_ts,
            label_ts=label_ts if label_ts is not None else request_ts,
            candidate=dict(candidate),
            labels=dict(labels),
            fat_uih=fat,
            context=self._context(self._next_request_id - 1),
        )
