"""Process-wide metrics registry: labeled counters, gauges, histograms.

The registry is the convergence point for the repo's ~14 ``*Stats``
dataclasses (DESIGN.md §13).  Legacy stats objects stay the source of truth
on their hot paths — workers mutate plain dataclass fields with zero
registry involvement — and a thin adapter (:func:`publish_dataclass`)
publishes point-in-time snapshots into labeled registry series at snapshot
or merge boundaries (``Feed.snapshot``, store ``stats`` reads, run-dir
dumps).  Direct instrumentation (histograms on the hedging RTT path, the
train-step timer, per-stage span durations) observes into the registry
directly; those paths are one uncontended lock acquire per sample.

Design points:

  * **Families + label sets.**  ``registry.counter(name, labels=("node",))``
    returns a family; ``family.labels(node=3)`` returns the per-series child
    (get-or-create under the family lock, then cached — steady-state lookups
    are a dict hit).  Families with no labels expose the child API directly
    (``family.inc()``), so unlabeled call sites stay one-liners.
  * **Mergeable.**  ``MetricsRegistry.merge_from`` folds another registry
    (e.g. a per-worker or per-node one) into this one by (name, labelset):
    counters add, gauges take the latest write, histograms add bucket
    vectors.  Histogram buckets are fixed at family creation so merges are
    exact.
  * **LatencyTracker-compatible histograms.**  ``Histogram`` optionally
    keeps a bounded sample window (``window=N``) and then serves
    ``quantile(q)`` with the exact same semantics as the legacy
    ``repro.storage.failover.LatencyTracker`` — ``None`` below
    ``min_samples``, index-method quantile over the sorted window — so the
    sharded store's hedge-deadline logic migrates onto a registry metric
    without behavioral drift.  Without a window, ``quantile`` interpolates
    within fixed buckets (good enough for p50/p95/p99 reporting).
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
import threading
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

# Exponential-ish second buckets: 10us .. 60s. Fixed so histograms merge
# exactly across workers/nodes/processes.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


class Counter:
    """Monotone counter. ``inc`` for live increments, ``set_total`` for
    adapter publishing of a cumulative legacy-stats field (monotone max, so
    republishing an older snapshot can never move the series backwards)."""

    kind = "counter"
    __slots__ = ("_value", "_lock")

    def __init__(self) -> None:
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def set_total(self, value: float) -> None:
        with self._lock:
            if value > self._value:
                self._value = float(value)

    @property
    def value(self) -> float:
        return self._value

    def merge_from(self, other: "Counter") -> None:
        self.inc(other.value)

    def to_dict(self) -> Dict[str, Any]:
        return {"value": self._value}


class Gauge:
    """Last-write-wins instantaneous value (queue depths, live workers)."""

    kind = "gauge"
    __slots__ = ("_value", "_lock")

    def __init__(self) -> None:
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        return self._value

    def merge_from(self, other: "Gauge") -> None:
        # Cross-worker gauges are additive (e.g. per-worker queue depths).
        self.inc(other.value)

    def to_dict(self) -> Dict[str, Any]:
        return {"value": self._value}


class Histogram:
    """Fixed-bucket histogram with optional exact-quantile sample window."""

    kind = "histogram"
    __slots__ = ("buckets", "min_samples", "_counts", "_sum", "_count",
                 "_min", "_max", "_window", "_lock")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS,
                 window: int = 0, min_samples: int = 1) -> None:
        self.buckets = tuple(sorted(buckets))
        self.min_samples = min_samples
        self._counts = [0] * (len(self.buckets) + 1)  # +1 overflow bucket
        self._sum = 0.0
        self._count = 0
        self._min = float("inf")
        self._max = float("-inf")
        self._window: Optional[Deque[float]] = (
            collections.deque(maxlen=window) if window else None)
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        idx = bisect.bisect_left(self.buckets, value)
        with self._lock:
            self._counts[idx] += 1
            self._sum += value
            self._count += 1
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value
            if self._window is not None:
                self._window.append(value)

    # LatencyTracker-compatible surface -----------------------------------
    record = observe

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> Optional[float]:
        """q-quantile; ``None`` below ``min_samples`` (a cold histogram must
        not drive hedging decisions). Exact over the sample window when one
        is kept, else interpolated within the fixed buckets."""
        with self._lock:
            if self._count < max(self.min_samples, 1):
                return None
            if self._window:
                ordered = sorted(self._window)
                idx = min(len(ordered) - 1, max(0, int(q * len(ordered))))
                return ordered[idx]
            counts = list(self._counts)
            total = self._count
            lo_all, hi_all = self._min, self._max
        # Bucket interpolation: find the bucket holding the q-th sample and
        # interpolate linearly inside it.
        target = max(0.0, min(1.0, q)) * total
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.buckets[i - 1] if i > 0 else min(lo_all, self.buckets[0])
                hi = self.buckets[i] if i < len(self.buckets) else max(hi_all, self.buckets[-1])
                frac = (target - cum) / c
                return lo + (hi - lo) * frac
            cum += c
        return hi_all

    def observed_at_least(self, seconds: float) -> int:
        """How many window samples are >= ``seconds`` (introspection)."""
        with self._lock:
            if self._window is None:
                idx = bisect.bisect_left(self.buckets, seconds)
                return sum(self._counts[idx:])
            ordered = sorted(self._window)
        return len(ordered) - bisect.bisect_left(ordered, seconds)

    def merge_from(self, other: "Histogram") -> None:
        if other.buckets != self.buckets:
            raise ValueError("cannot merge histograms with different buckets")
        with other._lock:
            counts = list(other._counts)
            osum, ocount = other._sum, other._count
            omin, omax = other._min, other._max
            owindow = list(other._window) if other._window is not None else []
        with self._lock:
            for i, c in enumerate(counts):
                self._counts[i] += c
            self._sum += osum
            self._count += ocount
            self._min = min(self._min, omin)
            self._max = max(self._max, omax)
            if self._window is not None:
                self._window.extend(owindow)

    def to_dict(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "buckets": list(self.buckets),
                "counts": list(self._counts),
                "sum": self._sum,
                "count": self._count,
                "min": self._min if self._count else None,
                "max": self._max if self._count else None,
                "p50": None, "p95": None, "p99": None,
            } | {f"p{int(q * 100)}": self.__quantile_unlocked(q)
                 for q in (0.5, 0.95, 0.99)}

    def __quantile_unlocked(self, q: float) -> Optional[float]:
        # to_dict holds the lock; quantile() re-acquires, so compute from a
        # window copy / bucket walk without locking again.
        if self._count < 1:
            return None
        if self._window:
            ordered = sorted(self._window)
            idx = min(len(ordered) - 1, max(0, int(q * len(ordered))))
            return ordered[idx]
        target = max(0.0, min(1.0, q)) * self._count
        cum = 0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.buckets[i - 1] if i > 0 else min(self._min, self.buckets[0])
                hi = self.buckets[i] if i < len(self.buckets) else max(self._max, self.buckets[-1])
                return lo + (hi - lo) * ((target - cum) / c)
            cum += c
        return self._max


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """A named metric plus its per-labelset children."""

    def __init__(self, name: str, kind: str, help: str,
                 label_names: Tuple[str, ...], **child_kw: Any) -> None:
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = label_names
        self._child_kw = child_kw
        self._children: Dict[Tuple[str, ...], Any] = {}
        self._lock = threading.Lock()

    def _make_child(self) -> Any:
        return _KINDS[self.kind](**self._child_kw)

    def labels(self, **labels: Any):
        try:
            key = tuple(str(labels[n]) for n in self.label_names)
        except KeyError as e:
            raise ValueError(
                f"metric {self.name!r} requires labels {self.label_names}, "
                f"got {tuple(labels)}") from e
        if len(labels) != len(self.label_names):
            raise ValueError(
                f"metric {self.name!r} requires labels {self.label_names}, "
                f"got {tuple(labels)}")
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.get(key)
                if child is None:
                    child = self._make_child()
                    self._children[key] = child
        return child

    @property
    def default(self):
        """The single child of an unlabeled family."""
        return self.labels()

    def series(self) -> List[Tuple[Dict[str, str], Any]]:
        with self._lock:
            items = list(self._children.items())
        return [(dict(zip(self.label_names, key)), child)
                for key, child in items]

    # Unlabeled convenience passthrough ------------------------------------
    def inc(self, n: float = 1.0) -> None:
        self.default.inc(n)

    def set(self, value: float) -> None:
        self.default.set(value)

    def set_total(self, value: float) -> None:
        self.default.set_total(value)

    def observe(self, value: float) -> None:
        self.default.observe(value)

    record = observe

    def quantile(self, q: float) -> Optional[float]:
        return self.default.quantile(q)

    @property
    def value(self) -> float:
        return self.default.value

    @property
    def count(self) -> int:
        return self.default.count


class MetricsRegistry:
    """Get-or-create metric families keyed by name; export + merge."""

    def __init__(self) -> None:
        self._families: Dict[str, Family] = {}
        self._lock = threading.Lock()

    def _family(self, name: str, kind: str, help: str,
                labels: Sequence[str], **child_kw: Any) -> Family:
        fam = self._families.get(name)
        if fam is None:
            with self._lock:
                fam = self._families.get(name)
                if fam is None:
                    fam = Family(name, kind, help, tuple(labels), **child_kw)
                    self._families[name] = fam
        if fam.kind != kind:
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind}, not {kind}")
        if fam.label_names != tuple(labels):
            raise ValueError(
                f"metric {name!r} already registered with labels "
                f"{fam.label_names}, not {tuple(labels)}")
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._family(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._family(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  window: int = 0, min_samples: int = 1) -> Family:
        return self._family(name, "histogram", help, labels,
                            buckets=buckets, window=window,
                            min_samples=min_samples)

    def families(self) -> List[Family]:
        with self._lock:
            return list(self._families.values())

    def merge_from(self, other: "MetricsRegistry") -> None:
        for fam in other.families():
            mine = self._family(fam.name, fam.kind, fam.help,
                                fam.label_names, **fam._child_kw)
            for labels, child in fam.series():
                mine.labels(**labels).merge_from(child)

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for fam in self.families():
            out[fam.name] = {
                "type": fam.kind,
                "help": fam.help,
                "label_names": list(fam.label_names),
                "series": [{"labels": labels, **child.to_dict()}
                           for labels, child in fam.series()],
            }
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (counters get the conventional
        ``_total``-suffixed sample names only if already named that way)."""
        lines: List[str] = []
        for fam in sorted(self.families(), key=lambda f: f.name):
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for labels, child in fam.series():
                base = _fmt_labels(labels)
                if fam.kind == "histogram":
                    cum = 0
                    snap = child.to_dict()
                    for ub, c in zip(snap["buckets"], snap["counts"]):
                        cum += c
                        lines.append(
                            f"{fam.name}_bucket{_fmt_labels(labels, le=ub)} {cum}")
                    cum += snap["counts"][-1]
                    lines.append(
                        f"{fam.name}_bucket{_fmt_labels(labels, le='+Inf')} {cum}")
                    lines.append(f"{fam.name}_sum{base} {snap['sum']}")
                    lines.append(f"{fam.name}_count{base} {snap['count']}")
                else:
                    lines.append(f"{fam.name}{base} {child.value}")
        return "\n".join(lines) + "\n"


def _fmt_labels(labels: Dict[str, str], **extra: Any) -> str:
    items = {**labels, **{k: str(v) for k, v in extra.items()}}
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items.items())
    return "{" + body + "}"


def publish_dataclass(registry: MetricsRegistry, obj: Any, *, prefix: str,
                      labels: Optional[Dict[str, Any]] = None,
                      gauge_fields: Sequence[str] = ()) -> None:
    """Adapter: publish every numeric field of a legacy ``*Stats`` dataclass
    into the registry under the naming rule

        ``repro_<prefix>_<field>_total``   (counters — the default)
        ``repro_<prefix>_<field>``         (fields listed in gauge_fields)

    Counter publishing uses ``set_total`` (monotone max), so republishing an
    older snapshot never regresses a series.  Non-numeric fields (nested
    stats, dicts, bools) are skipped — nested stats publish under their own
    prefix at their own call sites."""
    labels = dict(labels or {})
    label_names = tuple(sorted(labels))
    for f in dataclasses.fields(obj):
        v = getattr(obj, f.name, None)
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        if f.name in gauge_fields:
            registry.gauge(f"repro_{prefix}_{f.name}",
                           labels=label_names).labels(**labels).set(v)
        else:
            registry.counter(f"repro_{prefix}_{f.name}_total",
                             labels=label_names).labels(**labels).set_total(v)
