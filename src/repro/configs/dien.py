"""DIEN [arXiv:1809.03672]: embed 18, seq 100, GRU 108 + AUGRU interest
evolution, MLP 200-80."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import DIENConfig

FULL = DIENConfig(
    name="dien", embed_dim=18, seq_len=100, gru_dim=108, mlp=(200, 80),
    item_vocab=1_000_448, cat_vocab=10_240,
)

SMOKE = DIENConfig(
    name="dien-smoke", embed_dim=8, seq_len=12, gru_dim=16, mlp=(16, 8),
    item_vocab=500, cat_vocab=50, compute_dtype=jnp.float32,
)


def spec() -> ArchSpec:
    return ArchSpec("dien", "recsys", FULL, SMOKE, RECSYS_SHAPES)
