"""Quickstart: the versioned late materialization protocol in ~60 lines.

Walks the full lifecycle on synthetic traffic:
  events -> mutable tier (blind writes) -> daily compaction -> immutable tier
  -> inference-time snapshot (mutable slice + O(1) version metadata)
  -> training-time time-travel reconstruction -> O2O verification.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import events as ev
from repro.core.consistency import batches_equal, future_leakage_count
from repro.core.projection import TenantProjection
from repro.core.simulation import ProductionSim, SimConfig
from repro.data import DatasetSpec, SimSource, open_feed


def main() -> None:
    sim = ProductionSim(SimConfig(
        stream=ev.StreamConfig(n_users=4, n_items=2_000, days=5,
                               events_per_user_day_mean=50.0, seed=0),
        stripe_len=32,
        requests_per_user_day=3,
    ))
    sim.run_days(4)
    print(f"logged {len(sim.examples)} training examples over 4 days")

    exm = max(sim.examples, key=lambda e: e.version.seq_len)
    ref = sim.references[sim.examples.index(exm)]
    print(f"\npicked request {exm.request_id} of user {exm.user_id}:")
    print(f"  immutable window: [{exm.version.start_ts}, {exm.version.end_ts}]"
          f" seq_len={exm.version.seq_len} checksum={exm.version.checksum:#x}")
    print(f"  mutable slice: {ev.batch_len(exm.mutable_uih)} recent events")
    print(f"  example payload: {exm.payload_bytes(sim.schema)} B "
          f"(vs {sum(v.nbytes for v in ref.values())} B raw fat row)")

    # --- time-travel reconstruction (checksum-validated) ---
    mat = sim.materializer(validate_checksum=True)
    uih = mat.materialize(exm)
    print(f"\nreconstructed {ev.batch_len(uih)} events at training time")
    print(f"  O2O-exact vs inference state: {batches_equal(uih, ref)}")
    print(f"  future leakage events:       {future_leakage_count(uih, exm.request_ts)}")
    print(f"  checksum validations:        {mat.stats.checksum_validated}"
          f" (failures: {mat.stats.checksum_failures})")

    # --- multi-tenant projection pushdown ---
    short = TenantProjection("retrieval", seq_len=16, feature_groups=("core",),
                             traits_per_group={"core": ("timestamp", "item_id")})
    before = sim.immutable.stats.snapshot()
    small = mat.materialize(exm, short)
    d = sim.immutable.stats.delta(before)
    print(f"\nshort-sequence tenant fetched {ev.batch_len(small)} events, "
          f"traits={sorted(small.keys())}")
    print(f"  bytes scanned: {d.bytes_scanned} (projection pushdown), "
          f"stripes read: {d.stripes_read}, seeks: {d.seeks}")

    # --- the declarative read path: DatasetSpec -> open_feed -> Feed ---
    # one frozen spec describes the whole pipeline (source, projection,
    # consistency, batching); the compiler wires the data plane
    ds = DatasetSpec(tenant=short, source=SimSource(epochs=1),
                     consistency="audit", batch_size=8, base_batch_size=4,
                     n_workers=1)
    with open_feed(ds, sim) as feed:
        batch = next(iter(feed))
        print(f"\nopen_feed({ds.tenant.name!r}): first full batch "
              f"{len(batch['uih_len'])} rows, "
              f"uih_item_id {batch['uih_item_id'].shape}")
        for _ in feed:        # drain so the pool exits, then close via `with`
            pass
    print(f"  feed drained: {feed.drained}; "
          f"worker examples: {feed.stats().workers.examples}")


if __name__ == "__main__":
    main()
