"""Production mesh construction (defined as functions so importing this module
never touches jax device state)."""
from __future__ import annotations

from typing import Tuple

import jax

from repro.compat import set_mesh  # noqa: F401  (re-export; see repro.compat)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi-pod = 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes_of(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes used for batch/data parallelism (pod axis is pure DP)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def all_axes_of(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def make_test_mesh(n_devices: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many local devices exist (CPU tests)."""
    n = min(n_devices, jax.device_count())
    return jax.make_mesh((1, n), ("data", "model"))


def store_node_of_host(host: int, n_hosts: int, n_store_nodes: int) -> int:
    """Which store node a trainer host's DPP workers treat as *local*.

    The disaggregated immutable tier (``storage.sharded_store``) is deployed
    alongside the trainer mesh; hosts map onto store nodes round-robin so
    each node serves ``ceil(n_hosts / n_store_nodes)`` hosts and a host's
    affinity-planned work items (already node-local via the placement map)
    can be routed to the co-located node's feed partition."""
    if not 0 <= host < n_hosts:
        raise ValueError(f"host {host} out of range [0, {n_hosts})")
    return host % n_store_nodes


def replica_nodes_of_host(host: int, n_hosts: int, n_store_nodes: int,
                          replication_factor: int = 1) -> Tuple[int, ...]:
    """Ordered store-node preference chain for a trainer host.

    Head = the co-located node (``store_node_of_host``); tail = that node's
    round-robin replica successors — the SAME anti-affinity chain
    ``PlacementMap.replicas_of`` uses, so when the host's local node is down
    its DPP reads fail over to nodes that actually replicate the local
    node's primary data, instead of scattering across the tier."""
    primary = store_node_of_host(host, n_hosts, n_store_nodes)
    r = max(1, min(replication_factor, n_store_nodes))
    return tuple((primary + k) % n_store_nodes for k in range(r))
