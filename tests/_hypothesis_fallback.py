"""Minimal stand-in for ``hypothesis`` when it is not installed.

The property tests in this suite only use ``@settings(...)``, ``@given(...)``
and three strategies (``integers``, ``floats``, ``sampled_from``). This shim
replays each property as a deterministic sweep: the first iterations pin the
strategy boundaries (min / max / midpoint), the rest draw from a seeded RNG.
No shrinking, no example database — just enough coverage to keep the
properties exercised on machines without the real dependency (pinned in
``requirements-test.txt``).
"""
from __future__ import annotations

import types

import numpy as np


class _Strategy:
    def __init__(self, boundary, draw):
        self.boundary = list(boundary)
        self.draw = draw  # callable(rng) -> value


def _integers(min_value, max_value):
    mid = min_value + (max_value - min_value) // 2
    return _Strategy(
        [min_value, max_value, mid],
        lambda rng: int(rng.integers(min_value, max_value + 1)),
    )


def _floats(min_value, max_value):
    mid = (min_value + max_value) / 2.0
    return _Strategy(
        [min_value, max_value, mid],
        lambda rng: float(rng.uniform(min_value, max_value)),
    )


def _sampled_from(options):
    opts = list(options)
    return _Strategy(opts, lambda rng: opts[int(rng.integers(len(opts)))])


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, sampled_from=_sampled_from
)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        # no functools.wraps: pytest must see the zero-arg wrapper signature,
        # not the property's drawn parameters (it would treat them as fixtures)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples", 20))
            rng = np.random.default_rng(0)
            for i in range(n):
                drawn = {
                    name: (s.boundary[i] if i < len(s.boundary) else s.draw(rng))
                    for name, s in strats.items()
                }
                fn(*args, **drawn, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._fallback_max_examples = getattr(fn, "_fallback_max_examples",
                                                 20)
        return wrapper

    return deco
