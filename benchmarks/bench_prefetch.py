"""§4.2.2 pipelined I/O prefetching: overlap the immutable lookup for batch N
with the probe-side read for batch N+1. Paper: ~10% per-worker throughput."""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchResult, standard_sim
from repro.core.projection import TenantProjection
from repro.dpp.featurize import FeatureSpec
from repro.dpp.worker import DPPWorker, probe_from_list

TENANT = TenantProjection("t", seq_len=256, feature_groups=("core",))
SPEC = FeatureSpec(seq_len=256, uih_traits=("item_id",))
DELAY = 0.004  # comparable probe/lookup latencies (paper's assumption)


def _worker(sim, delay=DELAY):
    mat = sim.materializer(validate_checksum=False)
    mat.immutable.latency_model = lambda seeks, nbytes, fanout: delay
    return DPPWorker(mat, TENANT, SPEC, sim.schema, probe_latency_s=delay)


def run(quick: bool = False) -> List[BenchResult]:
    if quick:
        sim = standard_sim("vlm", users=8, days=2, req_per_day=3)
        examples, delay = sim.examples[:32], 0.001
    else:
        sim = standard_sim("vlm", users=32, days=5, req_per_day=5)
        examples, delay = sim.examples[:320], DELAY

    w_serial = _worker(sim, delay)
    n_serial = sum(1 for _ in w_serial.run_serial(probe_from_list(examples, 16)))
    w_piped = _worker(sim, delay)
    n_piped = sum(1 for _ in w_piped.run_pipelined(probe_from_list(examples, 16)))
    assert n_serial == n_piped

    thr_serial = len(examples) / w_serial.stats.total_time_s
    thr_piped = len(examples) / w_piped.stats.total_time_s
    delta = 100.0 * (thr_piped - thr_serial) / thr_serial
    return [BenchResult(
        "prefetch/pipelined_throughput",
        1e6 * w_piped.stats.total_time_s / n_piped,
        {"ours_pct": round(delta, 1), "paper_pct": +10.0,
         "serial_ex_per_s": round(thr_serial, 1),
         "pipelined_ex_per_s": round(thr_piped, 1),
         "serial_waste_pct": round(w_serial.stats.waste_pct, 1),
         "pipelined_waste_pct": round(w_piped.stats.waste_pct, 1)},
    )]


if __name__ == "__main__":
    for r in run():
        print(r.csv())
