"""Inference-time snapshotting (paper §3.3, Fig. 3).

During ranking, the service fetches the mutable tier (recent events) and the
immutable tier (long-term history) to assemble the complete UIH for model
inference. Under versioned late materialization, the logged training example
persists only:

  * the **mutable** slice (small: events newer than the immutable watermark),
    physically snapshotted at T_request so no late-arriving event can
    contaminate it; and
  * O(1) **version metadata** for the immutable window (start_ts = lookback
    bound, end_ts = immutable watermark, seq_len, checksum, generation).

The Fat Row baseline snapshotter logs the complete merged UIH instead.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import events as ev
from repro.core.versioning import TrainingExample, VersionMetadata, window_checksum
from repro.storage.immutable_store import ImmutableUIHStore, ScanRequest
from repro.storage.mutable_store import MutableUIHStore


@dataclasses.dataclass
class SnapshotterConfig:
    lookback_ms: int = 365 * ev.MS_PER_DAY
    max_seq_len: int = 1 << 20          # union-dataset maximum requirement
    with_checksum: bool = True
    nonseq_bytes: int = 1024            # opaque non-sequence feature payload


class BaseSnapshotter:
    def __init__(
        self,
        mutable: MutableUIHStore,
        immutable: ImmutableUIHStore,
        schema: ev.TraitSchema,
        cfg: Optional[SnapshotterConfig] = None,
    ):
        self.mutable = mutable
        self.immutable = immutable
        self.schema = schema
        self.cfg = cfg or SnapshotterConfig()
        self._next_request_id = 0

    def _fetch_both_tiers(self, user_id: int, request_ts: int):
        """The inference read path: assemble complete UIH at T_request."""
        watermark = self.immutable.watermark(user_id)
        end_ts = min(watermark, request_ts)
        start_ts = max(0, request_ts - self.cfg.lookback_ms)
        reqs = [
            ScanRequest(user_id=user_id, group=g, start_ts=start_ts, end_ts=end_ts)
            for g in self.schema.feature_groups
        ]
        parts = self.immutable.multi_range_scan(reqs)
        immutable_part: ev.EventBatch = {}
        for p in parts:
            immutable_part.update(p)
        # mutable tier: strictly newer than the immutable watermark, <= T_request
        mutable_part = self.mutable.read(user_id, end_ts, request_ts)
        return immutable_part, mutable_part, start_ts, end_ts

    def inference_uih(self, user_id: int, request_ts: int) -> ev.EventBatch:
        """Complete UIH as seen by the ranking model at T_request (ground truth
        for O2O-consistency checks)."""
        imm, mut, _, _ = self._fetch_both_tiers(user_id, request_ts)
        return ev.concat_batches([imm, mut]) or ev.empty_batch(self.schema)

    def _alloc_id(self) -> int:
        rid = self._next_request_id
        self._next_request_id += 1
        return rid

    def _context(self, request_id: int) -> bytes:
        """Deterministic stand-in for the non-sequence feature payload
        (identical across VLM and Fat Row snapshotters for fair accounting)."""
        import numpy as _np

        return _np.random.default_rng(request_id).bytes(self.cfg.nonseq_bytes)


class VLMSnapshotter(BaseSnapshotter):
    """Versioned late materialization: log mutable slice + version metadata."""

    def snapshot(
        self,
        user_id: int,
        request_ts: int,
        candidate: Dict[str, int],
        labels: Dict[str, float],
        label_ts: Optional[int] = None,
    ) -> TrainingExample:
        imm, mut, start_ts, end_ts = self._fetch_both_tiers(user_id, request_ts)
        seq_len = ev.batch_len(imm)
        checksum = (
            window_checksum(imm) if (self.cfg.with_checksum and seq_len) else 0
        )
        return TrainingExample(
            request_id=self._alloc_id(),
            user_id=user_id,
            request_ts=request_ts,
            label_ts=label_ts if label_ts is not None else request_ts,
            candidate=dict(candidate),
            labels=dict(labels),
            mutable_uih=mut,
            context=self._context(self._next_request_id - 1),
            version=VersionMetadata(
                start_ts=start_ts,
                end_ts=end_ts,
                seq_len=seq_len,
                checksum=checksum,
                generation=self.immutable.generation,
            ),
        )


class FatRowSnapshotter(BaseSnapshotter):
    """Industry-standard baseline: physically pre-materialize the full UIH."""

    def snapshot(
        self,
        user_id: int,
        request_ts: int,
        candidate: Dict[str, int],
        labels: Dict[str, float],
        label_ts: Optional[int] = None,
    ) -> TrainingExample:
        imm, mut, _, _ = self._fetch_both_tiers(user_id, request_ts)
        fat = ev.concat_batches([imm, mut]) or ev.empty_batch(self.schema)
        return TrainingExample(
            request_id=self._alloc_id(),
            user_id=user_id,
            request_ts=request_ts,
            label_ts=label_ts if label_ts is not None else request_ts,
            candidate=dict(candidate),
            labels=dict(labels),
            fat_uih=fat,
            context=self._context(self._next_request_id - 1),
        )
