"""Zero-copy trainer feed: vectorized featurize + slot rebatch + device
prefetch vs the seed per-example-loop / concat+gather / synchronous path.

Three measurements:
  * featurize: per-example Python loops (reference) vs arena+scatter rows/s;
  * feed: featurize + rebatch end-to-end — the seed pipeline (reference
    featurize -> concat merge -> gather reshuffle) vs the new one (vectorized
    featurize -> write-time-permuted slot placement), byte-identical outputs;
  * device feed: trainer starvation % with the synchronous seed-style feed
    (prep + transfer inside the step loop) vs the double-buffered prefetcher.

Acceptance target (ISSUE 2): >= 2x featurize+rebatch rows/s, lower
starvation % with the prefetcher enabled.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import BenchResult, timeit
from repro.core.versioning import TrainingExample
from repro.dpp.client import RebatchingClient
from repro.dpp.featurize import (
    FeatureSpec,
    featurize,
    featurize_jagged,
    featurize_reference,
    merge_base_batches,
    reshuffle,
)
from repro.dpp.prefetch import DevicePrefetcher
from repro.obs import DEFAULT_SAMPLE_EVERY, Telemetry
from repro.obs.spans import current_span

TRAIT_DTYPES = {"item_id": np.int64, "action_type": np.int32,
                "watch_time_ms": np.int32, "like": np.int8}


def _synth(n: int, seq_len: int, seed: int = 0):
    """Synthetic examples + materialized UIHs (isolates the feed from I/O)."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, 2 * seq_len, size=n)
    examples, uihs = [], []
    for i in range(n):
        ln = int(lens[i])
        u = {"timestamp": np.sort(rng.integers(0, 1 << 40, ln)).astype(np.int64)}
        for t, dt in TRAIT_DTYPES.items():
            u[t] = rng.integers(0, 1000, ln).astype(dt)
        uihs.append(u)
        examples.append(TrainingExample(
            request_id=i, user_id=int(rng.integers(0, 512)),
            request_ts=int(u["timestamp"][-1]) if ln else 0, label_ts=0,
            candidate={"item_id": int(rng.integers(0, 1000))},
            labels={"click": float(rng.random() < 0.1)}))
    return examples, uihs


def _seed_rebatch(bases: List[Dict[str, np.ndarray]], full: int, seed: int):
    """The seed client's merge+reshuffle semantics (concat copy + gather copy)."""
    out, pending, rows, k = [], [], 0, 0
    for b in bases:
        pending.append(b)
        rows += len(next(iter(b.values())))
        if rows < full:
            continue
        merged = merge_base_batches(pending)
        pending, rows = [], 0
        n = len(next(iter(merged.values())))
        emitted = 0
        while n - emitted >= full:
            out.append(reshuffle(
                {kk: v[emitted:emitted + full] for kk, v in merged.items()},
                seed + k))
            k += 1
            emitted += full
        if emitted < n:
            pending = [{kk: v[emitted:] for kk, v in merged.items()}]
            rows = n - emitted
    if pending:
        out.append(reshuffle(merge_base_batches(pending), seed + k))
    return out


def _feed_seed(chunks, spec, full):
    out = _seed_rebatch([featurize_reference(e, u, spec) for e, u in chunks],
                        full, seed=0)
    return out


def _feed_slot(chunks, spec, full, recycle=False, telemetry=None):
    """The new pipeline: jagged featurize + fused arena+scatter placement.

    With ``recycle`` the consumed batches' storage is handed straight back
    (the steady-state trainer loop) — recycled arrays get overwritten by
    later slots, so this mode returns only the batch COUNT, never contents.

    With ``telemetry`` the loop exercises the FULL span path the real
    pipeline runs (mint/enter/exit per item, featurize stage recording, batch
    emission, delivery + train finalization) — the overhead-guard measurement.
    """
    client = RebatchingClient(full, buffer_batches=1 << 16, shuffle_seed=0)
    client.telemetry = telemetry
    tr = telemetry.spans if telemetry is not None else None
    if recycle:
        count = 0
        for i, (e, u) in enumerate(chunks):
            if tr is not None:
                tr.mint(i)
                tr.enter_item(i)
            t0 = time.perf_counter()
            jf = featurize_jagged(e, u, spec)
            if tr is not None:
                sp = current_span()
                if sp is not None:
                    sp.stage("featurize", t0, time.perf_counter())
            client.put_jagged(jf)
            if tr is not None:
                tr.exit_item()
                tr.finish_item(i)
            while True:
                b = client.get_full_batch(timeout=0.0)
                if b is None:
                    break
                if tr is not None:
                    tr.mark_delivered()
                    tr.record_train(0.0)
                count += 1
                client.recycle(b)
        client.close()
        for _ in client:
            if tr is not None:
                tr.mark_delivered()
                tr.record_train(0.0)
            count += 1
        if tr is not None:
            tr.drain()
        return count
    for e, u in chunks:
        client.put_jagged(featurize_jagged(e, u, spec))
    client.close()
    return list(client)


def _starvation(client_batches, jit_step, prefetch: bool, prep):
    """Feed pre-featurized base batches through a client while a jit'd step
    consumes; returns the observed trainer starvation split."""
    import jax

    full = len(next(iter(client_batches[0].values())))
    client = RebatchingClient(full, buffer_batches=2, shuffle_seed=0)

    def producer():
        for b in client_batches:
            client.put(b)
        client.close()

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    if prefetch:
        feed = DevicePrefetcher(client, depth=2, prep_fn=prep)
    else:
        feed = client
    x = None
    for b in feed:
        if not prefetch:
            t0 = time.perf_counter()
            b = jax.device_put(prep(b))
            jax.block_until_ready(b)
            dt = time.perf_counter() - t0
            # seed path: prep + H2D are serialized into the step; they are
            # GPU-idle time exactly like a queue wait
            client.stats.starved_time_s += dt
            client.stats.starved_h2d_s += dt
        t0 = time.perf_counter()
        x = jit_step(b)
        x.block_until_ready()
        feed.record_train_step(time.perf_counter() - t0)
    th.join()
    return client.stats


def run(quick: bool = False, telemetry=None) -> List[BenchResult]:
    import jax
    import jax.numpy as jnp

    seq_len = 64 if quick else 512
    base, full = (8, 32) if quick else (64, 256)
    n = 4 * full if quick else 16 * full
    spec = FeatureSpec(seq_len=seq_len, uih_traits=tuple(TRAIT_DTYPES),
                       candidate_fields=("item_id",), label_fields=("click",))
    examples, uihs = _synth(n, seq_len)
    chunks = [(examples[i:i + base], uihs[i:i + base])
              for i in range(0, n, base)]
    repeats = 2 if quick else 3

    # -- featurize alone ------------------------------------------------------
    t_ref = timeit(lambda: [featurize_reference(e, u, spec) for e, u in chunks],
                   repeats=repeats)
    t_vec = timeit(lambda: [featurize(e, u, spec) for e, u in chunks],
                   repeats=repeats)
    # arena+offsets form (what DPP workers emit on the fused path): the [B, L]
    # densification is deferred to the slot write, so none happens here
    t_jag = timeit(lambda: [featurize_jagged(e, u, spec) for e, u in chunks],
                   repeats=repeats)
    out = [BenchResult(
        "feed/featurize", t_vec / len(chunks),
        {"ref_rows_per_s": round(n / (t_ref * 1e-6), 1),
         "vec_dense_rows_per_s": round(n / (t_vec * 1e-6), 1),
         "vec_jagged_rows_per_s": round(n / (t_jag * 1e-6), 1),
         "dense_speedup_x": round(t_ref / t_vec, 2),
         "jagged_speedup_x": round(t_ref / t_jag, 2)},
    )]

    # -- featurize + rebatch end-to-end ---------------------------------------
    want = _feed_seed(chunks, spec, full)
    got = _feed_slot(chunks, spec, full)
    identical = len(want) == len(got) and all(
        set(w) == set(g) and all(np.array_equal(w[k], g[k]) for k in w)
        for w, g in zip(want, got))
    t_seed = timeit(lambda: _feed_seed(chunks, spec, full), repeats=repeats)
    t_slot = timeit(lambda: _feed_slot(chunks, spec, full, recycle=True),
                    repeats=repeats)
    out.append(BenchResult(
        "feed/featurize_rebatch", t_slot / max(len(got), 1),
        {"seed_rows_per_s": round(n / (t_seed * 1e-6), 1),
         "slot_rows_per_s": round(n / (t_slot * 1e-6), 1),
         "speedup_x": round(t_seed / t_slot, 2),
         "byte_identical": identical,
         "target_x": 2.0},
    ))

    # -- telemetry overhead guard (ISSUE 8 satellite) -------------------------
    # same steady-state loop, spans on at DEFAULT sampling; the budget is <=2%
    # rows/s. Paired order-alternating runs + median-of-ratios: machine drift
    # hits both arms of each pair equally, so the estimator survives noisy
    # shared hosts where an A...A-then-B...B diff would not
    # (tests/test_obs.py enforces the budget the same way).
    def _once(tel):
        t0 = time.perf_counter()
        _feed_slot(chunks, spec, full, recycle=True, telemetry=tel)
        return time.perf_counter() - t0

    ratios = []
    for i in range(5 if quick else 11):
        if i % 2 == 0:
            t_off = _once(None)
            t_on = _once(Telemetry())
        else:
            t_on = _once(Telemetry())
            t_off = _once(None)
        ratios.append(t_on / max(t_off, 1e-9))
    ratios.sort()
    med = ratios[len(ratios) // 2]
    out.append(BenchResult(
        "feed/telemetry_overhead", t_slot * med / max(len(got), 1),
        {"off_rows_per_s": round(n / (t_slot * 1e-6), 1),
         "on_rows_per_s": round(n / (t_slot * med * 1e-6), 1),
         "overhead_pct": round((med - 1.0) * 100.0, 2),
         "sample_every": DEFAULT_SAMPLE_EVERY,
         "target_pct": 2.0},
    ))
    if telemetry is not None:
        # a --telemetry aggregator run: leave real spans/metrics in the
        # caller's registry for the run-dir export
        _feed_slot(chunks, spec, full, recycle=True, telemetry=telemetry)

    # -- device prefetch vs synchronous feed ----------------------------------
    d = 32 if quick else 128
    w = jnp.asarray(np.random.default_rng(0).standard_normal((seq_len, d)),
                    jnp.float32)
    steps = 3 if quick else 10

    @jax.jit
    def step(b):
        x = b["uih_item_id"].astype(jnp.float32)
        for _ in range(steps):
            x = jnp.tanh(x @ w @ w.T)
        return x.sum()

    def prep(b):
        # model-specific host transforms (the work the seed loop did inline)
        return {"uih_item_id": (b["uih_item_id"] % 1009).astype(np.float32)
                * (1.0 / seq_len)}

    bases = [featurize(e, u, spec) for e, u in chunks]
    step({"uih_item_id": jnp.zeros((full, seq_len), jnp.float32)}
         ).block_until_ready()  # compile off the clock
    s_sync = _starvation(bases, step, prefetch=False, prep=prep)
    s_pre = _starvation(bases, step, prefetch=True, prep=prep)
    out.append(BenchResult(
        "feed/device_prefetch", 0.0,
        {"sync_starvation_pct": round(s_sync.starvation_pct, 2),
         "prefetch_starvation_pct": round(s_pre.starvation_pct, 2),
         "reduced": s_pre.starvation_pct < s_sync.starvation_pct,
         "prefetch_starved_host_ms": round(s_pre.starved_host_s * 1e3, 2),
         "prefetch_starved_h2d_ms": round(s_pre.starved_h2d_s * 1e3, 2),
         "h2d_overlapped_ms": round(s_pre.h2d_time_s * 1e3, 2)},
    ))
    return out


if __name__ == "__main__":
    for r in run():
        print(r.csv())
