"""Distributed-correctness tests on an 8-device (2 data x 4 model) host mesh.

Run in a subprocess so XLA_FLAGS can force multiple host devices without
affecting the rest of the suite (which must see 1 device).

Verified invariants:
  * row-sharded shard_map embedding paths == plain gather paths (bitwise-ish)
  * 2D expert-sharded MoE == FSDP shard_map MoE == dense oracle
  * transformer loss under a 2x4 mesh == single-device loss
  * recsys forward with mesh-enabled config == mesh-free config
"""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    import dataclasses
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import set_mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)

    # ---- 1) row-sharded embedding vs plain ----
    from repro.models.embedding import (bag_rowsharded, embedding_bag,
                                        lookup_rowsharded, seq_rowsharded)
    table = jax.random.normal(key, (64, 16), jnp.float32)
    ids = jax.random.randint(key, (8, 5), 0, 64)
    mask = jax.random.bernoulli(key, 0.8, (8, 5))
    with set_mesh(mesh):
        got = jax.jit(lambda t, i, m: bag_rowsharded(
            t, i, m, "mean", mesh, ("data",)))(table, ids, mask)
    want = embedding_bag(table, ids, mask, "mean")
    # atol floor: the psum reduction order differs from the plain gather's
    # sum on some backends, leaving float32-epsilon noise near zero
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    with set_mesh(mesh):
        got2 = jax.jit(lambda t, i: seq_rowsharded(t, i, mesh, ("data",)))(
            table, ids)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(table[ids]),
                               rtol=1e-6, atol=1e-6)
    print("embedding OK")

    # ---- 2) MoE: 2d == fsdp == oracle ----
    from repro.models.moe import MoEConfig, init_moe, moe_ffn, moe_ref
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=8, capacity_factor=8.0)
    p = init_moe(key, 16, cfg)
    x = jax.random.normal(key, (16, 16), jnp.float32)
    want = moe_ref(p, x, cfg)
    with set_mesh(mesh):
        got_fsdp = jax.jit(lambda p, x: moe_ffn(p, x, cfg, mesh=mesh))(p, x)
        cfg2d = dataclasses.replace(cfg, ep_mode="2d")
        got_2d = jax.jit(lambda p, x: moe_ffn(p, x, cfg2d, mesh=mesh))(p, x)
    np.testing.assert_allclose(np.asarray(got_fsdp), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_2d), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    print("moe OK")

    # ---- 3) transformer loss: mesh == single device ----
    from repro.models.transformer import TransformerConfig, init, loss_fn
    from repro.launch.shardings import lm_param_specs
    tc = TransformerConfig("t", n_layers=2, d_model=32, n_heads=4,
                           n_kv_heads=2, d_ff=64, vocab=96, head_dim=8,
                           qk_norm=True, compute_dtype=jnp.float32,
                           q_chunk=8, loss_chunk=8)
    params = init(key, tc)
    toks = jax.random.randint(key, (4, 16), 0, 96)
    tgt = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 96)
    base = float(loss_fn(params, toks, tgt, tc))
    from repro.compat import as_shardings
    pspec = lm_param_specs(params, mesh)
    with set_mesh(mesh):
        f = jax.jit(lambda p, a, b: loss_fn(p, a, b, tc, mesh=mesh),
                    in_shardings=as_shardings(
                        mesh, (pspec, P("data", None), P("data", None))))
        dist = float(f(params, toks, tgt))
    assert abs(base - dist) < 1e-4, (base, dist)
    print("transformer OK")

    # ---- 4) recsys forward: mesh cfg == plain cfg ----
    from repro.models import recsys as R
    rc = R.DLRMUIHConfig(name="t", seq_len=16, d_seq=16, n_seq_layers=1,
                         n_heads=2, n_dense=4, n_sparse=2, embed_dim=8,
                         item_vocab=256, field_vocab=64,
                         compute_dtype=jnp.float32, remat=False)
    rp = R.init_dlrm_uih(key, rc)
    batch = {
        "uih_item_id": jax.random.randint(key, (8, 16), 0, 256),
        "uih_action_type": jax.random.randint(key, (8, 16), 0, 16),
        "uih_mask": jnp.ones((8, 16), bool),
        "cand_item_id": jax.random.randint(key, (8,), 0, 256),
        "sparse_ids": jax.random.randint(key, (8, 2), 0, 64),
        "dense": jax.random.normal(key, (8, 4), jnp.float32),
    }
    want = R.dlrm_uih_forward(rp, batch, rc)
    rc_mesh = dataclasses.replace(rc, mesh=mesh, data_axes=("data",))
    with set_mesh(mesh):
        got = jax.jit(lambda p, b: R.dlrm_uih_forward(p, b, rc_mesh))(rp, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    print("recsys OK")
    print("ALL DISTRIBUTED CHECKS PASSED")
""")


@pytest.mark.slow
def test_distributed_correctness_8dev():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # host-device test: never let jax probe for real accelerators
             "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout
