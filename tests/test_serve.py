"""Low-latency serving tier (DESIGN.md §14): snapshot-consistent top-k.

Covers the PR's acceptance spine:
  * byte-identity — cache-on results identical to cache-off on the same mix
    (the embedding cache is a latency optimization, never a staleness trade);
  * generation flips — a synchronous compaction between waves invalidates
    every cached embedding (``invalidated_generation``), yet results stay
    byte-identical; a churn thread flipping generations THROUGH the waves
    (the PR-3 harness style) never yields a failed request, a
    ``StaleGeneration`` escape, or a leaked lease;
  * freshness — new mutable events for a user make their cached embedding
    unusable (``invalidated_freshness``) and the recomputed embedding differs;
  * shutdown — ``close()`` drains in-flight requests and leaves ZERO leases
    on the store;
  * chaos — the 4-node r=2 sharded/replicated tier under ``node_flap`` +
    ``node_slow`` serves the full mix byte-identical to the fault-free run
    with replica failover absorbing the outage.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_sim
from repro.models import recsys as R
from repro.serve import RequestCoalescer, RetrievalServer, ServeConfig
from repro.serve.coalescer import PendingRequest
from repro.testing import FaultPlan, FaultSpec, wrap_sim

CFG = R.TwoTowerConfig(
    name="test-serve", embed_dim=8, tower_mlp=(16, 8), item_vocab=1_500,
    user_vocab=64, uih_len=16, compute_dtype=jnp.float32)
PARAMS = R.init_two_tower(jax.random.PRNGKey(0), CFG)
TOP_K = 5


def _server(sim, telemetry=None, **kw):
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_delay_s", 0.001)
    return RetrievalServer.from_sim(
        sim, PARAMS, CFG, telemetry=telemetry,
        cfg=ServeConfig(lookback_ms=sim.cfg.lookback_ms, **kw))


def _mix(sim, n=64):
    now = max(e.request_ts for e in sim.examples)
    seq = [e.user_id for e in sim.examples]
    return now, (seq * (n // len(seq) + 1))[:n]


def _issue(server, now, users):
    pendings = [server.submit(u, now, k=TOP_K) for u in users]
    return [p.result(timeout=30.0) for p in pendings]


def _assert_same(want, got):
    assert len(want) == len(got)
    for i, (a, b) in enumerate(zip(want, got)):
        np.testing.assert_array_equal(a.item_ids, b.item_ids,
                                      err_msg=f"request {i} ids")
        np.testing.assert_array_equal(a.scores, b.scores,
                                      err_msg=f"request {i} scores")


def _no_leaks(server, sim):
    st = server.stats
    assert st.failed_requests == 0
    assert server.materializer.stats.stale_failures == 0
    assert sim.immutable.leased_generations() == {}


# ---------------------------------------------------------------------------
# byte-identity: cache on vs cache off
# ---------------------------------------------------------------------------

def test_cache_on_byte_identical_to_cache_off():
    sim = make_sim(users=6, days=2, seed=3, capture_reference=False)
    now, users = _mix(sim)

    off = _server(sim, cache_capacity=0, window_cache_size=0)
    ref = _issue(off, now, users)
    off.close()
    _no_leaks(off, sim)
    assert off.stats.cold_requests == len(users)   # nothing cached anywhere

    on = _server(sim)
    got = _issue(on, now, users)          # first wave populates...
    got2 = _issue(on, now, users)         # ...second wave hits
    on.close()
    _no_leaks(on, sim)
    _assert_same(ref, got)
    _assert_same(ref, got2)
    cs = on.cache.stats
    assert cs.hits >= len(users)          # repeat users actually cached
    assert all(r.cached for r in got2)
    assert on.stats.cold_requests < len(users)


# ---------------------------------------------------------------------------
# generation flips: deterministic + flip-stress
# ---------------------------------------------------------------------------

def test_generation_flip_invalidates_every_cached_embedding():
    sim = make_sim(users=6, days=2, seed=4, capture_reference=False)
    now, users = _mix(sim)
    server = _server(sim)
    ref = _issue(server, now, users)
    gen0 = sim.immutable.generation

    sim.run_compaction(now, evict=False)   # flip: same content, new version
    assert sim.immutable.generation > gen0

    got = _issue(server, now, users)
    server.close()
    _no_leaks(server, sim)
    _assert_same(ref, got)                 # compaction preserves content
    distinct = len(set(users))
    cs = server.cache.stats
    assert cs.invalidated_generation >= distinct   # every entry was dropped
    assert all(r.generation > gen0 for r in got)   # nothing served at old gen


def test_flip_stress_churn_thread_never_serves_stale():
    """PR-3 harness style: a compaction thread flips generations through the
    whole request stream. Results must stay byte-identical to the quiet run —
    a cached embedding from a superseded generation is never served — with
    zero failed requests, zero StaleGeneration escapes, zero leaked leases."""
    sim = make_sim(users=6, days=2, seed=5, capture_reference=False)
    now, users = _mix(sim, n=96)
    quiet = _server(sim, cache_capacity=0, window_cache_size=0)
    ref = _issue(quiet, now, users)
    quiet.close()

    server = _server(sim)
    stop = threading.Event()
    flips = [0]

    def churn():
        while not stop.is_set():
            sim.run_compaction(now, evict=False)
            flips[0] += 1

    th = threading.Thread(target=churn, daemon=True)
    th.start()
    try:
        got = [_issue(server, now, users) for _ in range(3)]
    finally:
        stop.set()
        th.join()
    server.close()
    _no_leaks(server, sim)
    assert flips[0] >= 1
    for wave in got:
        _assert_same(ref, wave)


# ---------------------------------------------------------------------------
# freshness: new mutable events invalidate the cached embedding
# ---------------------------------------------------------------------------

def test_new_mutable_events_invalidate_cached_embedding():
    sim = make_sim(users=6, days=2, seed=6, capture_reference=False)
    now, users = _mix(sim)
    u = users[0]
    server = _server(sim)
    server.retrieve(u, now, k=TOP_K)       # populate
    first = server.retrieve(u, now, k=TOP_K)
    assert first.cached

    # a genuinely new engagement lands in the mutable tier for u
    recent = sim.mutable.read(u, -1, now)
    assert len(recent["timestamp"])
    newer = {k: v[-1:].copy() for k, v in recent.items()}
    newer["timestamp"] = np.array([now + 1_000], dtype=np.int64)
    sim.mutable.append(u, newer)

    second = _issue(server, now + 2_000, [u])[0]
    server.close()
    _no_leaks(server, sim)
    assert not second.cached               # forced back through the cold path
    assert server.cache.stats.invalidated_freshness >= 1


# ---------------------------------------------------------------------------
# shutdown: close() drains, answers everything, leaves zero leases
# ---------------------------------------------------------------------------

def test_close_drains_in_flight_requests_and_leaks_nothing():
    sim = make_sim(users=6, days=2, seed=7, capture_reference=False)
    now, users = _mix(sim, n=48)
    server = _server(sim, max_delay_s=0.05)   # long deadline: close must drain
    pendings = [server.submit(u, now, k=TOP_K) for u in users]
    server.close()
    results = [p.result(timeout=10.0) for p in pendings]
    assert len(results) == len(users)
    assert all(r.item_ids.shape == (TOP_K,) for r in results)
    _no_leaks(server, sim)
    # and the coalescer refuses new work instead of queueing it to nobody
    try:
        server.submit(users[0], now)
        raised = False
    except RuntimeError:
        raised = True
    assert raised


# ---------------------------------------------------------------------------
# chaos: 4-node r=2 sharded/replicated tier under flap + slow
# ---------------------------------------------------------------------------

def test_chaos_sharded_r2_flap_and_slow_byte_identical():
    sim = make_sim(users=6, days=2, seed=5, capture_reference=False,
                   nodes=4, replication=2)
    now, users = _mix(sim, n=96)
    quiet = _server(sim, cache_capacity=0, window_cache_size=0)
    ref = _issue(quiet, now, users)
    quiet.close()

    plan = FaultPlan([
        FaultSpec("node_flap", 1, node=1, duration=2),
        FaultSpec("node_slow", 3, node=2, duration=2, factor=4.0),
        FaultSpec("node_flap", 5, node=3, duration=2),
    ])
    fsim = wrap_sim(sim, plan)
    server = _server(fsim, cache_capacity=0, window_cache_size=0)
    got = _issue(server, now, users)
    server.close()
    assert plan.n_fired == 3
    fsim.immutable.settle_node_state()
    _assert_same(ref, got)
    _no_leaks(server, sim)
    assert sim.immutable.stats.failovers >= 1   # the replica path absorbed it
    ns = sim.immutable.node_stats()
    assert not any(ns.down) and not any(ns.pending_replays)


# ---------------------------------------------------------------------------
# coalescer unit behavior: flush reasons + close semantics
# ---------------------------------------------------------------------------

def test_coalescer_flush_reasons():
    c = RequestCoalescer(max_batch=2, max_delay_s=0.005)
    c.submit(PendingRequest(1, 5, 100))
    c.submit(PendingRequest(2, 5, 100))
    batch, flush = c.next_batch()
    assert flush == "size" and len(batch) == 2

    c.submit(PendingRequest(3, 5, 100))
    batch, flush = c.next_batch()          # lonely request: deadline flush
    assert flush == "deadline" and len(batch) == 1

    c.submit(PendingRequest(4, 5, 100))
    c.close()
    batch, flush = c.next_batch()
    assert flush == "drain" and len(batch) == 1
    assert c.next_batch() == (None, "closed")
    st = c.stats
    assert (st.size_flushes, st.deadline_flushes, st.drain_flushes) == (1, 1, 1)
