"""Read-optimized immutable UIH store (paper §4.1.2).

Single-level layout: each user's long-term history is partitioned into
fixed-length temporal *stripes* keyed by the multi-dimensional composite key
``(user_id, feature_group, subsequence_start_ts)``. Stripes are produced
pre-sorted by the offloaded compaction pipeline and **bulk-loaded** as a whole
generation — there is no write path other than ``bulk_load``, hence no LSM
multi-level read amplification and no compaction-induced write amplification.

The read path is a bounded *multi-range scan*: for each request the store
locates the stripe run overlapping ``[start_ts, end_ts]`` (one "seek") and then
reads stripes sequentially. Projection pushdown happens server-side in three
dimensions (§4.1.2):

  1. sequence-length projection — scan only as many stripes (from the most
     recent backwards) as needed for the tenant's ``max_events``;
  2. feature-group projection — the composite key isolates groups physically;
  3. trait projection — selective byte-level decoding inside a stripe.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import events as ev
from repro.storage import columnar
from repro.storage.sharding import ShardRouter


@dataclasses.dataclass(frozen=True)
class Stripe:
    start_ts: int
    end_ts: int
    n_events: int
    blob: bytes


@dataclasses.dataclass(frozen=True)
class ScanRequest:
    user_id: int
    group: str
    start_ts: int            # inclusive temporal lower bound (version metadata)
    end_ts: int              # inclusive temporal upper bound (version metadata)
    max_events: int = -1     # sequence-length projection (-1 = unbounded)
    traits: Optional[Tuple[str, ...]] = None  # trait projection (None = group's all)


@dataclasses.dataclass
class IOStats:
    seeks: int = 0
    stripes_read: int = 0
    bytes_scanned: int = 0    # stripe blob bytes touched (I/O)
    bytes_decoded: int = 0    # payload bytes actually decoded (selective decode)
    requests: int = 0
    batched_requests: int = 0

    def snapshot(self) -> "IOStats":
        return dataclasses.replace(self)

    def delta(self, since: "IOStats") -> "IOStats":
        return IOStats(*(getattr(self, f.name) - getattr(since, f.name)
                         for f in dataclasses.fields(IOStats)))


class ImmutableUIHStore:
    def __init__(self, schema: Optional[ev.TraitSchema] = None, n_shards: int = 8):
        self.schema = schema or ev.default_schema()
        self.router = ShardRouter(n_shards)
        # shard -> (user_id, group) -> (sorted start_ts list, stripes list)
        self._shards: List[Dict[Tuple[int, str], Tuple[List[int], List[Stripe]]]] = [
            {} for _ in range(n_shards)
        ]
        self.generation = -1
        self.stats = IOStats()
        self.bulk_load_bytes = 0
        # Optional remote-I/O latency emulation for DPP benchmarks:
        # callable(seeks, bytes_scanned, shard_fanout) -> seconds to sleep.
        self.latency_model = None

    # -- bulk load (write path) ---------------------------------------------
    def bulk_load(
        self,
        tables: Dict[Tuple[int, str], List[Stripe]],
        generation: int,
    ) -> None:
        """Replace the store contents with a new compaction generation.

        ``tables`` maps (user_id, group) -> chronologically ordered stripes.
        Pre-sorted input is *required* (compaction guarantees it); the store
        only verifies and installs — mirroring a bulk file ingest."""
        new_shards: List[Dict[Tuple[int, str], Tuple[List[int], List[Stripe]]]] = [
            {} for _ in self._shards
        ]
        load_bytes = 0
        for (user_id, group), stripes in tables.items():
            starts = [s.start_ts for s in stripes]
            assert starts == sorted(starts), "compaction must emit sorted stripes"
            shard = self.router.route(user_id)
            new_shards[shard][(user_id, group)] = (starts, list(stripes))
            load_bytes += sum(len(s.blob) for s in stripes)
        self._shards = new_shards
        self.generation = generation
        self.bulk_load_bytes += load_bytes

    # -- read path ------------------------------------------------------------
    def _locate(self, user_id: int, group: str):
        shard = self.router.route(user_id)
        return shard, self._shards[shard].get((user_id, group))

    def scan(self, req: ScanRequest) -> ev.EventBatch:
        """Bounded range scan with 3-dimensional projection pushdown."""
        self.stats.requests += 1
        traits = req.traits or self.schema.group_traits(req.group)
        shard, entry = self._locate(req.user_id, req.group)
        if entry is None:
            return ev.empty_batch(self.schema, traits)
        starts, stripes = entry
        self.stats.seeks += 1  # single-level layout: one seek per (user,group) run

        # stripe run overlapping [start_ts, end_ts]
        lo = bisect.bisect_right(starts, req.start_ts) - 1
        lo = max(lo, 0)
        hi = bisect.bisect_right(starts, req.end_ts)  # stripes[lo:hi] may overlap
        if lo >= hi:
            return ev.empty_batch(self.schema, traits)

        # sequence-length projection: walk backwards from the most recent stripe
        chosen: List[Stripe] = []
        have = 0
        for i in range(hi - 1, lo - 1, -1):
            s = stripes[i]
            if s.end_ts < req.start_ts:
                break
            chosen.append(s)
            # conservative count: events in stripe within bound (upper estimate)
            have += s.n_events
            if req.max_events >= 0 and have >= req.max_events + s.n_events:
                # we may overshoot by up to one stripe at each temporal edge;
                # an extra stripe guards against end_ts trimming removing events
                break
        chosen.reverse()

        parts: List[ev.EventBatch] = []
        for s in chosen:
            self.stats.stripes_read += 1
            self.stats.bytes_scanned += len(s.blob)
            self.stats.bytes_decoded += columnar.decoded_bytes_for(s.blob, traits)
            parts.append(columnar.decode_stripe(s.blob, self.schema, traits))
        out = ev.concat_batches(parts)
        if not out:
            return ev.empty_batch(self.schema, traits)
        out = ev.time_slice(out, req.start_ts, req.end_ts)
        if req.max_events >= 0 and ev.batch_len(out) > req.max_events:
            # keep the most recent max_events (tenant sequence-length budget)
            n = ev.batch_len(out)
            out = ev.slice_batch(out, n - req.max_events, n)
        return out

    def multi_range_scan(self, reqs: Sequence[ScanRequest]) -> List[ev.EventBatch]:
        """Batched scan (paper: 'optimized multi-range scan with parallel I/O'):
        amortizes per-request overhead; shard fanout of the batch is recorded so
        the data-affinity benchmarks can show the symmetric-sharding win."""
        self.stats.batched_requests += 1
        before = self.stats.snapshot()
        out = [self.scan(r) for r in reqs]
        if self.latency_model is not None:
            import time

            d = self.stats.delta(before)
            delay = self.latency_model(d.seeks, d.bytes_scanned, self.fanout(reqs))
            if delay > 0:
                time.sleep(delay)
        return out

    # -- introspection ---------------------------------------------------------
    def fanout(self, reqs: Sequence[ScanRequest]) -> int:
        return len({self.router.route(r.user_id) for r in reqs})

    def stored_bytes(self) -> int:
        return sum(
            len(s.blob)
            for shard in self._shards
            for _, stripes in shard.values()
            for s in stripes
        )

    def stored_events(self, user_id: int, group: str) -> int:
        _, entry = self._locate(user_id, group)
        if entry is None:
            return 0
        return sum(s.n_events for s in entry[1])

    def watermark(self, user_id: int, group: str = "core") -> int:
        """Largest timestamp consolidated into the immutable tier for a user."""
        _, entry = self._locate(user_id, group)
        if entry is None or not entry[1]:
            return -1
        return entry[1][-1].end_ts
