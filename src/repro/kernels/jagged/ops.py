"""Public jit'd wrapper for the jagged->padded materialization kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.jagged.jagged import jagged_to_padded_kernel


def jagged_to_padded(values: jax.Array, offsets: jax.Array, max_len: int
                     ) -> jax.Array:
    """values (N, D) + offsets (B+1,) -> (B, max_len, D), right-aligned.

    Front-pads values by max_len zero rows so the kernel's fixed-size DMA
    window is always in-bounds; lane-pads D to a multiple of 128."""
    n, d = values.shape
    b = offsets.shape[0] - 1
    if b == 0 or max_len == 0:
        # zero-step grids / zero-row DMA windows are not valid pallas_calls
        return jnp.zeros((b, max_len, d), values.dtype)
    dp = (128 - d % 128) % 128
    v = jnp.pad(values, ((max_len, 0), (0, dp)))
    out = jagged_to_padded_kernel(v, offsets.astype(jnp.int32), max_len,
                                  interpret=runtime.interpret_default())
    return out[:, :, :d]
