"""Two-tower retrieval [Yi et al., RecSys'19]: embed 256, towers
1024-512-256, dot interaction, in-batch sampled softmax w/ logQ."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import TwoTowerConfig

FULL = TwoTowerConfig(
    name="two-tower-retrieval", embed_dim=256, tower_mlp=(1024, 512, 256),
    item_vocab=10_000_384, user_vocab=20_000_768, uih_len=100,
)

SMOKE = TwoTowerConfig(
    name="two-tower-smoke", embed_dim=16, tower_mlp=(32, 16),
    item_vocab=1_000, user_vocab=500, uih_len=12,
    compute_dtype=jnp.float32,
)


def spec() -> ArchSpec:
    return ArchSpec("two-tower-retrieval", "recsys", FULL, SMOKE, RECSYS_SHAPES)
