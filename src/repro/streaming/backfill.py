"""Batch→stream catch-up handoff (paper §3.2).

A trainer that starts (or restarts) behind the live edge first **replays
warehouse hours** — the batch tier, user-bucketed, cheap sequential reads —
then **flips to live stream consumption**, with an exactly-once guarantee at
the flip:

  * ``request_id``s are allocated monotonically in request-arrival order, and
    warehouse hours partition that order, so the largest replayed id is a
    **watermark**: every id <= watermark has been trained from the warehouse;
  * the live phase drops stream examples with ``request_id <= watermark``
    (they are the same examples, republished on the other leg of the
    bifurcated pipeline) and releases their generation leases;
  * everything above the watermark is trained exactly once, from the stream.

The replayed hour range is captured at **construction time** and must be
sealed (no concurrent ingestion into those hours): construct the coordinator
while the warehouse head is a finished hour, then start live traffic. Hours
inside the range with no data read as empty — the sweep is contiguous and
gap-tolerant.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

from repro.core.versioning import TrainingExample
from repro.storage.stream import Warehouse
from repro.streaming.source import StreamingSource


@dataclasses.dataclass
class BackfillStats:
    hours_replayed: int = 0
    empty_hours: int = 0
    warehouse_examples: int = 0
    stream_examples: int = 0
    duplicates_skipped: int = 0   # stream copies of warehouse-trained examples
    watermark: int = -1           # largest request_id trained from the warehouse
    flipped: bool = False         # reached the live phase


class BackfillCoordinator:
    """Replay ``warehouse`` hours up to the (sealed) head, then flip to live
    consumption from ``source`` — one unified micro-batch iterator a
    ``DPPWorkerPool`` can drain via ``start_stream``."""

    def __init__(
        self,
        warehouse: Warehouse,
        source: StreamingSource,
        micro_batch: int = 32,
        start_hour: Optional[int] = None,
        end_hour: Optional[int] = None,
    ):
        self.warehouse = warehouse
        self.source = source
        self.micro_batch = micro_batch
        hours = warehouse.hours()
        # the replay range is FROZEN here: [start_hour, end_hour] must be
        # sealed before live traffic starts, or the watermark under-covers
        self.start_hour = start_hour if start_hour is not None else (
            hours[0] if hours else 0)
        self.end_hour = end_hour if end_hour is not None else (
            hours[-1] if hours else self.start_hour - 1)
        self.stats = BackfillStats()

    def micro_batches(self) -> Iterator[List[TrainingExample]]:
        st = self.stats
        # -- phase 1: warehouse replay (contiguous, gap-tolerant hour sweep) --
        buf: List[TrainingExample] = []
        for hour in range(self.start_hour, self.end_hour + 1):
            empty = True
            for bucket in self.warehouse.iter_bucketed(hour):
                for exm in bucket:
                    empty = False
                    if exm.request_id > st.watermark:
                        st.watermark = exm.request_id
                    st.warehouse_examples += 1
                    buf.append(exm)
                    if len(buf) >= self.micro_batch:
                        yield buf
                        buf = []
            st.hours_replayed += 1
            if empty:
                st.empty_hours += 1
        if buf:
            yield buf
        st.flipped = True
        # -- phase 2: live stream, exactly-once across the flip ---------------
        for mb in self.source.micro_batches():
            keep: List[TrainingExample] = []
            for exm in mb:
                if exm.request_id <= st.watermark:
                    st.duplicates_skipped += 1
                    self.source.discard(exm)   # release its lease; it already
                    continue                   # trained from the warehouse
                st.stream_examples += 1
                keep.append(exm)
            if keep:
                yield keep
