"""The uniform ``Feed``: one handle over the compiled read path.

Whatever a ``DatasetSpec`` compiles into — warehouse replay through a
``DPPWorkerPool`` + ``RebatchingClient``, a live ``StreamingSession``, with or
without a ``DevicePrefetcher`` on top — the consumer sees ONE protocol:

  * iterate (or ``get(timeout=...)``) device-/host-ready full batches,
    ``None``/end meaning the feed is exhausted;
  * ``drained`` / ``ended`` — the end-of-stream sentinel was observed (vs a
    ``get`` timeout);
  * ``stats()`` — one composite ``FeedStats`` snapshot (client counters,
    merged worker counters, freshness, co-scan share savings);
  * ``client_stats`` — the live mutable ``ClientStats`` (starvation
    accounting shared with the trainer and elastic controller);
  * ``record_train_step`` / ``recycle`` — trainer backchannel, delegated to
    whichever stage owns it;
  * ``stop()`` — release the device-prefetch stage (queued device batches);
  * ``close()`` — full shutdown: stop prefetching, drain the host pipeline
    untrained so parked workers can exit, join, and re-raise any pipeline
    error;
  * ``join()`` — wait for a fully-consumed pipeline and surface errors.

``Trainer.fit`` consumes a ``Feed`` identically for batch and streaming.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Deque, Dict, Iterator, Optional

from repro.core.materialize import TenantShareStats
from repro.dpp.client import ClientStats
from repro.dpp.worker import WorkerStats
from repro.streaming.session import FreshnessStats


@dataclasses.dataclass
class FeedStats:
    """Composite snapshot of one feed's counters (see DESIGN.md §6/§9).

    Every member is a consistent point-in-time COPY taken by
    ``Feed.snapshot()`` — mutating a ``FeedStats`` never writes through to
    the live pipeline counters."""

    client: ClientStats
    workers: Optional[WorkerStats] = None     # merged across pool workers
    freshness: Optional[FreshnessStats] = None  # streaming feeds only
    share: Optional[TenantShareStats] = None    # co-scan feeds only
    peak_workers: int = 0
    stale_dropped: int = 0               # streaming protocol drops


class _StatsHandle:
    """``feed.stats`` must serve two contracts at once: the legacy feeds
    (``DevicePrefetcher``/``RebatchingClient``/``StreamingSession``) exposed a
    live ``ClientStats`` ATTRIBUTE (``feed.stats.starvation_pct``), while the
    Feed protocol specifies a ``stats()`` METHOD returning a composite
    snapshot. This handle is both: calling it snapshots (``FeedStats``);
    attribute access reads/writes through to the live ``ClientStats`` — so
    call sites migrated off the deprecated ``make_*_feed`` shims keep working
    either way."""

    __slots__ = ("_feed",)

    def __init__(self, feed: "Feed"):
        object.__setattr__(self, "_feed", feed)

    def __call__(self) -> "FeedStats":
        return self._feed.snapshot()

    def __getattr__(self, name):
        return getattr(self._feed.client_stats, name)

    def __setattr__(self, name, value):
        setattr(self._feed.client_stats, name, value)


class Feed:
    """Uniform read-path handle (see module docstring).

    ``inner`` is the stage the consumer pulls from (a ``DevicePrefetcher``,
    ``StreamingSession``, or ``RebatchingClient``); the other stages are held
    for stats, shutdown, and draining. Constructed by ``repro.data.open_feed``
    (or the deprecated ``launch.steps.make_*_feed`` shims).
    """

    def __init__(
        self,
        inner: Any,
        *,
        client: Any = None,
        pool: Any = None,
        session: Any = None,
        prefetcher: Any = None,
        prep_fn=None,
        spec=None,
        share_stats=None,
        resume_meta=None,
        telemetry=None,
        store=None,
    ):
        self._inner = inner
        # per-run repro.obs.Telemetry (None = off): the Feed is the delivery
        # and train end of the span pipeline, and publishes the final
        # composite counters into the metrics registry on close()
        self.telemetry = telemetry
        # the store the feed scans (telemetry publish on close)
        self.store = store
        self.client = client if client is not None else getattr(
            session, "client", None)
        self.pool = pool if pool is not None else getattr(
            session, "pool", None)
        self.session = session
        self.prefetcher = prefetcher
        self.spec = spec
        self.share_stats = share_stats
        # prep applied consumer-side when there is no prefetch stage to run it
        self._prep_fn = prep_fn if prefetcher is None else None
        self._closed = False
        # -- crash-safe checkpoint accounting (§10) ---------------------------
        # ``resume_meta`` is attached by open_feed on checkpointable feeds:
        # {"fingerprint", "base_rows", "base_batches", "hour_rows"?}. The FIFO
        # below maps delivered batches to trained batches: get() pushes each
        # batch's row count, record_train_step() pops the oldest — a batch the
        # prefetcher pulled ahead (or the trainer fetched but never stepped)
        # is therefore NOT counted as trained, which is exactly the set a
        # resume must re-produce.
        self._resume_meta = resume_meta
        self._pending_rows: Deque[int] = collections.deque()
        self._ckpt_lock = threading.Lock()
        self._trained_rows = 0
        self._trained_batches = 0
        self._join_error: list = []
        self._joiner: Optional[threading.Thread] = None
        if pool is not None and session is None:
            # batch pipeline: a background joiner waits out the pool so the
            # client receives its end-of-stream sentinel the moment the work
            # list drains (the consumer must never have to call pool.join()
            # itself — it would deadlock waiting for batches meanwhile)
            def _join() -> None:
                try:
                    pool.join()
                except BaseException as e:  # surfaced by join()/close()
                    self._join_error.append(e)

            self._joiner = threading.Thread(target=_join, daemon=True,
                                            name="feed-joiner")
            self._joiner.start()

    # -- consumption -----------------------------------------------------------
    def get(self, timeout: Optional[float] = None, record: bool = True):
        """Next full batch, or ``None`` (end of stream OR timeout —
        disambiguate via ``drained``). ``record=False`` suppresses the
        starvation accounting (pulls that are not the trainer's critical
        path), propagated to whichever stage owns the counters."""
        g = getattr(self._inner, "get", None)
        if g is not None:                       # DevicePrefetcher stage
            out = g(timeout=timeout, record=record)
        else:
            out = self._inner.get_full_batch(timeout=timeout, record=record)
            if out is not None and self._prep_fn is not None:
                out = self._prep_fn(out)
        if out is not None and record and self.telemetry is not None:
            # pop the span FIFO's delivery side (record=False drains bypass
            # this on purpose — SpanTracker.drain() accounts those batches)
            self.telemetry.spans.mark_delivered()
        if out is not None and record and self._resume_meta is not None:
            # row count from the CLIENT's emission FIFO, not the delivered
            # batch: a prep_fn may reshape batches (e.g. pre-split grad-accum
            # microbatches) and the resume cursor must count source rows
            emitted = getattr(self.client, "emitted_rows", None)
            if emitted:
                rows = emitted.popleft()
            else:
                v = next(iter(out.values()))
                shape = getattr(v, "shape", None)   # numpy OR device arrays
                rows = int(shape[0]) if shape else len(v)
            with self._ckpt_lock:
                self._pending_rows.append(rows)
        return out

    def get_full_batch(self, timeout: Optional[float] = None,
                       record: bool = True):
        """Client-protocol alias (legacy call sites)."""
        return self.get(timeout=timeout, record=record)

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        while True:
            b = self.get()
            if b is None:
                return
            yield b

    @property
    def ended(self) -> bool:
        return bool(getattr(self._inner, "ended", False))

    @property
    def drained(self) -> bool:
        """True iff the end-of-stream sentinel was observed (the feed is
        exhausted — a ``get`` returning ``None`` alone may just be a
        timeout)."""
        return self.ended

    # -- trainer backchannel ---------------------------------------------------
    def record_train_step(self, seconds: float) -> None:
        if self._resume_meta is not None:
            with self._ckpt_lock:
                if self._pending_rows:   # oldest delivered batch now trained
                    self._trained_rows += self._pending_rows.popleft()
                    self._trained_batches += 1
                trained = self._trained_rows
            if self.session is not None:
                # steady-state bound on the session's resume ledger even when
                # the trainer never checkpoints
                self.session.trim_ledger(trained)
        rec = getattr(self._inner, "record_train_step", None)
        if rec is not None:
            rec(seconds)
        if self.telemetry is not None:
            self.telemetry.spans.record_train(seconds)

    def recycle(self, batch) -> None:
        rec = getattr(self._inner, "recycle", None)
        if rec is not None:
            rec(batch)

    # -- stats -----------------------------------------------------------------
    @property
    def client_stats(self) -> Optional[ClientStats]:
        """The live mutable ClientStats (starvation/train-time accounting)."""
        if self.client is not None:
            return self.client.stats
        return getattr(self._inner, "stats", None)

    @property
    def stats(self) -> _StatsHandle:
        """Dual-contract handle: ``feed.stats()`` -> composite ``FeedStats``
        snapshot (the Feed protocol); ``feed.stats.<counter>`` -> the live
        ``ClientStats`` field (the legacy feed-object contract)."""
        return _StatsHandle(self)

    def snapshot(self) -> FeedStats:
        """Point-in-time snapshot: every member is a COPY, so the repo's
        before/after delta idiom works (the live mutable counters stay
        reachable via ``client_stats``)."""

        def copy(obj):
            return dataclasses.replace(obj) if obj is not None else None

        workers = None
        if self.pool is not None:
            workers = self.pool.merged_worker_stats()  # already a fresh merge
        return FeedStats(
            client=copy(self.client_stats) or ClientStats(),
            workers=workers,
            freshness=copy(getattr(self.session, "freshness", None)),
            share=copy(self.share_stats),
            peak_workers=getattr(self.pool, "peak_workers", 0),
            stale_dropped=getattr(self.session, "stale_dropped", 0),
        )

    def publish_telemetry(self) -> None:
        """Flush the composite counters into the telemetry registry and close
        out spans still riding the FIFOs. Idempotent (the registry adapters
        take monotone maxima); called by ``close()``, callable any time for a
        mid-run flush."""
        tel = self.telemetry
        if tel is None:
            return
        snap = self.snapshot()
        tel.publish_stats(snap.client, "client")
        if snap.workers is not None:
            tel.publish_stats(snap.workers, "worker")
        if snap.freshness is not None:
            tel.publish_stats(snap.freshness, "freshness")
        if snap.share is not None:
            tel.publish_stats(snap.share, "share")
        tel.registry.gauge(
            "repro_feed_peak_workers",
            help="peak concurrent DPP workers").set(snap.peak_workers)
        tel.registry.counter(
            "repro_feed_stale_dropped_total",
            help="streaming protocol drops").set_total(snap.stale_dropped)
        if self.session is not None:
            src = getattr(self.session, "source", None)
            if src is not None:
                tel.publish_stats(src.stats, "source")
            coord = getattr(self.session, "coordinator", None)
            if coord is not None:
                tel.publish_stats(coord.stats, "backfill",
                                  gauge_fields=("watermark",))
        pub = getattr(self.store, "publish_telemetry", None)
        if pub is not None:
            pub()

    # -- crash-safe checkpoint (§10) --------------------------------------------
    @property
    def can_checkpoint(self) -> bool:
        """True iff this feed was compiled by ``open_feed`` with resumable
        plumbing (ordered placement; for streaming, the warehouse backfill
        leg). Shim-constructed feeds cannot checkpoint."""
        return self._resume_meta is not None

    def checkpoint(self) -> Dict[str, Any]:
        """Minimal cursor state for exactly-once resume (§10): pass the dict
        to ``open_feed(spec, sim, resume_from=...)`` after a restart (the
        ``CheckpointManager`` saves it as a ``feed_state`` sidecar atomically
        with the model state).

        Counts only rows whose gradient was APPLIED (``record_train_step``
        consumed them) — batches pulled ahead by a prefetcher, or delivered
        but killed before the step, are re-produced by the resumed feed.
        Call from the training thread (the same serialization point the
        model checkpoint is taken at)."""
        if self._resume_meta is None:
            raise ValueError(
                "checkpoint() requires a spec-compiled, ordered feed "
                "(repro.data.open_feed); shim feeds cannot checkpoint")
        meta = self._resume_meta
        with self._ckpt_lock:
            local_rows = self._trained_rows
            local_batches = self._trained_batches
        state: Dict[str, Any] = {
            "kind": "stream" if self.session is not None else "batch",
            "fingerprint": meta["fingerprint"],
            "trained_rows": meta["base_rows"] + local_rows,
            "trained_batches": meta["base_batches"] + local_batches,
        }
        if self.session is not None:
            state["stream"] = self.session.checkpoint_state(local_rows)
        else:
            hour_rows = meta.get("hour_rows")
            if hour_rows:
                state["warehouse"] = self._warehouse_cursor(
                    hour_rows, state["trained_rows"])
        return state

    @staticmethod
    def _warehouse_cursor(hour_rows, trained_rows: int) -> Dict[str, int]:
        """Observability view of a batch cursor: (hour, intra-hour offset) of
        the next untrained example in the warehouse replay order."""
        remaining = trained_rows
        for hour, n in hour_rows:
            if remaining < n:
                return {"hour": int(hour), "offset": int(remaining)}
            remaining -= n
        last = hour_rows[-1]
        return {"hour": int(last[0]), "offset": int(last[1])}  # exhausted

    # -- lifecycle -------------------------------------------------------------
    def stop(self) -> None:
        """Release the device-prefetch stage (queued device buffers). The host
        pipeline keeps running — use ``close()`` for full shutdown."""
        if self.prefetcher is not None:
            self.prefetcher.stop()

    def join(self) -> None:
        """Wait for a fully-consumed pipeline to finish and re-raise any
        worker/feeder error. Call only after consuming the whole feed — use
        ``close()`` if the consumer walked away early."""
        if self.session is not None:
            self.session.join()
        if self._joiner is not None:
            self._joiner.join()
        if self._join_error:
            raise self._join_error[0]

    def close(self, timeout: Optional[float] = None) -> None:
        """Full shutdown (idempotent): stop the prefetch stage, drain the host
        pipeline untrained so workers parked on the bounded slot queue can
        exit, then join and surface any pipeline error. ``timeout`` bounds the
        drain; on expiry the daemon threads are abandoned. A shim feed over a
        bare client (caller-owned pool) drains in the background instead —
        close() returns immediately and the caller's own ``pool.join()``
        both finishes the drain and terminates it."""
        if self._closed:
            return
        self._closed = True
        try:
            self._close_inner(timeout)
        finally:
            if self.telemetry is not None:
                # close out spans still riding the FIFOs, then flush the
                # final composite counters — even when join() re-raises a
                # pipeline error (chaos runs must still report)
                self.telemetry.spans.drain()
                self.publish_telemetry()

    def _close_inner(self, timeout: Optional[float]) -> None:
        self.stop()
        if self.session is not None:
            self.session.close(timeout=timeout)
            return
        if self.client is not None and self._joiner is None:
            # Shim-constructed feed around a BARE client (the deprecated
            # make_*_feed path): the pool — and thus the pool.join() that
            # sends the client's end-of-stream sentinel — belongs to the
            # CALLER and runs only after this close() returns. Drain in the
            # background so workers parked on the bounded slot queues are
            # released while the caller joins its own pool; the sentinel that
            # join sends is what stops the drainer. Daemon: if the caller
            # never joins, it idles until process exit.
            client = self.client

            def _drain() -> None:
                while not getattr(client, "ended", True):
                    b = client.get_full_batch(timeout=0.05, record=False)
                    if b is not None:
                        client.recycle(b)

            threading.Thread(target=_drain, daemon=True,
                             name="feed-shim-drainer").start()
            self.join()
            return
        if self._joiner is not None and self.client is not None:
            deadline = (None if timeout is None
                        else time.perf_counter() + timeout)
            while self._joiner.is_alive():
                if deadline is not None and time.perf_counter() > deadline:
                    # drain timed out: abandon the daemon threads, but still
                    # surface any pipeline error already captured — a close()
                    # that swallows a worker failure would report success on
                    # silently truncated training data
                    if self._join_error:
                        raise self._join_error[0]
                    return
                b = self.client.get_full_batch(timeout=0.05, record=False)
                if b is not None:
                    self.client.recycle(b)
        self.join()

    def __enter__(self) -> "Feed":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
