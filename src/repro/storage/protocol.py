"""The storage-tier contract every consumer speaks (§4.2.3, DESIGN.md §11).

``core.materialize``, ``core.snapshot``, ``data.planner``, ``data.compile``
and ``dpp.affinity`` are all written against this surface, never against a
concrete store class — the in-process monolith (``ImmutableUIHStore``) and
the disaggregated multi-node client (``ShardedUIHStore``) are drop-in
interchangeable. The contract is behavioral, not just structural:

  * ``plan``/``execute_plan``/``multi_range_scan`` — batched reads are
    planned (dedupe + union-projection subsumption) and executed with the
    implementation's parallelism (shard threads / node fanout); results come
    back in original request order and the call's ``IOStats`` delta lands in
    the caller's ``out_stats``.
  * ``acquire_lease`` — pins ONE consistent generation for the holder: on the
    sharded store this is an epoch barrier (every node pins the same
    generation; a bulk load can never interleave with lease acquisition).
  * ``bulk_load`` — installs a generation atomically with respect to leases:
    a leased generation id is never reused, a superseded-but-leased
    generation is retained until its last release.
  * ``StaleGeneration`` remediation contract: scanning a generation that is
    neither live nor retained raises ``GenerationUnavailable`` (a
    ``KeyError``) so the Materializer's layered remediation works unchanged.

**Failure model** (DESIGN.md §12): the contract distinguishes exactly two
error classes on the read path, and every consumer is written against the
distinction rather than against any concrete store:

  * ``NodeUnavailable`` (an ``IOError``) — *the bytes still exist, the path
    to them is down*. Retryable: the caller's work item fails cleanly with no
    partial result, and an identical retry succeeds once a replica answers or
    the node returns. The DPP pool's self-healing (requeue + respawn,
    PR 5) is the designated handler.
  * ``GenerationUnavailable`` (a ``KeyError``) — *the data is gone* (the
    generation was GC'd everywhere). NOT retryable: the Materializer's
    StaleGeneration remediation must re-resolve against a live generation.

**Degraded-mode contract** (replicated tier, r-way): a store with replicas
serves reads from any live replica — failover is invisible to the caller
(same bytes, same ``StaleGeneration`` semantics, leases keep pinning on the
survivors). Only when EVERY replica of a user's chain is unreachable does the
read raise ``NodeUnavailable`` — still the retryable class, so training
degrades to the PR 5 self-healing path (requeue, bounded retries, surfaced
abandonment) and is byte-identical to a fault-free run once a replica
returns within the retry budget. Degradation is never silent: the store
counts ``degraded_scans`` and the pool surfaces abandonment.
"""
from __future__ import annotations

from typing import (
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    runtime_checkable,
)

from repro.core import events as ev
from repro.storage.immutable_store import IOStats, ScanPlan, ScanRequest
from repro.storage.sharding import PlacementMap


class NodeUnavailable(IOError):
    """A store node (or, with replication, every replica in a user's chain)
    is unreachable. Transient and retryable: the caller's work item fails
    cleanly (no partial result is returned) and a retry after a replica or
    the node returns succeeds — unlike ``GenerationUnavailable``, which means
    the *data* is gone and remediation must re-resolve."""


@runtime_checkable
class LeaseProtocol(Protocol):
    """A refcounted pin on one immutable generation (context-manager
    friendly; ``release`` is idempotent)."""

    generation: int

    def release(self) -> None: ...

    def __enter__(self) -> "LeaseProtocol": ...

    def __exit__(self, *exc) -> None: ...


@runtime_checkable
class StoreProtocol(Protocol):
    """The immutable-tier surface (monolith and sharded client both satisfy
    it). Attributes are part of the contract: consumers read ``schema`` for
    trait resolution, ``generation`` for staleness decisions, ``n_shards``
    for symmetric data placement, and ``stats`` for I/O accounting."""

    schema: ev.TraitSchema
    n_shards: int
    generation: int
    stats: IOStats

    # -- write path ----------------------------------------------------------
    def bulk_load(self, tables, generation: int) -> None: ...

    # -- read path -----------------------------------------------------------
    def scan(self, req: ScanRequest) -> ev.EventBatch: ...

    def plan(self, reqs: Sequence[ScanRequest]) -> ScanPlan: ...

    def execute_plan(
        self, plan: ScanPlan, out_stats: Optional[IOStats] = None
    ) -> List[ev.EventBatch]: ...

    def multi_range_scan(
        self,
        reqs: Sequence[ScanRequest],
        out_stats: Optional[IOStats] = None,
    ) -> List[ev.EventBatch]: ...

    def estimate_scan(self, req: ScanRequest) -> Tuple[int, int]: ...

    # -- generations + leases ------------------------------------------------
    def acquire_lease(
        self, generation: Optional[int] = None
    ) -> LeaseProtocol: ...

    def has_generation(self, generation: int) -> bool: ...

    def leased_generations(self) -> Dict[int, int]: ...

    def retained_generations(self) -> List[int]: ...

    # -- placement + introspection -------------------------------------------
    def live_placement(self) -> Optional[PlacementMap]: ...

    def watermark(self, user_id: int, group: str = "core",
                  generation: int = -1) -> int: ...

    def stored_events(self, user_id: int, group: str) -> int: ...

    def stored_bytes(self) -> int: ...

    def close(self) -> None: ...
