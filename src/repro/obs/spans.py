"""Per-batch pipeline spans: correlation ids riding the work-item ledger
(DESIGN.md §13).

A span is minted when a work item is sequenced into the DPP pool
(``DPPWorkerPool._task`` — the moment the scan plan's micro-batch enters the
pipeline); its correlation id IS the pool's work-item ``seq``, the same id
the placement ledger and retry machinery already carry, so spans survive
worker crashes, requeues and failovers for free.  Stage timestamps are
recorded ambiently: the pool parks the item's span in a thread-local around
``worker.process*`` and the placement ``put``, and the worker/client record
stages via :func:`current_span` without knowing telemetry exists (one
thread-local read when telemetry is off).

Stages (all ``time.perf_counter`` pairs; a retried attempt OVERWRITES the
stage so the surviving chain is the attempt that actually produced data):

    scan       store lookup incl. decode (decode runs on store-internal
               shard threads, so it folds into scan; the scan stage carries
               IOStats-delta metadata — bytes_scanned/bytes_decoded — so
               decode weight stays visible)
    featurize  jagged featurization on the DPP worker
    place      rebatch placement (ordered placer / worker delivery)
    h2d        host-to-device transfer (present when a DevicePrefetcher runs)
    train      device step wall time (present when a Trainer drives the feed)

plus two point timestamps on the batch: ``t_emit`` (slot commit) and
``t_deliver`` (handed to the consumer).

Batch association: every committed slot carries the item spans that wrote
rows into it; at commit the tracker appends a ``BatchSpan`` to an emission
FIFO that rides parallel to the client's output queue.  The prefetcher pops
that FIFO to attach the h2d stage; ``Feed.get`` pops the delivery side; and
``record_train_step`` closes the chain.  Unsampled batches flow through the
FIFOs as lightweight placeholders so the queues never desynchronize.
Association is exact in ordered mode (a single placer thread owns
commit order); in unordered mode it is best-effort FIFO matching.

Sampling: 1-in-``sample_every`` items get a span (seq modulo). ``sample_every=1``
records everything (tests); the default keeps overhead well under the 2%
budget enforced by ``benchmarks/bench_feed.py``.
"""
from __future__ import annotations

import collections
import json
import threading
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

STAGES: Tuple[str, ...] = ("scan", "featurize", "place", "h2d", "train")
HOST_STAGES: Tuple[str, ...] = ("scan", "featurize", "place")

_TLS = threading.local()


def current_span() -> Optional["ItemSpan"]:
    """The span of the work item this thread is currently processing, or
    None (telemetry off / item unsampled).  Stage recorders in the worker
    and client call this; it must stay allocation-free."""
    return getattr(_TLS, "span", None)


class ItemSpan:
    """Span of one pool work item (a micro-batch of requests)."""

    __slots__ = ("seq", "t_mint", "stages", "attempts", "meta")

    def __init__(self, seq: int, t_mint: float) -> None:
        self.seq = seq
        self.t_mint = t_mint
        self.stages: Dict[str, Tuple[float, float]] = {}
        self.attempts = 0
        self.meta: Dict[str, Any] = {}

    def stage(self, name: str, t0: float, t1: float) -> None:
        self.stages[name] = (t0, t1)

    def stage_s(self, name: str) -> float:
        w = self.stages.get(name)
        return (w[1] - w[0]) if w else 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {"seq": self.seq, "t_mint": self.t_mint,
                "attempts": self.attempts,
                "stages": {k: list(v) for k, v in self.stages.items()},
                "meta": self.meta}


class BatchSpan:
    """Merged span of one emitted full batch: the item spans whose rows the
    batch contains, plus emit/deliver/train timestamps."""

    __slots__ = ("emit_seq", "items", "rows", "t_emit", "t_deliver",
                 "t_train_end", "sampled", "stages")

    def __init__(self, emit_seq: int, items: List[ItemSpan], rows: int,
                 t_emit: float) -> None:
        self.emit_seq = emit_seq
        self.items = items
        self.rows = rows
        self.t_emit = t_emit
        self.t_deliver: Optional[float] = None
        self.t_train_end: Optional[float] = None
        self.sampled = bool(items)
        # batch-level stages (h2d, train) — stages that see whole batches,
        # not work items
        self.stages: Dict[str, Tuple[float, float]] = {}

    def stage(self, name: str, t0: float, t1: float) -> None:
        self.stages[name] = (t0, t1)

    def stage_window(self, name: str) -> Optional[Tuple[float, float]]:
        if name in self.stages:
            return self.stages[name]
        ws = [sp.stages[name] for sp in self.items if name in sp.stages]
        if not ws:
            return None
        return (min(w[0] for w in ws), max(w[1] for w in ws))

    def stage_s(self, name: str) -> float:
        """Stage seconds: batch-level window if recorded, else total across
        contributing items (work time, not wall time)."""
        if name in self.stages:
            w = self.stages[name]
            return w[1] - w[0]
        return sum(sp.stage_s(name) for sp in self.items)

    def latency_s(self) -> Optional[float]:
        """Pipeline latency: first contributing scan start -> delivery."""
        if self.t_deliver is None:
            return None
        starts = [w[0] for sp in self.items for w in sp.stages.values()]
        if not starts:
            return None
        return self.t_deliver - min(starts)

    def to_dict(self) -> Dict[str, Any]:
        return {"emit_seq": self.emit_seq, "rows": self.rows,
                "t_emit": self.t_emit, "t_deliver": self.t_deliver,
                "t_train_end": self.t_train_end, "sampled": self.sampled,
                "latency_s": self.latency_s(),
                "stages": {k: list(v) for k, v in self.stages.items()},
                "items": [sp.to_dict() for sp in self.items]}


class SpanTracker:
    """Mints item spans, threads them through the emission/delivery FIFOs,
    and keeps a bounded ring of completed batch spans."""

    def __init__(self, sample_every: int = 8, capacity: int = 2048,
                 registry=None) -> None:
        if sample_every < 1:
            raise ValueError(f"sample_every must be >= 1, got {sample_every}")
        self.sample_every = sample_every
        self.registry = registry
        self._stage_hist = None    # lazy repro_stage_seconds family cache
        self.has_h2d = False
        self._lock = threading.Lock()
        self._items: Dict[int, ItemSpan] = {}      # minted, not yet placed
        self._emitted: Deque[BatchSpan] = collections.deque()
        self._h2d_done: Deque[BatchSpan] = collections.deque()
        self._await_train: Deque[BatchSpan] = collections.deque()
        self.completed: Deque[BatchSpan] = collections.deque(maxlen=capacity)
        # lifecycle accounting (orphan detection in tests / report)
        self.minted = 0
        self.abandoned = 0
        self.emitted_batches = 0
        self.delivered_batches = 0
        self.dropped_in_flight = 0

    # -- mint / worker-side -------------------------------------------------
    def mint(self, seq: int) -> Optional[ItemSpan]:
        if seq % self.sample_every:
            return None
        sp = ItemSpan(seq, time.perf_counter())
        with self._lock:
            self._items[seq] = sp
            self.minted += 1
        return sp

    def get(self, seq: int) -> Optional[ItemSpan]:
        return self._items.get(seq)

    def enter_item(self, seq: int, attempt: bool = True) -> None:
        # unsampled fast path: skip the dict lookup (seven of eight items at
        # the default sampling — this is the per-item hot path)
        if seq % self.sample_every:
            _TLS.span = None
            return
        sp = self._items.get(seq)
        if sp is not None and attempt:
            sp.attempts += 1
        _TLS.span = sp

    def exit_item(self) -> None:
        _TLS.span = None

    def current(self) -> Optional[ItemSpan]:
        return current_span()

    def abandon(self, seq: int) -> None:
        """Item exhausted its retries; its span is accounted, not orphaned."""
        if seq % self.sample_every:
            return
        with self._lock:
            if self._items.pop(seq, None) is not None:
                self.abandoned += 1

    def finish_item(self, seq: int) -> None:
        """Item fully placed — it no longer rides the live-item map (its
        span stays referenced by whatever BatchSpans it contributed to)."""
        if seq % self.sample_every:
            return
        with self._lock:
            self._items.pop(seq, None)

    # -- emission / consumption pipeline ------------------------------------
    def emit_batch(self, emit_seq: int, items: List[ItemSpan],
                   rows: int) -> BatchSpan:
        # unsampled batches are placeholders that only hold a FIFO position:
        # skip the clock read for them
        t = time.perf_counter() if items else 0.0
        bs = BatchSpan(emit_seq, list(items), rows, t)
        with self._lock:
            self._emitted.append(bs)
            self.emitted_batches += 1
        return bs

    def pop_emitted(self) -> Optional[BatchSpan]:
        with self._lock:
            return self._emitted.popleft() if self._emitted else None

    def push_h2d_done(self, bs: Optional[BatchSpan]) -> None:
        if bs is None:
            return
        with self._lock:
            self._h2d_done.append(bs)

    def mark_delivered(self) -> Optional[BatchSpan]:
        with self._lock:
            q = self._h2d_done if self.has_h2d else self._emitted
            if not q:
                return None
            bs = q.popleft()
            if bs.sampled:
                bs.t_deliver = time.perf_counter()
            self._await_train.append(bs)
            self.delivered_batches += 1
        return bs

    def record_train(self, dt: float) -> Optional[BatchSpan]:
        with self._lock:
            if not self._await_train:
                return None
            bs = self._await_train.popleft()
        if bs.sampled:
            bs.t_train_end = time.perf_counter()
            bs.stage("train", bs.t_train_end - dt, bs.t_train_end)
            self._finalize(bs)
        return bs

    def _finalize(self, bs: BatchSpan) -> None:
        if not bs.sampled:
            return
        self.completed.append(bs)
        if self.registry is not None:
            hist = self._stage_hist
            if hist is None:
                hist = self._stage_hist = self.registry.histogram(
                    "repro_stage_seconds",
                    help="stage durations from sampled pipeline spans",
                    labels=("stage",))
            for sp in bs.items:
                for name in sp.stages:
                    hist.labels(stage=name).observe(sp.stage_s(name))
            for name in bs.stages:
                hist.labels(stage=name).observe(bs.stage_s(name))

    def drain(self) -> None:
        """Feed shut down: close out spans still riding the FIFOs.  Batches
        delivered but never trained finalize without a train stage; batches
        emitted but never delivered count as dropped in flight."""
        with self._lock:
            await_train = list(self._await_train)
            self._await_train.clear()
            dropped = list(self._emitted) + list(self._h2d_done)
            self._emitted.clear()
            self._h2d_done.clear()
            self.dropped_in_flight += len(dropped)
        for bs in await_train:
            self._finalize(bs)

    def orphan_items(self) -> List[ItemSpan]:
        """Spans minted but never placed NOR abandoned — must be empty after
        a drained run (the span-completeness invariant)."""
        with self._lock:
            return list(self._items.values())

    # -- analysis ------------------------------------------------------------
    def stage_totals(self) -> Dict[str, float]:
        totals: Dict[str, float] = {}
        for bs in list(self.completed):
            for sp in bs.items:
                for name in sp.stages:
                    totals[name] = totals.get(name, 0.0) + sp.stage_s(name)
            for name in bs.stages:
                totals[name] = totals.get(name, 0.0) + bs.stage_s(name)
        return totals

    def critical_path(self, *, starved_host_s: float = 0.0,
                      starved_h2d_s: float = 0.0,
                      starved_time_s: float = 0.0) -> Dict[str, Any]:
        """Attribute trainer starvation to pipeline stages.

        ``starved_h2d_s`` is attributed to the h2d stage outright; the host
        share splits across the host stages proportionally to their sampled
        span time (the stage the pipeline spends most host time in is the
        stage most likely to be the one the trainer waited on)."""
        return critical_path(self.stage_totals(),
                             starved_host_s=starved_host_s,
                             starved_h2d_s=starved_h2d_s,
                             starved_time_s=starved_time_s)

    def to_jsonl_lines(self) -> List[str]:
        return [json.dumps(bs.to_dict(), default=str)
                for bs in list(self.completed)]

    def write_jsonl(self, path) -> None:
        with open(path, "w") as f:
            for line in self.to_jsonl_lines():
                f.write(line + "\n")

    def lifecycle_counts(self) -> Dict[str, int]:
        with self._lock:
            return {"minted": self.minted, "abandoned": self.abandoned,
                    "emitted_batches": self.emitted_batches,
                    "delivered_batches": self.delivered_batches,
                    "dropped_in_flight": self.dropped_in_flight,
                    "live_items": len(self._items),
                    "completed": len(self.completed)}


def critical_path(stage_totals: Dict[str, float], *,
                  starved_host_s: float = 0.0, starved_h2d_s: float = 0.0,
                  starved_time_s: float = 0.0) -> Dict[str, Any]:
    """Pure attribution math (shared by the tracker and the report CLI)."""
    host_total = sum(stage_totals.get(s, 0.0) for s in HOST_STAGES)
    attribution: Dict[str, float] = {}
    if starved_h2d_s > 0:
        attribution["h2d"] = starved_h2d_s
    if starved_host_s > 0:
        if host_total > 0:
            for s in HOST_STAGES:
                share = stage_totals.get(s, 0.0) / host_total
                if share > 0:
                    attribution[s] = attribution.get(s, 0.0) + starved_host_s * share
        else:
            # No sampled host spans: attribute to scan, the stage that owns
            # the store round-trip and dominates cold pipelines.
            attribution["scan"] = attribution.get("scan", 0.0) + starved_host_s
    attributed = sum(attribution.values())
    dominant = max(attribution, key=attribution.get) if attribution else None
    frac = (attributed / starved_time_s) if starved_time_s > 0 else 1.0
    return {"stage_totals_s": dict(stage_totals),
            "attribution_s": attribution,
            "attributed_s": attributed,
            "starved_time_s": starved_time_s,
            "attributed_frac": min(1.0, frac),
            "dominant_stage": dominant}
