"""Deterministic fault-injection harness (§10): seeded FaultPlans + wrapper
layers that turn chaos scenarios into reproducible tests."""
from repro.testing.faults import (
    ALL_KINDS,
    CONSUME_KINDS,
    NODE_STATE_KINDS,
    SCAN_KINDS,
    DecodeCorruption,
    FaultPlan,
    FaultSpec,
    FaultyStore,
    FaultyStream,
    FaultySim,
    InjectedFault,
    InjectedIOError,
    WorkerCrash,
    wrap_sim,
)

__all__ = [
    "ALL_KINDS",
    "CONSUME_KINDS",
    "NODE_STATE_KINDS",
    "SCAN_KINDS",
    "DecodeCorruption",
    "FaultPlan",
    "FaultSpec",
    "FaultyStore",
    "FaultyStream",
    "FaultySim",
    "InjectedFault",
    "InjectedIOError",
    "WorkerCrash",
    "wrap_sim",
]
