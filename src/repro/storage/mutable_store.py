"""Real-time mutable UIH store (paper §4.1.1).

Captures the most recent engagements with second-level freshness. To support
high-frequency updates without a Read-Modify-Write penalty, writes are
*blind-write appends* (unsorted chunks per user); state resolution (sort +
merge) is deferred to read time or to background compaction. A write-through
cache co-located with the ranking service serves the read path.

Retention is coupled to the immutable store's compaction cadence: events must
stay in the mutable tier until the next compaction cycle has consolidated them
into the immutable tier (``evict_until``)."""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

import numpy as np

from repro.core import events as ev


class MutableUIHStore:
    def __init__(self, schema: Optional[ev.TraitSchema] = None):
        self.schema = schema or ev.default_schema()
        self._chunks: Dict[int, List[ev.EventBatch]] = {}
        # write-through cache of the merged view, invalidated on append
        self._cache: Dict[int, ev.EventBatch] = {}
        # append/evict mutual exclusion: eviction's merge->install sequence
        # must not lose a concurrent blind-write (or re-publish a cached view
        # missing it); reads stay lock-free
        self._write_lock = threading.Lock()
        # per-user write-state version: bumped on every append and eviction.
        # O(1) freshness probe for serving-side caches — an unchanged version
        # guarantees an unchanged merged view (the converse is conservative:
        # an eviction below a reader's window bumps it without changing that
        # reader's slice, which can only cause a spurious recompute)
        self._versions: Dict[int, int] = {}
        # accounting for benchmarks
        self.bytes_written = 0
        self.bytes_read = 0
        self.appends = 0
        self.evict_cache_hits = 0   # evictions served from the merged-view cache
        self.evict_merges = 0       # evictions that had to re-merge chunks

    # -- write path ---------------------------------------------------------
    def append(self, user_id: int, batch: ev.EventBatch) -> None:
        """Blind-write append: no read, no sort, O(1) amortized."""
        if ev.batch_len(batch) == 0:
            return
        with self._write_lock:
            self._chunks.setdefault(user_id, []).append(batch)
            self._cache.pop(user_id, None)
            self._versions[user_id] = self._versions.get(user_id, 0) + 1
        self.appends += 1
        self.bytes_written += sum(v.nbytes for v in batch.values())

    # -- read path ----------------------------------------------------------
    def read(self, user_id: int, t_lo: int, t_hi: int) -> ev.EventBatch:
        """Merged, time-ordered view of recent events in (t_lo, t_hi].

        Merge-on-read resolves the unsorted blind-write chunks; the merged view
        is cached (write-through cache) until the next append."""
        merged = self._cache.get(user_id)
        if merged is None:
            chunks = self._chunks.get(user_id, [])
            n0 = len(chunks)
            merged = ev.merge_sorted(chunks)
            if not merged:
                merged = ev.empty_batch(self.schema)
            with self._write_lock:
                # install only if no append/evict raced the merge: eviction
                # trusts the cache as authoritative, so a stale install here
                # would let it write back a view missing the new chunk
                if (self._chunks.get(user_id) is chunks
                        and len(chunks) == n0):
                    self._cache[user_id] = merged
        out = ev.time_slice(merged, t_lo + 1, t_hi)
        self.bytes_read += sum(v.nbytes for v in out.values())
        return out

    # -- retention ----------------------------------------------------------
    def evict_until(self, user_id: int, watermark_ts: int) -> None:
        """Drop events with timestamp <= watermark (already compacted into the
        immutable tier). Called after each compaction cycle.

        Reuses the write-through cache's merged view when valid (it is
        invalidated on every append, so a present entry IS the chunks' merge)
        instead of re-merging every chunk list on each cycle; the surviving
        suffix is written back so the next read is also merge-free."""
        with self._write_lock:
            chunks = self._chunks.get(user_id)
            if not chunks:
                return
            self._versions[user_id] = self._versions.get(user_id, 0) + 1
            merged = self._cache.get(user_id)
            if merged is None or ev.batch_len(merged) == 0:
                merged = ev.merge_sorted(chunks)
                self.evict_merges += 1
            else:
                self.evict_cache_hits += 1
            ts = merged["timestamp"]
            keep_from = int(np.searchsorted(ts, watermark_ts, side="right"))
            kept = ev.slice_batch(merged, keep_from, len(ts))
            if ev.batch_len(kept) == 0:
                self._chunks.pop(user_id, None)
                self._cache.pop(user_id, None)
            else:
                self._chunks[user_id] = [kept]
                self._cache[user_id] = kept

    def evict_all_until(self, watermark_ts: int) -> None:
        for uid in list(self._chunks.keys()):
            self.evict_until(uid, watermark_ts)

    def user_ids(self):
        return list(self._chunks.keys())

    def resident_events(self, user_id: int) -> int:
        return sum(ev.batch_len(c) for c in self._chunks.get(user_id, []))

    def version(self, user_id: int) -> int:
        """Monotone per-user write-state version (0 = never written). Equal
        versions imply an identical merged view; a bump means *something*
        changed and any derived cache entry must be recomputed."""
        return self._versions.get(user_id, 0)
