"""Mixture-of-Experts FFN (top-k routed + optional shared experts).

TPU-native design (see DESIGN.md §3): tokens are data-parallel, experts are
sharded over the ``model`` mesh axis. Inside ``shard_map`` each model-rank
  1. computes the (identical, replicated) routing for its local token block,
  2. gathers only the tokens routed to ITS experts via an index-based dispatch
     (sort + rank-in-expert; no (T, E, C) one-hot dispatch tensor is ever
     materialized — that is the GShard memory hog we deliberately avoid),
  3. runs the expert SwiGLU as a grouped (E_loc, C, d) einsum on the MXU,
  4. scatter-adds weighted expert outputs and psums over the model axis
     (one all-reduce per MoE layer — the Megatron-TP collective schedule).

Without a mesh the same inner function runs with all experts local (CPU tests).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map

from repro.models.layers import _init

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    n_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    router_dtype: Any = jnp.float32
    # expert-parallel weight layout:
    #   "fsdp": E on model, d_ff ZeRO-sharded on data, all-gathered per layer
    #           (best for training: weight traffic amortized over many tokens)
    #   "2d":   E on model AND d/f dims on data — weights fully resident, the
    #           only collectives are tiny activation psums (best for decode,
    #           where per-step FSDP all-gathers would dominate)
    ep_mode: str = "fsdp"


def init_moe(key, d_model: int, cfg: MoEConfig) -> Params:
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d_model, cfg.n_experts), scale=0.02),
        # fused gate+up per expert: (E, d, 2f); down: (E, f, d)
        "w_in": _init(ks[1], (cfg.n_experts, d_model, 2 * cfg.d_ff)),
        "w_out": _init(ks[2], (cfg.n_experts, cfg.d_ff, d_model),
                       scale=1.0 / np.sqrt(cfg.d_ff)),
    }
    if cfg.n_shared:
        p["shared_w_in"] = _init(ks[3], (d_model, 2 * cfg.n_shared * cfg.d_ff))
        p["shared_w_out"] = _init(
            ks[4], (cfg.n_shared * cfg.d_ff, d_model),
            scale=1.0 / np.sqrt(cfg.n_shared * cfg.d_ff),
        )
    return p


def _capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(np.ceil(cfg.top_k * n_tokens / cfg.n_experts * cfg.capacity_factor))
    return max(8, int(np.ceil(c / 8)) * 8)  # pad to sublane multiple


def _moe_inner(
    x: jax.Array,          # (T_loc, d) local token block (replicated over model)
    router_w: jax.Array,   # (d, E) replicated
    w_in: jax.Array,       # (E_loc, d, 2f) local expert shard
    w_out: jax.Array,      # (E_loc, f, d)
    cfg: MoEConfig,
    model_axis: Optional[str],
) -> jax.Array:
    t_loc, d = x.shape
    e_loc = w_in.shape[0]
    e = cfg.n_experts
    k = cfg.top_k
    dt = x.dtype
    cap = _capacity(t_loc, cfg)

    # 1) routing (identical on every model-rank: x and router_w are replicated)
    logits = (x.astype(cfg.router_dtype) @ router_w.astype(cfg.router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)                   # (T_loc, E)
    gate, idx = jax.lax.top_k(probs, k)                       # (T_loc, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # 2) index-based dispatch: rank of each (token, expert) pair within expert
    flat_e = idx.reshape(-1)                                  # (T_loc*k,)
    flat_t = jnp.repeat(jnp.arange(t_loc), k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)                  # group by expert
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    starts = jnp.searchsorted(se, jnp.arange(e))              # (E,)
    pos = jnp.arange(t_loc * k) - starts[se]                  # rank in expert

    offset = 0
    if model_axis is not None:
        offset = jax.lax.axis_index(model_axis) * e_loc
    local_e = se - offset
    keep = (local_e >= 0) & (local_e < e_loc) & (pos < cap)
    # dispatch tables (E_loc, cap): source token id and combine weight
    disp_t = jnp.full((e_loc, cap), t_loc, dtype=jnp.int32)   # t_loc = dummy row
    disp_g = jnp.zeros((e_loc, cap), dtype=cfg.router_dtype)
    le = jnp.where(keep, local_e, 0)
    lp = jnp.where(keep, pos, cap - 1)
    disp_t = disp_t.at[le, lp].set(
        jnp.where(keep, st.astype(jnp.int32), t_loc), mode="drop"
    )
    disp_g = disp_g.at[le, lp].set(jnp.where(keep, sg, 0.0), mode="drop")

    # 3) gather + grouped expert SwiGLU
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), dt)], axis=0)
    xe = x_pad[disp_t]                                        # (E_loc, cap, d)
    h = jnp.einsum("ecd,edf->ecf", xe, w_in.astype(dt))       # (E_loc, cap, 2f)
    g, u = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g) * u
    oe = jnp.einsum("ecf,efd->ecd", h, w_out.astype(dt))      # (E_loc, cap, d)

    # 4) weighted scatter-add back to tokens (+psum over experts' axis)
    oe = oe * disp_g[..., None].astype(dt)
    out = jnp.zeros((t_loc + 1, d), dt).at[disp_t.reshape(-1)].add(
        oe.reshape(-1, d), mode="drop"
    )[:t_loc]
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
    return out


def _moe_inner_2d(
    x: jax.Array,          # (T, d) FULL token block (replicated over data)
    router_w: jax.Array,   # (d, E)
    w_in: jax.Array,       # (E_loc, d_loc, 2f): E on model, d on data
    w_out: jax.Array,      # (E_loc, f_loc, d): E on model, f on data
    cfg: MoEConfig,
    model_axis: str,
    data_axis: Tuple[str, ...],
) -> jax.Array:
    """Fully-resident 2D expert sharding (decode path): contraction dims are
    data-sharded, so partial matmul products are psum'd (tiny at decode batch)
    and NO weight all-gather ever happens."""
    t, d = x.shape
    e_loc, d_loc, two_f = w_in.shape
    e, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    cap = _capacity(t, cfg)

    logits = x.astype(cfg.router_dtype) @ router_w.astype(cfg.router_dtype)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    starts = jnp.searchsorted(se, jnp.arange(e))
    pos = jnp.arange(t * k) - starts[se]
    offset = jax.lax.axis_index(model_axis) * e_loc
    local_e = se - offset
    keep = (local_e >= 0) & (local_e < e_loc) & (pos < cap)
    disp_t = jnp.full((e_loc, cap), t, dtype=jnp.int32)
    disp_g = jnp.zeros((e_loc, cap), dtype=cfg.router_dtype)
    le = jnp.where(keep, local_e, 0)
    lp = jnp.where(keep, pos, cap - 1)
    disp_t = disp_t.at[le, lp].set(jnp.where(keep, st.astype(jnp.int32), t),
                                   mode="drop")
    disp_g = disp_g.at[le, lp].set(jnp.where(keep, sg, 0.0), mode="drop")

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), dt)], axis=0)
    xe = x_pad[disp_t]                                   # (E_loc, cap, d)
    # slice the contraction dim to this data-rank's weight block
    d_rank = jax.lax.axis_index(data_axis[-1])
    if len(data_axis) > 1:
        d_rank = d_rank + jax.lax.axis_index(data_axis[0]) * \
            jax.lax.axis_size(data_axis[-1])
    xe_loc = jax.lax.dynamic_slice_in_dim(xe, d_rank * d_loc, d_loc, axis=2)
    h = jnp.einsum("ecd,edf->ecf", xe_loc, w_in.astype(dt))
    h = jax.lax.psum(h, data_axis)                       # (E_loc, cap, 2f)
    g, u = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g) * u
    f_loc = w_out.shape[1]
    h_loc = jax.lax.dynamic_slice_in_dim(h, d_rank * f_loc, f_loc, axis=2)
    oe = jnp.einsum("ecf,efd->ecd", h_loc, w_out.astype(dt))
    oe = oe * disp_g[..., None].astype(dt)
    out = jnp.zeros((t + 1, d), dt).at[disp_t.reshape(-1)].add(
        oe.reshape(-1, d), mode="drop")[:t]
    return jax.lax.psum(out, (model_axis,) + tuple(data_axis))


def moe_ffn(
    params: Params,
    x: jax.Array,                  # (B, S, d) or (T, d)
    cfg: MoEConfig,
    mesh: Optional[jax.sharding.Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
    model_axis: str = "model",
) -> jax.Array:
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    if mesh is None:
        out = _moe_inner(xt, params["router"], params["w_in"], params["w_out"],
                         cfg, None)
    elif cfg.ep_mode == "2d":
        P = jax.sharding.PartitionSpec
        wd = ("data",) if "data" in mesh.axis_names else ()
        inner = partial(_moe_inner_2d, cfg=cfg, model_axis=model_axis,
                        data_axis=wd)
        out = shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(None, None), P(None, None),
                      P(model_axis, wd, None), P(model_axis, wd, None)),
            out_specs=P(None, None),
            check_vma=False,
        )(xt, params["router"], params["w_in"], params["w_out"])
    else:
        P = jax.sharding.PartitionSpec
        dp = tuple(data_axes) if data_axes else None  # () -> replicated tokens
        inner = partial(_moe_inner, cfg=cfg, model_axis=model_axis)
        out = shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(dp, None), P(None, None),
                      P(model_axis, None, None), P(model_axis, None, None)),
            out_specs=P(dp, None),
            check_vma=False,
        )(xt, params["router"], params["w_in"], params["w_out"])
    if "shared_w_in" in params:
        dt = x.dtype
        h = xt @ params["shared_w_in"].astype(dt)
        g, u = jnp.split(h, 2, axis=-1)
        out = out + (jax.nn.silu(g) * u) @ params["shared_w_out"].astype(dt)
    return out.reshape(shape)


def moe_ref(params: Params, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Dense per-token oracle (no capacity drops) for tests: every token is
    processed by its exact top-k experts via full einsum over E."""
    shape = x.shape
    xt = x.reshape(-1, shape[-1])
    dt = x.dtype
    logits = xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    h = jnp.einsum("td,edf->tef", xt, params["w_in"].astype(dt))
    g, u = jnp.split(h, 2, axis=-1)
    o = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, params["w_out"].astype(dt))
    mask = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32)  # (T,k,E)
    w = jnp.einsum("tk,tke->te", gate, mask).astype(dt)
    out = jnp.einsum("te,ted->td", w, o)
    if "shared_w_in" in params:
        hs = xt @ params["shared_w_in"].astype(dt)
        gs, us = jnp.split(hs, 2, axis=-1)
        out = out + (jax.nn.silu(gs) * us) @ params["shared_w_out"].astype(dt)
    return out.reshape(shape)
