"""Resilience overhead: the fault-tolerant data plane under a seeded 1%-fault
FaultPlan vs the fault-free baseline (§10).

Three measurements over the same warehouse-replay feed (ordered placement,
self-healing workers):

  * ``chaos_clean``     — fault-free rows/s (the resilience machinery is on,
                          but nothing fires: its standing cost);
  * ``chaos_faulty_1pct`` — rows/s with ~1% of store scans failing (IOError /
                          decode corruption / worker crash mix), plus the
                          recovery counters and the mean recovery latency
                          (extra wall per injected fault);
  * ``chaos_equivalence`` — asserts the faulty run's batches are
                          byte-identical to the clean run's (the §10
                          guarantee this benchmark exists to price).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import BenchResult, standard_sim
from repro.core.projection import TenantProjection
from repro.data import DatasetSpec, WarehouseSource, open_feed
from repro.dpp.featurize import FeatureSpec
from repro.testing import FaultPlan, FaultSpec, wrap_sim

RATES = {"scan_ioerror": 0.004, "decode_corruption": 0.003,
         "worker_crash": 0.003}   # ~1% of scans fault in total


def _spec(seq_len: int) -> DatasetSpec:
    tenant = TenantProjection(
        "chaos", seq_len, ("core",),
        traits_per_group={"core": ("timestamp", "item_id", "action_type")})
    return DatasetSpec(
        tenant=tenant,
        source=WarehouseSource(),
        features=FeatureSpec(seq_len=seq_len,
                             uih_traits=("item_id", "action_type")),
        batch_size=32, base_batch_size=8, n_workers=2, prefetch_depth=0,
        window_cache_size=0,    # every item scans: the fault rate is honest
    )


def _run(spec, sim):
    feed = open_feed(spec, sim)
    t0 = time.perf_counter()
    batches = list(feed)
    feed.join()
    wall = time.perf_counter() - t0
    rows = sum(len(b["user_id"]) for b in batches)
    return batches, rows, wall, feed.stats()


def run(quick: bool = False):
    if quick:
        sim = standard_sim("vlm", users=8, days=2, req_per_day=3,
                           events_mean=20.0)
    else:
        sim = standard_sim("vlm")
    spec = _spec(32 if quick else 64)

    clean_batches, rows, wall_clean, _ = _run(spec, sim)

    if quick:
        # the tiny quick config has too few scans for a 1% rate to reliably
        # land a fault: pin two so the recovery path is still smoke-tested
        plan = FaultPlan([FaultSpec("worker_crash", 1),
                          FaultSpec("scan_ioerror", 3)])
    else:
        # seeded 1%-fault plan over a horizon above the scan count
        plan = FaultPlan.seeded(42, RATES,
                                max(64, rows // spec.base_batch_size * 4))
    faulty_batches, rows_f, wall_f, st = _run(spec, wrap_sim(sim, plan))

    identical = len(clean_batches) == len(faulty_batches) and all(
        all(np.array_equal(x[k], y[k]) for k in x)
        for x, y in zip(clean_batches, faulty_batches))
    assert identical, (
        "faulty run diverged from the fault-free run — the §10 byte-identical "
        "recovery guarantee is broken")
    n_faults = plan.n_fired
    recovery_ms = (max(0.0, wall_f - wall_clean) / n_faults * 1e3
                   if n_faults else 0.0)

    return [
        BenchResult("chaos_clean", wall_clean / max(rows, 1) * 1e6, {
            "rows": rows,
            "rows_per_s": round(rows / wall_clean, 1),
        }),
        BenchResult("chaos_faulty_1pct", wall_f / max(rows_f, 1) * 1e6, {
            "rows": rows_f,
            "rows_per_s": round(rows_f / wall_f, 1),
            "faults_injected": n_faults,
            "worker_restarts": st.workers.worker_restarts,
            "items_requeued": st.workers.items_requeued,
            "overhead_pct": round(100.0 * (wall_f - wall_clean)
                                  / max(wall_clean, 1e-9), 1),
            "mean_recovery_ms": round(recovery_ms, 2),
        }),
        BenchResult("chaos_equivalence", 0.0, {
            "byte_identical": bool(identical),
        }),
    ]


if __name__ == "__main__":
    for r in run():
        print(r.csv())
