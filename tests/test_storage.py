"""Storage tier: mutable blind-writes, immutable range scans, compaction
idempotence, right-to-delete, schema evolution, symmetric sharding."""
import numpy as np
import pytest

from repro.core import events as ev
from repro.storage import columnar
from repro.storage.compaction import (
    CompactionConfig,
    CompactionPipeline,
    make_scrub,
)
from repro.storage.immutable_store import ImmutableUIHStore, ScanRequest
from repro.storage.mutable_store import MutableUIHStore
from repro.storage.sharding import ShardRouter, shard_of

SCHEMA = ev.default_schema()


def _gen(users=4, days=5, seed=0):
    return ev.SyntheticEventStream(
        ev.StreamConfig(n_users=users, n_items=1_000, days=days,
                        events_per_user_day_mean=50.0, seed=seed),
        SCHEMA,
    )


def _build_store(gen, users, as_of_ts, stripe_len=16, scrub=None):
    store = ImmutableUIHStore(SCHEMA, n_shards=4)
    pipe = CompactionPipeline(SCHEMA, CompactionConfig(stripe_len=stripe_len))
    source = lambda uid, lo, hi: ev.time_slice(gen.history_until(uid, hi), lo, hi)
    report = pipe.run(source, list(range(users)), as_of_ts, store, scrub=scrub)
    return store, report


# -- mutable store -------------------------------------------------------------

def test_mutable_blind_write_merge_on_read():
    store = MutableUIHStore(SCHEMA)
    gen = _gen(users=1)
    batch = gen.day_events(0, 0)
    n = ev.batch_len(batch)
    assert n > 5
    # append shuffled chunks (out of order) — merge-on-read must sort
    rng = np.random.default_rng(0)
    perm = rng.permutation(n)
    for idx in np.array_split(perm, 4):
        store.append(0, ev.take_batch(batch, np.sort(idx)))
    view = store.read(0, -1, 10**18)
    np.testing.assert_array_equal(view["timestamp"], batch["timestamp"])
    np.testing.assert_array_equal(view["item_id"], batch["item_id"])


def test_mutable_read_respects_bounds():
    store = MutableUIHStore(SCHEMA)
    gen = _gen(users=1)
    batch = gen.day_events(0, 0)
    store.append(0, batch)
    ts = batch["timestamp"]
    mid = int(ts[len(ts) // 2])
    out = store.read(0, mid, 10**18)
    assert np.all(out["timestamp"] > mid)
    out2 = store.read(0, -1, mid)
    assert np.all(out2["timestamp"] <= mid)


def test_mutable_eviction_coupled_to_watermark():
    store = MutableUIHStore(SCHEMA)
    gen = _gen(users=1)
    b0, b1 = gen.day_events(0, 0), gen.day_events(0, 1)
    store.append(0, b0)
    store.append(0, b1)
    watermark = int(b0["timestamp"][-1])
    store.evict_until(0, watermark)
    view = store.read(0, -1, 10**18)
    assert np.all(view["timestamp"] > watermark)
    assert ev.batch_len(view) == ev.batch_len(b1)


# -- immutable store -------------------------------------------------------------

def test_range_scan_matches_source_of_truth():
    gen = _gen()
    as_of = 3 * ev.MS_PER_DAY
    store, _ = _build_store(gen, 4, as_of)
    for uid in range(4):
        truth = gen.history_until(uid, as_of)
        got = store.scan(ScanRequest(uid, "core", 0, as_of))
        np.testing.assert_array_equal(got["timestamp"], truth["timestamp"])
        np.testing.assert_array_equal(got["item_id"], truth["item_id"])


def test_bounded_scan_temporal_predicate():
    gen = _gen()
    as_of = 4 * ev.MS_PER_DAY
    store, _ = _build_store(gen, 2, as_of)
    truth = gen.history_until(0, as_of)
    ts = truth["timestamp"]
    lo, hi = int(ts[len(ts) // 4]), int(ts[3 * len(ts) // 4])
    got = store.scan(ScanRequest(0, "core", lo, hi))
    want = ev.time_slice(truth, lo, hi)
    np.testing.assert_array_equal(got["timestamp"], want["timestamp"])


def test_sequence_length_projection_reads_fewer_stripes():
    gen = _gen(users=1, days=6)
    as_of = 5 * ev.MS_PER_DAY
    store, _ = _build_store(gen, 1, as_of, stripe_len=8)
    truth = gen.history_until(0, as_of)
    n = ev.batch_len(truth)
    assert n > 64

    before = store.stats.snapshot()
    short = store.scan(ScanRequest(0, "core", 0, as_of, max_events=8))
    short_stats = store.stats.delta(before)

    before = store.stats.snapshot()
    full = store.scan(ScanRequest(0, "core", 0, as_of))
    full_stats = store.stats.delta(before)

    assert ev.batch_len(short) == 8
    np.testing.assert_array_equal(short["timestamp"], truth["timestamp"][-8:])
    assert short_stats.stripes_read < full_stats.stripes_read
    assert short_stats.bytes_scanned < full_stats.bytes_scanned


def test_feature_group_and_trait_projection():
    gen = _gen(users=1)
    as_of = 3 * ev.MS_PER_DAY
    store, _ = _build_store(gen, 1, as_of)
    got = store.scan(
        ScanRequest(0, "engagement", 0, as_of, traits=("timestamp", "like"))
    )
    assert set(got.keys()) == {"timestamp", "like"}
    truth = gen.history_until(0, as_of)
    np.testing.assert_array_equal(got["like"], truth["like"])


def test_single_seek_per_scan():
    gen = _gen(users=1, days=6)
    store, _ = _build_store(gen, 1, 5 * ev.MS_PER_DAY, stripe_len=8)
    before = store.stats.snapshot()
    store.scan(ScanRequest(0, "core", 0, 5 * ev.MS_PER_DAY))
    d = store.stats.delta(before)
    assert d.seeks == 1  # single-level layout: one seek then sequential I/O
    assert d.stripes_read > 1


# -- compaction ----------------------------------------------------------------

def test_compaction_idempotent():
    gen = _gen()
    as_of = 3 * ev.MS_PER_DAY
    s1, r1 = _build_store(gen, 4, as_of)
    s2, r2 = _build_store(gen, 4, as_of)
    assert r1.events == r2.events and r1.stripes == r2.stripes
    for uid in range(4):
        a = s1.scan(ScanRequest(uid, "core", 0, as_of))
        b = s2.scan(ScanRequest(uid, "core", 0, as_of))
        np.testing.assert_array_equal(a["timestamp"], b["timestamp"])


def test_right_to_delete_scrub():
    gen = _gen(users=2)
    as_of = 3 * ev.MS_PER_DAY
    truth = gen.history_until(0, as_of)
    victim = int(truth["item_id"][0])
    store, report = _build_store(
        gen, 2, as_of, scrub=make_scrub(deleted_items=[victim])
    )
    assert report.scrubbed_events > 0
    got = store.scan(ScanRequest(0, "core", 0, as_of))
    assert victim not in got["item_id"]


def test_scrub_is_idempotent_across_generations():
    gen = _gen(users=2)
    as_of = 3 * ev.MS_PER_DAY
    truth = gen.history_until(0, as_of)
    victim = int(truth["item_id"][0])
    scrub = make_scrub(deleted_items=[victim])
    store = ImmutableUIHStore(SCHEMA, n_shards=4)
    pipe = CompactionPipeline(SCHEMA, CompactionConfig(stripe_len=16))
    source = lambda uid, lo, hi: ev.time_slice(gen.history_until(uid, hi), lo, hi)
    pipe.run(source, [0, 1], as_of, store, scrub=scrub)
    first = store.scan(ScanRequest(0, "core", 0, as_of))
    pipe.run(source, [0, 1], as_of, store, scrub=scrub)  # re-run: same result
    second = store.scan(ScanRequest(0, "core", 0, as_of))
    np.testing.assert_array_equal(first["timestamp"], second["timestamp"])
    assert store.generation == 1


def test_schema_evolution_single_run():
    """Adding a SideInfo trait only requires one compaction run (§4.3)."""
    gen = _gen(users=2)
    as_of = 3 * ev.MS_PER_DAY
    new_trait = ev.TraitSpec("is_weekend", np.dtype(np.int8), ev.SPARSE_FLAG)
    evolved = SCHEMA.with_traits(
        add=[new_trait],
        feature_groups={**{g: c for g, c in SCHEMA.feature_groups.items()},
                        "sideinfo": SCHEMA.feature_groups["sideinfo"] + ("is_weekend",)},
    )

    def source(uid, lo, hi):
        h = ev.time_slice(gen.history_until(uid, hi), lo, hi)
        day_of_week = (h["timestamp"] // ev.MS_PER_DAY) % 7
        h["is_weekend"] = (day_of_week >= 5).astype(np.int8)
        return h

    store = ImmutableUIHStore(evolved, n_shards=2)
    pipe = CompactionPipeline(evolved, CompactionConfig(stripe_len=16))
    pipe.run(source, [0, 1], as_of, store)
    got = store.scan(ScanRequest(0, "sideinfo", 0, as_of))
    assert "is_weekend" in got
    # deprecating works the same way
    shrunk = evolved.with_traits(drop=["surface"])
    store2 = ImmutableUIHStore(shrunk, n_shards=2)
    pipe2 = CompactionPipeline(shrunk, CompactionConfig(stripe_len=16))

    def source2(uid, lo, hi):
        h = source(uid, lo, hi)
        h.pop("surface")
        return h

    pipe2.run(source2, [0, 1], as_of, store2)
    got2 = store2.scan(ScanRequest(0, "sideinfo", 0, as_of))
    assert "surface" not in got2 and "is_weekend" in got2


# -- symmetric sharding -----------------------------------------------------------

def test_shard_router_stable_and_uniform():
    r = ShardRouter(8)
    ids = np.arange(10_000)
    shards = np.array([r.route(int(u)) for u in ids])
    counts = np.bincount(shards, minlength=8)
    assert counts.min() > 0.7 * counts.mean()
    assert shard_of(12345, 8) == shard_of(12345, 8)


def test_shard_of_golden_values():
    """The hash placement is LOAD-BEARING persistent state: warehouse buckets
    and store shards are bucketed with it, so a silent drift (new mix
    constants, int-width change) would invalidate symmetric bucketing of
    every already-written generation. Golden values pin it forever."""
    golden = {
        0: [0, 0, 0, 0, 0],
        1: [0, 1, 1, 1, 9],
        2: [0, 0, 2, 2, 10],
        7: [0, 0, 2, 6, 6],
        42: [0, 1, 1, 5, 13],
        999_983: [0, 0, 0, 0, 0],
        123_456_789: [0, 0, 2, 6, 14],
        2**31 - 1: [0, 1, 3, 3, 11],
        2**63 - 1: [0, 1, 3, 7, 7],
    }
    for user_id, want in golden.items():
        got = [shard_of(user_id, n) for n in (1, 2, 4, 8, 16)]
        assert got == want, f"shard_of({user_id}) drifted: {got} != {want}"


def test_symmetric_sharding_zero_fanout_for_bucketed_batch():
    """A user-bucketed batch touches exactly one immutable shard (§4.2.3)."""
    n_shards = 8
    r = ShardRouter(n_shards)
    users = [u for u in range(200) if r.route(u) == 3][:16]
    store = ImmutableUIHStore(SCHEMA, n_shards=n_shards)
    reqs = [ScanRequest(u, "core", 0, 10**12) for u in users]
    assert store.fanout(reqs) == 1
