"""Disaggregated immutable tier (DESIGN.md §11): ShardedUIHStore vs monolith.

Covers the PR's acceptance spine:
  * interchangeability — the SAME materialize / snapshot / co-scan / lease
    scenarios that tier-1 runs on the monolith produce byte-identical output
    on a 4-node ``ShardedUIHStore`` (including the PR 3 generation-flip audit
    stress and the PR 5 kill-and-resume acceptance);
  * epoch-barrier generation flips — a lease pins ONE consistent generation
    on every node, even with bulk loads racing lease acquisition;
  * length-aware placement — heavy-tail overrides cut max/mean node skew vs
    pure hashing, the map rides generation metadata (pinned scans route with
    the generation that placed them, across a rebalance), and
    ``plan_affine`` keeps DPP work items node-local (zero cross-node fanout);
  * fault surface — a down node fails scans with the retryable
    ``NodeUnavailable`` while leases/metadata stay up and nothing leaks.
"""
import threading
import time

import numpy as np
import pytest

from conftest import make_sim
from repro.core import events as ev
from repro.core.consistency import audit
from repro.core.materialize import Materializer, TenantShareStats
from repro.core.projection import TenantProjection
from repro.data import DatasetSpec, WarehouseSource, open_feed
from repro.dpp.affinity import plan_affine
from repro.dpp.featurize import FeatureSpec
from repro.storage.compaction import CompactionConfig, CompactionPipeline
from repro.storage.immutable_store import GenerationUnavailable, ScanRequest
from repro.storage.protocol import StoreProtocol
from repro.storage.sharded_store import (
    NodeUnavailable,
    ShardedUIHStore,
    StoreNode,
)
from repro.storage.sharding import PlacementMap, shard_of

SCHEMA = ev.default_schema()

TENANT = TenantProjection(
    "t", 16, ("core",),
    traits_per_group={"core": ("timestamp", "item_id", "action_type")})
FEATURES = FeatureSpec(seq_len=16, uih_traits=("item_id", "action_type"))


def _views_equal(a, b, ctx=""):
    assert set(a.keys()) == set(b.keys()), ctx
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{ctx} trait {k}")


# ---------------------------------------------------------------------------
# synthetic heavy-tailed population (for placement tests)
# ---------------------------------------------------------------------------

def _user_events(uid: int, n: int) -> ev.EventBatch:
    rng = np.random.default_rng(uid + 1)
    batch = {}
    for name in SCHEMA.trait_names:
        dt = SCHEMA.spec(name).dtype
        batch[name] = rng.integers(0, 100, n).astype(dt)
    batch["timestamp"] = np.sort(
        rng.integers(0, 900_000, n)).astype(np.int64)
    return batch


def _load_skewed(store, heavy_users=(3, 11, 19, 27), torso_n=30,
                 heavy_n=3_000, n_users=64, generation=None):
    """One compacted generation over a heavy-tailed population: a few users
    carry ~100x the torso's bytes (the FlexShard setting)."""
    events = {u: _user_events(u, heavy_n if u in heavy_users else torso_n)
              for u in range(n_users)}
    pipe = CompactionPipeline(SCHEMA, CompactionConfig(stripe_len=64))
    source = lambda uid, lo, hi: ev.time_slice(events[uid], lo, hi)
    pipe.run(source, list(range(n_users)), 1_000_000, store,
             generation=generation)
    return events


# ---------------------------------------------------------------------------
# interchangeability: monolith scenarios, byte-identical on 4 nodes
# ---------------------------------------------------------------------------

def test_sharded_store_satisfies_protocol():
    store = ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=4)
    assert isinstance(store, StoreProtocol)
    store.close()


@pytest.mark.parametrize("mode", ["vlm", "fatrow"])
def test_materialize_byte_identical_to_monolith(mode):
    mono = make_sim(users=8, days=2, seed=21, mode=mode)
    shard = make_sim(users=8, days=2, seed=21, mode=mode, nodes=4)
    assert len(mono.examples) == len(shard.examples)

    want = mono.materializer().materialize_batch(mono.examples, TENANT)
    mat = shard.materializer()
    got = mat.materialize_batch(shard.examples, TENANT)
    for i, (a, b) in enumerate(zip(want, got)):
        _views_equal(a, b, f"example {i}")
    if mode == "vlm":
        # the planned path really ran on the client: one co-planned round per
        # materialize_batch window group, with client-side dedupe
        assert mat.io_stats.batched_requests >= 1
        assert mat.io_stats.dedup_hits > 0
        # and more than one node did physical work
        ns = shard.immutable.node_stats()
        assert sum(1 for b_ in ns.scan_load if b_ > 0) > 1


def test_audit_clean_on_four_nodes():
    sim = make_sim(users=8, days=2, seed=7, nodes=4)
    mat = sim.materializer(validate_checksum=True)
    report = audit(sim.examples, sim.references, mat, sim.schema, TENANT)
    assert report.clean
    assert report.examples == len(sim.examples)


def test_coscan_on_sharded_matches_solo():
    sim = make_sim(users=6, days=2, seed=13, nodes=4)
    tenants = [
        TenantProjection("wide", 12, ("core", "engagement"),
                         traits_per_group={
                             "core": ("timestamp", "item_id", "action_type"),
                             "engagement": ("like", "watch_time_ms")}),
        TenantProjection("narrow", 6, ("core",),
                         traits_per_group={"core": ("timestamp", "item_id")}),
    ]
    multi = Materializer(sim.immutable, sim.schema)
    solos = {t.name: Materializer(sim.immutable, sim.schema) for t in tenants}
    share = TenantShareStats()
    for lo in range(0, len(sim.examples), 8):
        batch = sim.examples[lo:lo + 8]
        got = multi.materialize_multi(batch, tenants, share_stats=share)
        for t in tenants:
            want = solos[t.name].materialize_batch(batch, t)
            for i, (a, b) in enumerate(zip(want, got[t.name])):
                _views_equal(a, b, f"{t.name} {lo + i}")
    assert share.co_scan_windows > 0
    assert share.bytes_saved_vs_solo > 0


def test_generation_flip_audit_stress_on_sharded():
    """The PR 3 adversarial lease scenario on 4 nodes: compaction churns
    fresh generation ids at the established watermark WHILE pinned
    materialization replays the stream backlog — audit stays clean, leases
    drain, retained generations GC."""
    sim = make_sim(users=6, days=2, seed=13, pin=True, nodes=4)
    assert sim.stream.pending_leases() > 0
    gen_start = sim.immutable.generation
    stop = threading.Event()
    flips = [0]
    wm = sim.compaction_watermark

    def churn():
        while not stop.is_set() or flips[0] < 2:
            sim.run_compaction(wm, evict=False)
            flips[0] += 1
            time.sleep(0.003)

    th = threading.Thread(target=churn, daemon=True)
    th.start()
    try:
        mat = sim.materializer(validate_checksum=True, pin_generations=True)
        report = audit(sim.examples, sim.references, mat, sim.schema, TENANT)
        assert report.clean, report
        assert mat.stats.stale_failures == 0
    finally:
        stop.set()
        th.join()
    assert flips[0] >= 2
    assert sim.immutable.generation - gen_start >= 2


def test_kill_and_resume_batch_on_sharded(tmp_path):
    """PR 5 exactly-once acceptance, immutable tier on 4 nodes: kill the
    trainer mid-run, resume from the checkpoint's feed cursor, and the replay
    is byte-identical to the uninterrupted run."""
    from repro.train.train_loop import Trainer, TrainerConfig
    import jax.numpy as jnp

    sim = make_sim(users=6, days=2, seed=6, capture_reference=False, nodes=4)
    spec = DatasetSpec(tenant=TENANT, source=WarehouseSource(),
                       features=FEATURES, batch_size=8, base_batch_size=4,
                       n_workers=2, prefetch_depth=0, reshuffle_seed=3)
    clean_feed = open_feed(spec, sim)
    uninterrupted = list(clean_feed)
    clean_feed.join()
    n_batches = len(uninterrupted)
    assert n_batches >= 4

    def loss_fn(params, b):
        score = jnp.sum(b["uih_item_id"] * params["w"], axis=1)
        return jnp.mean((score - b["label_click"]) ** 2)

    params = {"w": jnp.zeros((16,), jnp.float32)}
    cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2, log_every=10**6)
    recorded1 = []
    t1 = Trainer(loss_fn, params, cfg)
    feed1 = open_feed(spec, sim,
                      prep_fn=lambda b: (recorded1.append(b), b)[1])
    t1.fit(feed1, max_steps=n_batches - 2)
    feed1.close(timeout=30.0)

    t2 = Trainer(loss_fn, params, cfg)
    assert t2.try_resume()
    restored_step = t2.step
    feed_state = t2.ckpt.feed_state(restored_step)
    assert feed_state is not None
    recorded2 = []
    feed2 = open_feed(spec, sim, resume_from=feed_state,
                      prep_fn=lambda b: (recorded2.append(b), b)[1])
    t2.fit(feed2)
    feed2.close(timeout=30.0)

    replay = recorded1[:restored_step] + recorded2
    assert len(replay) == len(uninterrupted)
    for i, (a, b) in enumerate(zip(uninterrupted, replay)):
        _views_equal(a, b, f"batch {i}")


# ---------------------------------------------------------------------------
# epoch barrier + lease consistency
# ---------------------------------------------------------------------------

def test_lease_pins_consistent_generation_on_every_node():
    store = ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=4)
    _load_skewed(store, generation=0)
    with store.acquire_lease() as lease:
        assert lease.generation == 0
        assert all(n.has_generation(0) for n in store.nodes)
        _load_skewed(store, generation=1)        # flip under the lease
        # superseded generation stays retained on EVERY node...
        assert all(n.has_generation(0) for n in store.nodes)
        assert store.leased_generations() == {0: 1}
        # ...and pinned scans on it still work
        got = store.scan(ScanRequest(3, "core", 0, 10**9, generation=0))
        assert ev.batch_len(got) > 0
    # release drains retention everywhere
    assert store.leased_generations() == {}
    assert all(not n.has_generation(0) for n in store.nodes)
    with pytest.raises(GenerationUnavailable):
        store.scan(ScanRequest(3, "core", 0, 10**9, generation=0))
    store.close()


def test_epoch_barrier_under_concurrent_flips():
    """Race bulk loads against lease acquisition from many threads: every
    lease must name a generation that is retained on ALL nodes for the
    lease's whole lifetime (the barrier property), and nothing leaks."""
    store = ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=4)
    events = _load_skewed(store, generation=0)
    stop = threading.Event()
    errors = []
    flips = [0]

    def flipper():
        g = 1
        pipe = CompactionPipeline(SCHEMA, CompactionConfig(stripe_len=64))
        source = lambda uid, lo, hi: ev.time_slice(events[uid], lo, hi)
        while not stop.is_set():
            pipe.run(source, list(events), 1_000_000, store, generation=g)
            flips[0] += 1
            g += 1

    def leaser():
        try:
            # keep leasing until the flipper has raced us several times
            rounds = 0
            while rounds < 40 or flips[0] < 3:
                rounds += 1
                with store.acquire_lease() as lease:
                    for node in store.nodes:
                        assert node.has_generation(lease.generation), \
                            (node.node_id, lease.generation)
                    # a scan pinned to the leased generation never misses
                    store.scan(ScanRequest(3, "core", 0, 10**9,
                                           generation=lease.generation))
        except Exception as e:   # noqa: BLE001 - collected for the assert
            errors.append(e)

    th_flip = threading.Thread(target=flipper, daemon=True)
    leasers = [threading.Thread(target=leaser, daemon=True) for _ in range(4)]
    th_flip.start()
    for t in leasers:
        t.start()
    for t in leasers:
        t.join()
    stop.set()
    th_flip.join()
    assert not errors, errors
    assert flips[0] >= 3            # the race really happened
    assert store.leased_generations() == {}
    assert store.retained_generations() == []   # nothing outlives its lease
    store.close()


def test_bulk_load_of_leased_generation_id_rejected_atomically():
    store = ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=4)
    _load_skewed(store, generation=5)
    lease = store.acquire_lease()
    with pytest.raises(ValueError, match="leased"):
        _load_skewed(store, generation=5)
    # the rejected load touched NO node: all still on generation 5 content
    assert store.generation == 5
    assert all(n.generation == 5 for n in store.nodes)
    lease.release()
    store.close()


# ---------------------------------------------------------------------------
# length-aware placement
# ---------------------------------------------------------------------------

def test_length_aware_placement_cuts_node_skew():
    hash_store = ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=4,
                                 placement_policy="hash")
    la_store = ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=4,
                               placement_policy="length_aware")
    # pick heavy users that hash-collide onto ONE node => guaranteed hot spot
    heavy = [u for u in range(200)
             if shard_of(u, 8) % 4 == 1][:4]
    _load_skewed(hash_store, heavy_users=tuple(heavy), generation=0)
    _load_skewed(la_store, heavy_users=tuple(heavy), generation=0)

    skew_hash = hash_store.node_stats().max_mean_stored_ratio
    skew_la = la_store.node_stats().max_mean_stored_ratio
    assert skew_la < skew_hash          # the acceptance inequality
    assert skew_la < 1.5 < skew_hash    # and decisively so
    # heavy users actually got explicit override placements
    overrides = la_store.live_placement().overrides
    assert set(heavy) <= set(overrides)
    # byte-equality: placement moves bytes, never changes them
    for u in (heavy[0], 5):
        a = hash_store.scan(ScanRequest(u, "core", 0, 10**9))
        b = la_store.scan(ScanRequest(u, "core", 0, 10**9))
        _views_equal(a, b, f"user {u}")
    hash_store.close()
    la_store.close()


def test_placement_map_is_generation_metadata_across_rebalance():
    """A pinned scan must route with the placement of the generation it
    pins — not today's map — or the bytes are simply not there."""
    store = ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=4)
    heavy = [u for u in range(200) if shard_of(u, 8) % 4 == 1][:4]
    events = _load_skewed(store, heavy_users=tuple(heavy), generation=0)
    gen0_map = store.live_placement()
    # a heavy user whose explicit placement MOVED it off its hash node: the
    # pinned-routing property below is then non-vacuous
    hash_node = lambda u: PlacementMap(4, 8, {}).node_of(u)
    target = next(u for u in heavy
                  if gen0_map.overrides[u] != hash_node(u))
    lease = store.acquire_lease()     # pin generation 0
    want = store.scan(ScanRequest(target, "core", 0, 10**9, generation=0))

    # next flip: torso-only load (the heavy users churned away) + rebalance
    # => generation 1 places `target` by hash again (no override)
    store.rebalance()
    torso_events = {u: events[u] for u in range(64) if u not in heavy}
    pipe = CompactionPipeline(SCHEMA, CompactionConfig(stripe_len=64))
    pipe.run(lambda uid, lo, hi: ev.time_slice(torso_events[uid], lo, hi),
             list(torso_events), 1_000_000, store, generation=1)
    assert store.live_placement().overrides.get(target) is None
    assert store.placement_for(0).node_of(target) == gen0_map.node_of(target)

    # pinned scan still routes with generation 0's map, byte-exact
    got = store.scan(ScanRequest(target, "core", 0, 10**9, generation=0))
    _views_equal(want, got, "pinned scan across rebalance")
    lease.release()
    # after the last release the superseded generation AND its map are GC'd
    assert 0 not in store._placements
    store.close()


def test_plan_affine_items_stay_node_local_with_overrides():
    """With heavy-tail overrides in play the (node, shard) tag — not the bare
    shard — is the clustering key: every work item still lands on exactly one
    store node (zero cross-node fanout), and the plan partitions the input."""
    rng = np.random.default_rng(3)
    placement = PlacementMap(
        4, 8, {7: 2, 11: 0, 42: 1})   # overrides off their hash nodes
    from repro.core.versioning import TrainingExample
    examples = [
        TrainingExample(request_id=i, user_id=int(rng.integers(0, 48)),
                        request_ts=int(rng.integers(0, 10_000)), label_ts=0,
                        candidate={"item_id": 0}, labels={"click": 0.0})
        for i in range(120)
    ]
    plan = plan_affine(examples, 8, 6, placement=placement)
    assert plan.expected_node_fanout == 1.0
    for item in plan.items:
        assert len({placement.node_of(e.user_id) for e in item}) == 1
        assert len({shard_of(e.user_id, 8) for e in item}) == 1
    got = sorted(e.request_id for item in plan.items for e in item)
    assert got == list(range(120))
    # permutation invariance survives the placement-aware sort key
    shuffled = [examples[i] for i in rng.permutation(len(examples))]
    plan2 = plan_affine(shuffled, 8, 6, placement=placement)
    assert [[e.request_id for e in it] for it in plan.items] == \
           [[e.request_id for e in it] for it in plan2.items]


def test_sharded_plan_keeps_dedup_and_subsumption():
    """Client-side planning preserves the co-scan machinery: duplicate
    requests dedupe, narrower windows are carved from wider in-plan roots,
    and only the roots cross the 'network' to the nodes."""
    store = ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=4)
    _load_skewed(store, generation=0)
    users = [1, 2, 3, 4]
    reqs = []
    for u in users:
        reqs.append(ScanRequest(u, "core", 0, 10**9))
        reqs.append(ScanRequest(u, "core", 0, 10**9))          # duplicate
        reqs.append(ScanRequest(u, "core", 0, 10**9, max_events=4))  # subsumed
    plan = store.plan(reqs)
    assert plan.dedup_hits == len(users)
    assert plan.subsumed == len(users)
    # shard_groups keys are NODE ids; only roots are dispatched
    n_dispatched = sum(len(v) for v in plan.shard_groups.values())
    assert n_dispatched == len(users)
    assert set(plan.shard_groups) <= set(range(store.n_nodes))

    out = store.execute_plan(plan)
    assert len(out) == len(reqs)
    for i, req in enumerate(reqs):
        solo = store.nodes[store._node_of(req.user_id)].scan(req)
        _views_equal(solo, out[i], f"req {i}")
    agg = store.stats
    assert agg.dedup_hits == len(users)
    assert agg.subsumed_hits == len(users)
    assert agg.batched_requests == 1
    store.close()


# ---------------------------------------------------------------------------
# node outage
# ---------------------------------------------------------------------------

def test_down_node_scans_fail_retryable_and_recover():
    store = ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=4)
    _load_skewed(store, generation=0)
    # find a user on node 2 under the live placement
    victim = next(u for u in range(64) if store._node_of(u) == 2)
    bystander = next(u for u in range(64) if store._node_of(u) == 0)
    store.set_node_down(2)
    with pytest.raises(NodeUnavailable):
        store.scan(ScanRequest(victim, "core", 0, 10**9))
    with pytest.raises(NodeUnavailable):
        store.multi_range_scan([ScanRequest(victim, "core", 0, 10**9)])
    # NodeUnavailable is retryable I/O, NOT a remediation signal
    assert not isinstance(NodeUnavailable("x"), KeyError)
    # other nodes keep serving; leases/metadata stay up through the outage
    assert ev.batch_len(store.scan(ScanRequest(bystander, "core", 0, 10**9))) > 0
    assert store.watermark(victim) > 0
    with store.acquire_lease() as lease:
        assert lease.generation == 0
    assert store.leased_generations() == {}
    store.set_node_down(2, down=False)
    assert ev.batch_len(store.scan(ScanRequest(victim, "core", 0, 10**9))) > 0
    store.close()


def test_store_node_is_a_full_store():
    """A StoreNode alone satisfies the protocol (it IS the monolith plus an
    identity): the client composes nodes, it doesn't special-case them."""
    node = StoreNode(0, SCHEMA, n_shards=2)
    assert isinstance(node, StoreProtocol)
    assert node.live_placement() is None
    assert node.node_id == 0
    node.close()
