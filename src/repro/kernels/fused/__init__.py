"""Fused device-side late materialization (decode -> densify -> embed)."""
from repro.kernels.fused.ops import (  # noqa: F401
    fused_densify,
    late_materialize,
    pack_arena,
    unpack_dense,
)
