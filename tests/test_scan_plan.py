"""Planned multi-range scan: dedupe, shard-parallel execution, decode cache,
and the batched materialization path's byte-for-byte equivalence with the
per-example path (O2O stays clean)."""
import numpy as np
import pytest

from repro.core import events as ev
from repro.core.consistency import audit, batches_equal
from repro.core.materialize import Materializer
from repro.core.projection import TenantProjection, table1_tenants
from repro.storage import columnar
from repro.storage.immutable_store import ImmutableUIHStore, ScanRequest

SCHEMA = ev.default_schema()


@pytest.fixture(scope="module")
def sim(planned_sim):
    # the shared module-scoped heavy sim (tests/conftest.py)
    return planned_sim


PROJ = TenantProjection("t", seq_len=64, feature_groups=("core",),
                        traits_per_group={"core": ("timestamp", "item_id")})


# -- store-level planner ------------------------------------------------------

def test_plan_dedupes_and_groups_by_shard(sim):
    store = sim.immutable
    reqs = [ScanRequest(u, "core", 0, 10**12) for u in range(6)]
    dup = reqs + reqs  # duplicate-heavy batch
    plan = store.plan(dup)
    assert len(plan.unique) == 6
    assert plan.dedup_hits == 6
    assert plan.assignment == list(range(6)) * 2
    assert sum(len(g) for g in plan.shard_groups.values()) == 6
    assert plan.fanout == len({store.router.route(u) for u in range(6)})


def test_execute_plan_matches_serial_scans(sim):
    store = sim.immutable
    reqs = [ScanRequest(u, g, 0, 10**12)
            for u in range(6) for g in ("core", "engagement")]
    got = store.multi_range_scan(reqs + reqs)
    want = [store.scan(r) for r in reqs]
    assert len(got) == 2 * len(want)
    for a, b in zip(got, want + want):
        assert batches_equal(a, b)


def test_batched_scan_counters(sim):
    store = sim.immutable
    reqs = [ScanRequest(u, "core", 0, 10**12) for u in range(6)]
    before = store.stats.snapshot()
    store.multi_range_scan(reqs * 3)
    d = store.stats.delta(before)
    assert d.requests == 6            # post-dedupe executions only
    assert d.dedup_hits == 12
    assert d.parallel_shards == len({store.router.route(u) for u in range(6)})
    assert d.batched_requests == 1


def test_decode_cache_hits_on_overlapping_windows(sim):
    store = sim.immutable
    assert store.decode_cache is not None
    store.decode_cache.clear()
    req = ScanRequest(0, "core", 0, 10**12)
    before = store.stats.snapshot()
    first = store.scan(req)
    d1 = store.stats.delta(before)
    assert ev.batch_len(first) > 0 and d1.bytes_decoded > 0
    # same stripes, different (non-identical) request -> decode LRU hits
    before = store.stats.snapshot()
    again = store.scan(ScanRequest(0, "core", 1, 10**12))
    d2 = store.stats.delta(before)
    assert d2.decode_cache_hits == d2.stripes_read > 0
    assert d2.bytes_decoded == 0
    np.testing.assert_array_equal(first["item_id"][-ev.batch_len(again):],
                                  again["item_id"])


def test_decode_cache_lru_bound_and_identity():
    cache = columnar.StripeDecodeCache(max_entries=2)
    blobs = []
    for seed in range(3):
        rng = np.random.default_rng(seed)
        n = 32
        batch = {
            "timestamp": np.sort(rng.integers(0, 10**9, n)).astype(np.int64),
            "item_id": rng.integers(0, 1000, n).astype(np.int64),
        }
        blobs.append(columnar.encode_stripe(batch, SCHEMA))
    traits = ("timestamp", "item_id")
    a, hit = cache.get(blobs[0], SCHEMA, traits)
    assert not hit
    _, hit = cache.get(blobs[0], SCHEMA, traits)
    assert hit
    cache.get(blobs[1], SCHEMA, traits)
    cache.get(blobs[0], SCHEMA, traits)   # promote 0 over 1
    cache.get(blobs[2], SCHEMA, traits)   # evicts 1 (LRU), not 0
    _, hit = cache.get(blobs[0], SCHEMA, traits)
    assert hit
    _, hit = cache.get(blobs[1], SCHEMA, traits)
    assert not hit
    # cached arrays are frozen: in-place mutation must fail loudly
    with pytest.raises(ValueError):
        a["item_id"][0] = -1


def test_latency_model_charged_per_shard(sim):
    """Shard groups run concurrently: a constant per-shard delay costs ~max,
    not the sum over shards."""
    import time

    store = sim.immutable
    users = list(range(8))
    fanout = len({store.router.route(u) for u in users})
    assert fanout > 1
    delay = 0.05
    store.latency_model = lambda seeks, nbytes, f: delay
    try:
        t0 = time.perf_counter()
        store.multi_range_scan([ScanRequest(u, "core", 0, 10**12) for u in users])
        wall = time.perf_counter() - t0
    finally:
        store.latency_model = None
    assert wall < delay * fanout  # parallel shards overlap their latency


# -- materializer batch path --------------------------------------------------

def test_batched_materialization_identical_to_per_example(sim):
    for projection in (None, PROJ, *table1_tenants(256, 64, 8).values()):
        mat_a = sim.materializer()
        mat_b = sim.materializer()
        per_example = [mat_a.materialize(e, projection) for e in sim.examples]
        planned = mat_b.materialize_batch(sim.examples, projection)
        assert len(per_example) == len(planned)
        for a, b in zip(per_example, planned):
            assert batches_equal(a, b)


def test_batched_audit_stays_o2o_clean(sim):
    report = audit(sim.examples, sim.references, sim.materializer(),
                   sim.schema, batched=True)
    assert report.examples == len(sim.examples) > 0
    assert report.o2o_mismatches == 0
    assert report.leaked_events == 0


def test_batched_path_dedupes_same_user_windows(sim):
    """A duplicate-heavy (same-user, same-day) batch executes one scan per
    unique window x group; the plan's twins surface as dedup_hits."""
    ex = next(e for e in sim.examples if e.version and e.version.seq_len > 0)
    batch = [ex] * 5
    mat = sim.materializer(validate_checksum=False)
    before = sim.immutable.stats.snapshot()
    outs = mat.materialize_batch(batch, PROJ)
    d = sim.immutable.stats.delta(before)
    n_groups = len(PROJ.feature_groups)
    assert d.requests == n_groups              # one execution per group
    assert d.dedup_hits == 4 * n_groups        # the other 4 examples
    assert d.batched_requests == 1             # single store round-trip
    for o in outs:
        assert batches_equal(o, outs[0])


def test_window_cache_lru_promotes_on_hit(sim):
    users = {e.user_id for e in sim.examples if e.version}
    a, b, c = [next(e for e in sim.examples
                    if e.version and e.user_id == u) for u in list(users)[:3]]
    mat = sim.materializer(validate_checksum=False)
    mat.window_cache_size = 2
    mat.materialize_batch([a], PROJ)
    mat.materialize_batch([b], PROJ)
    mat.materialize_batch([a], PROJ)   # hit: promote a over b
    assert mat.stats.window_cache_hits == 1
    mat.materialize_batch([c], PROJ)   # evicts b (LRU), not a
    before = sim.immutable.stats.snapshot()
    mat.materialize_batch([a], PROJ)   # still cached -> no store traffic
    assert sim.immutable.stats.delta(before).requests == 0
    assert mat.stats.window_cache_hits == 2
    before = sim.immutable.stats.snapshot()
    mat.materialize_batch([b], PROJ)   # evicted -> refetched
    assert sim.immutable.stats.delta(before).requests > 0


def test_worker_surfaces_plan_counters(sim):
    """WorkerStats reports the planned-scan savings of ITS materializer's
    lookups (not global store traffic)."""
    from repro.dpp.featurize import FeatureSpec
    from repro.dpp.worker import DPPWorker

    spec = FeatureSpec(seq_len=64, uih_traits=("item_id", "timestamp"))
    worker = DPPWorker(sim.materializer(validate_checksum=False), PROJ, spec,
                       sim.schema)
    ex = next(e for e in sim.examples if e.version and e.version.seq_len > 0)
    worker.process([ex] * 4 + sim.examples[:8])
    assert worker.stats.dedup_hits >= 3 * len(PROJ.feature_groups)
    assert worker.stats.parallel_shards >= 1
    # another worker's traffic must not leak into this worker's counters
    other = DPPWorker(sim.materializer(validate_checksum=False), PROJ, spec,
                      sim.schema)
    before = worker.stats.dedup_hits
    other.process(sim.examples[:8])
    assert worker.stats.dedup_hits == before


def test_mixed_fat_and_vlm_batch(sim):
    """Fat Row + VLM examples in one batch keep their positions."""
    from repro.core.snapshot import FatRowSnapshotter

    fat_snap = FatRowSnapshotter(sim.mutable, sim.immutable, sim.schema)
    fat_ex = fat_snap.snapshot(sim.examples[0].user_id,
                               sim.examples[0].request_ts, {"item_id": 1},
                               {"click": 0.0})
    mat = sim.materializer(validate_checksum=False)
    batch = [sim.examples[0], fat_ex, sim.examples[1]]
    outs = mat.materialize_batch(batch, PROJ)
    assert batches_equal(outs[0], mat.materialize(sim.examples[0], PROJ))
    assert batches_equal(outs[1], mat.materialize(fat_ex, PROJ))
    assert batches_equal(outs[2], mat.materialize(sim.examples[1], PROJ))
