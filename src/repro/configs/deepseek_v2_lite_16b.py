"""DeepSeek-V2-Lite [arXiv:2405.04434]: 27L d2048 16H MLA (kv_lora 512,
nope 128 / rope 64 / v 128), MoE 64 routed top-6 + 2 shared, per-expert
d_ff 1408, v102400.

Assignment header says "MoE 64e top-6"; the inline note "160 routed" matches
DeepSeek-V2 (full), not Lite — we implement the Lite config (64 routed) per
the header and the public model card. V2-Lite's first dense layer is folded
into the homogeneous MoE stack (scan-over-layers); deviation noted in
DESIGN.md §Arch-applicability."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab=102_400, attention="mla",
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    rope_theta=1e4,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared=2),
)

SMOKE = TransformerConfig(
    name="deepseek-v2-lite-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=48, vocab=193, attention="mla", kv_lora_rank=32,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, rope_theta=1e4,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=48, n_shared=1),
    compute_dtype=jnp.float32, q_chunk=16, loss_chunk=16,
)


def spec() -> ArchSpec:
    return ArchSpec("deepseek-v2-lite-16b", "lm", FULL, SMOKE, LM_SHAPES)
