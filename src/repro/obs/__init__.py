"""Unified telemetry for the data plane (DESIGN.md §13).

    from repro.obs import Telemetry
    tel = Telemetry(sample_every=8)
    spec = DatasetSpec(..., telemetry=tel)
    ...
    tel.write_run_dir("runs/my-run")
    # python -m repro.obs.report runs/my-run
"""
from repro.obs.events import Event, EventLog
from repro.obs.registry import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                                MetricsRegistry, publish_dataclass)
from repro.obs.spans import (HOST_STAGES, STAGES, BatchSpan, ItemSpan,
                             SpanTracker, critical_path, current_span)
from repro.obs.telemetry import DEFAULT_SAMPLE_EVERY, Telemetry

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "publish_dataclass",
    "DEFAULT_BUCKETS", "Event", "EventLog", "ItemSpan", "BatchSpan",
    "SpanTracker", "current_span", "critical_path", "STAGES", "HOST_STAGES",
    "Telemetry", "DEFAULT_SAMPLE_EVERY",
]
