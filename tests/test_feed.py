"""Golden-equality tests for the zero-copy trainer feed (ISSUE 2).

The vectorized featurizer, the fused jagged->slot placement, and the
write-time-permuted reshuffle must be BYTE-identical to the seed
implementations (kept as ``*_reference`` / ``merge_base_batches`` +
``reshuffle``) across the edge cases: empty sequences, over-length
truncation, ``left_align=True``, mixed trait dtypes, traits missing from
some examples, and the remainder flush on ``close()``.
"""
import threading
import time

import numpy as np
import pytest

from repro.dpp.client import RebatchingClient
from repro.dpp.featurize import (
    FeatureSpec,
    featurize,
    featurize_jagged,
    featurize_reference,
    merge_base_batches,
    pad_sequences,
    pad_sequences_reference,
    reshuffle,
)
from repro.dpp.prefetch import DevicePrefetcher
from repro.dpp.worker import DPPWorker, probe_from_list
from repro.core.versioning import TrainingExample


def assert_batch_equal(got, want):
    assert list(got.keys()) == list(want.keys())
    for k in want:
        assert got[k].dtype == want[k].dtype, k
        assert got[k].shape == want[k].shape, k
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


# ---------------------------------------------------------------------------
# pad_sequences / featurize golden equality
# ---------------------------------------------------------------------------

def _random_seqs(rng, b, max_len, dtypes=(np.int64,)):
    return [rng.integers(0, 1000, size=int(rng.integers(0, max_len))).astype(
        rng.choice(dtypes)) for _ in range(b)]


@pytest.mark.parametrize("left_align", [False, True])
def test_pad_sequences_golden(left_align):
    rng = np.random.default_rng(0)
    for trial in range(40):
        b = int(rng.integers(0, 10))
        seq_len = int(rng.integers(1, 16))
        seqs = _random_seqs(rng, b, 3 * seq_len,
                            dtypes=(np.int64, np.int32, np.float32, np.int8))
        got = pad_sequences(seqs, seq_len, left_align=left_align)
        want = pad_sequences_reference(seqs, seq_len, left_align=left_align)
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)


def test_pad_sequences_golden_edge_cases():
    # empty batch, all-empty seqs, exact-length, over-length, dtype override
    for seqs, kw in [
        ([], {}),
        ([np.zeros(0, np.int32)] * 3, {}),
        ([np.arange(5)], {}),
        ([np.arange(50)], {}),
        ([np.arange(4, dtype=np.float64) + 0.7], {"dtype": np.int64}),
        ([np.arange(3), np.zeros(0, np.int64), np.arange(10)], {"left_align": True}),
    ]:
        got = pad_sequences(seqs, 5, **kw)
        want = pad_sequences_reference(seqs, 5, **kw)
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)


def _synth_batch(rng, b, seq_len, drop_trait_at=()):
    """Examples + UIHs with mixed trait dtypes; some examples missing traits."""
    traits = {"item_id": np.int64, "action": np.int32, "flag": np.int8,
              "score": np.float32}
    exs, uihs = [], []
    for i in range(b):
        n = int(rng.integers(0, 3 * seq_len))
        u = {"timestamp": np.sort(rng.integers(0, 10_000, n)).astype(np.int64)}
        for t, dt in traits.items():
            u[t] = rng.integers(0, 100, n).astype(dt)
        if i in drop_trait_at:
            u.pop("flag")
        uihs.append(u)
        exs.append(TrainingExample(
            request_id=i, user_id=int(rng.integers(0, 50)),
            request_ts=10_000 + i, label_ts=0,
            candidate={"item_id": int(rng.integers(0, 100))},
            labels={"click": float(rng.random() < 0.3)}))
    return exs, uihs


SPEC = FeatureSpec(seq_len=7, uih_traits=("item_id", "action", "flag", "score"),
                   candidate_fields=("item_id",), label_fields=("click",))


def test_featurize_golden():
    rng = np.random.default_rng(1)
    for trial in range(25):
        b = int(rng.integers(0, 12))
        drop = tuple(int(x) for x in rng.integers(0, max(b, 1), 2)) \
            if trial % 3 == 0 else ()
        exs, uihs = _synth_batch(rng, b, SPEC.seq_len, drop_trait_at=drop)
        assert_batch_equal(featurize(exs, uihs, SPEC),
                           featurize_reference(exs, uihs, SPEC))


def test_featurize_golden_all_empty_sequences():
    rng = np.random.default_rng(2)
    exs, uihs = _synth_batch(rng, 4, SPEC.seq_len)
    uihs = [{k: v[:0] for k, v in u.items()} for u in uihs]
    assert_batch_equal(featurize(exs, uihs, SPEC),
                       featurize_reference(exs, uihs, SPEC))


def test_featurize_jagged_layout_matches_dense():
    """offsets/arena form must densify to the same batch (kernel contract)."""
    rng = np.random.default_rng(3)
    exs, uihs = _synth_batch(rng, 9, SPEC.seq_len)
    jf = featurize_jagged(exs, uihs, SPEC)
    assert jf.offsets.shape == (10,)
    assert int(jf.offsets[-1]) == len(jf.values["item_id"])
    assert (np.diff(jf.offsets) <= SPEC.seq_len).all()  # clipped to budget
    assert_batch_equal(jf.to_padded(), featurize_reference(exs, uihs, SPEC))


# ---------------------------------------------------------------------------
# Slot rebatching golden equality (fused reshuffle + remainder flush)
# ---------------------------------------------------------------------------

def seed_rebatch_reference(bases, full, seed):
    """The seed client's semantics: arrival-order concat merge, exact-size
    emission reshuffled with seed+k, remainder flushed (reshuffled) at close."""
    out, k = [], 0
    cat = merge_base_batches(bases)
    n = len(next(iter(cat.values())))
    for lo in range(0, n - full + 1, full):
        b = {kk: v[lo : lo + full] for kk, v in cat.items()}
        out.append(reshuffle(b, seed + k) if seed is not None else b)
        k += 1
    if n % full:
        tail = {kk: v[n - n % full :] for kk, v in cat.items()}
        out.append(reshuffle(tail, seed + k) if seed is not None else tail)
    return out


def _base_batches(rng, spec, n_bases, rows_hi, seq_len):
    bases = []
    for _ in range(n_bases):
        b = int(rng.integers(1, rows_hi))
        exs, uihs = _synth_batch(rng, b, seq_len)
        bases.append((exs, uihs))
    return bases


@pytest.mark.parametrize("shuffle_seed", [None, 0, 7])
@pytest.mark.parametrize("full", [4, 16, 21])
def test_slot_rebatch_golden(shuffle_seed, full):
    rng = np.random.default_rng(4)
    chunks = _base_batches(rng, SPEC, 9, 2 * full + 1, SPEC.seq_len)
    dense = [featurize_reference(e, u, SPEC) for e, u in chunks]
    want = seed_rebatch_reference(dense, full, shuffle_seed)

    # dense put path
    c = RebatchingClient(full, buffer_batches=1024, shuffle_seed=shuffle_seed)
    for e, u in chunks:
        c.put(featurize(e, u, SPEC))
    c.close()
    got = list(c)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert_batch_equal(g, w)

    # fused jagged put path
    cj = RebatchingClient(full, buffer_batches=1024, shuffle_seed=shuffle_seed)
    for e, u in chunks:
        cj.put_jagged(featurize_jagged(e, u, SPEC))
    cj.close()
    got_j = list(cj)
    assert len(got_j) == len(want)
    for g, w in zip(got_j, want):
        assert_batch_equal(g, w)


def test_slot_rebatch_remainder_flush_on_close():
    """The epoch tail (fewer rows than a full batch) must be emitted as a
    short batch, reshuffled over its ACTUAL length like the seed path."""
    rng = np.random.default_rng(5)
    exs, uihs = _synth_batch(rng, 10, SPEC.seq_len)
    base = featurize_reference(exs, uihs, SPEC)
    want = seed_rebatch_reference([base], 16, 3)
    assert len(want) == 1 and len(want[0]["user_id"]) == 10

    c = RebatchingClient(16, shuffle_seed=3)
    c.put_jagged(featurize_jagged(exs, uihs, SPEC))
    c.close()
    got = list(c)
    assert len(got) == 1
    assert_batch_equal(got[0], want[0])


def test_slot_recycling_reuses_storage_and_stays_identical():
    rng = np.random.default_rng(6)
    full = 8
    chunks = _base_batches(rng, SPEC, 12, 6, SPEC.seq_len)
    dense = [featurize_reference(e, u, SPEC) for e, u in chunks]
    want = seed_rebatch_reference(dense, full, 0)

    c = RebatchingClient(full, buffer_batches=2, shuffle_seed=0)
    got = []

    def consume():
        while True:
            b = c.get_full_batch()
            if b is None:
                return
            got.append({k: v.copy() for k, v in b.items()})
            c.recycle(b)  # hand storage back for reuse

    th = threading.Thread(target=consume)
    th.start()
    for e, u in chunks:
        c.put_jagged(featurize_jagged(e, u, SPEC))
    c.close()
    th.join()
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert_batch_equal(g, w)
    assert c.stats.slot_reuses > 0


def test_mixed_put_and_put_jagged_interoperate():
    rng = np.random.default_rng(7)
    chunks = _base_batches(rng, SPEC, 6, 9, SPEC.seq_len)
    dense = [featurize_reference(e, u, SPEC) for e, u in chunks]
    want = seed_rebatch_reference(dense, 8, 1)
    c = RebatchingClient(8, buffer_batches=1024, shuffle_seed=1)
    for i, (e, u) in enumerate(chunks):
        if i % 2:
            c.put(featurize(e, u, SPEC))
        else:
            c.put_jagged(featurize_jagged(e, u, SPEC))
    c.close()
    got = list(c)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert_batch_equal(g, w)


def test_concurrent_producers_preserve_all_rows():
    """Placement copies run outside the client lock (span reservation); under
    producer contention every row must land exactly once, in every batch."""
    full = 32
    n_threads, per_thread = 4, 30
    c = RebatchingClient(full, buffer_batches=10_000, shuffle_seed=11)
    rng = np.random.default_rng(9)
    payloads = [[rng.integers(1, 1 << 30, (int(rng.integers(1, 13)),)
                              ).astype(np.int64)
                 for _ in range(per_thread)] for _ in range(n_threads)]

    def producer(mine):
        for arr in mine:
            c.put({"x": arr, "tag": arr * 3 + 1})

    threads = [threading.Thread(target=producer, args=(p,)) for p in payloads]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    c.close()
    got_x, got_tag = [], []
    for b in c:
        got_x.extend(b["x"].tolist())
        got_tag.extend(b["tag"].tolist())
    want = sorted(int(v) for p in payloads for a in p for v in a)
    assert sorted(got_x) == want                      # nothing lost/duplicated
    assert [t == x * 3 + 1 for x, t in zip(got_x, got_tag)].count(False) == 0


def test_schema_drift_put_raises_and_close_does_not_hang():
    """A mid-stream base batch with mismatched keys must raise (the seed
    concat path did too), poison its slot rather than emit a half-written
    batch, and leave the client usable: close() terminates and later puts
    land on a fresh slot."""
    c = RebatchingClient(8, buffer_batches=16, shuffle_seed=0)
    c.put({"a": np.arange(4), "b": np.arange(4.0)})
    with pytest.raises(KeyError):
        c.put({"a": np.arange(4)})        # missing key "b"
    c.put({"a": np.arange(4), "b": np.arange(4.0)})
    c.close()                              # must not hang on leaked writers
    out = list(c)
    assert [len(b["a"]) for b in out] == [4]   # only the fresh slot's tail


# ---------------------------------------------------------------------------
# Starvation accounting (satellite fix)
# ---------------------------------------------------------------------------

def test_starvation_not_inflated_by_timeouts_or_drain():
    c = RebatchingClient(4, shuffle_seed=None)
    assert c.get_full_batch(timeout=0.02) is None     # timeout: no delivery
    assert c.stats.starved_time_s == 0.0
    c.put({"a": np.arange(4)})
    assert c.get_full_batch(timeout=1.0) is not None  # delivered: counted
    starved_after_delivery = c.stats.starved_time_s
    assert starved_after_delivery > 0.0
    c.close()
    assert c.get_full_batch() is None                 # end-of-stream sentinel
    assert c.get_full_batch(timeout=0.02) is None     # post-drain poll
    assert c.stats.starved_time_s == starved_after_delivery
    assert c.stats.full_batches == 1


# ---------------------------------------------------------------------------
# Pipelined probe error propagation (satellite fix)
# ---------------------------------------------------------------------------

def test_run_pipelined_reraises_producer_exception(monkeypatch):
    class Boom(RuntimeError):
        pass

    def probe(idx):
        if idx == 2:
            raise Boom("probe died")
        return [] if idx < 2 else None

    # worker whose lookup/featurize do nothing (probe fails before use)
    w = DPPWorker.__new__(DPPWorker)
    w.probe_latency_s = 0.0
    from repro.dpp.worker import WorkerStats
    w.stats = WorkerStats()
    w._lookup = lambda examples: []
    w._featurize = lambda examples, uihs: {"n": np.zeros(0)}

    with pytest.raises(RuntimeError) as ei:
        list(w.run_pipelined(probe))
    assert isinstance(ei.value.__cause__, Boom)


# ---------------------------------------------------------------------------
# Device prefetcher
# ---------------------------------------------------------------------------

def test_device_prefetcher_preserves_stream_and_splits_starvation():
    full = 4
    c = RebatchingClient(full, buffer_batches=64, shuffle_seed=0)
    rng = np.random.default_rng(8)
    rows = [rng.integers(0, 100, (full, 3)).astype(np.int64) for _ in range(5)]
    for r in rows:
        c.put({"x": r})
    c.close()
    want = list(seed_rebatch_reference([{"x": r} for r in rows], full, 0))

    pf = DevicePrefetcher(c, depth=2,
                          prep_fn=lambda b: {"x": b["x"] * 2})
    got = [np.asarray(b["x"]) for b in pf]
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w["x"] * 2)
    s = c.stats
    assert s.full_batches == len(want)
    assert s.h2d_time_s > 0.0
    # the starved split must account the total
    assert s.starved_host_s + s.starved_h2d_s == pytest.approx(
        s.starved_time_s, rel=1e-6, abs=1e-9)


def test_device_prefetcher_propagates_source_errors():
    c = RebatchingClient(2, shuffle_seed=None)
    c.put({"a": np.arange(2)})

    def bad_prep(b):
        raise ValueError("prep exploded")

    pf = DevicePrefetcher(c, prep_fn=bad_prep)
    with pytest.raises(RuntimeError) as ei:
        pf.get()
    assert isinstance(ei.value.__cause__, ValueError)


def test_device_prefetcher_wraps_plain_iterables():
    batches = [{"x": np.full((2, 2), i)} for i in range(4)]
    pf = DevicePrefetcher(iter(batches), depth=1)
    got = [np.asarray(b["x"]) for b in pf]
    assert len(got) == 4
    for i, g in enumerate(got):
        np.testing.assert_array_equal(g, np.full((2, 2), i))


# ---------------------------------------------------------------------------
# Device-side late materialization (DESIGN §3): jagged emission + fused densify
# ---------------------------------------------------------------------------

SPEC_TS = FeatureSpec(
    seq_len=7,
    uih_traits=("item_id", "action", "flag", "score", "timestamp"),
    candidate_fields=("item_id",), label_fields=("click",))


def _synth_batch_ts(rng, b, seq_len, drop_trait_at=(), ts_base=3_000_000_000):
    """Like ``_synth_batch`` but with epoch-scale (> 2^31) timestamps — the
    range whose decode used to wrap in an int32 kernel carry."""
    exs, uihs = _synth_batch(rng, b, seq_len, drop_trait_at=drop_trait_at)
    for u in uihs:
        u["timestamp"] = u["timestamp"] + np.int64(ts_base)
    for i, e in enumerate(exs):
        exs[i] = TrainingExample(
            request_id=e.request_id, user_id=e.user_id,
            request_ts=e.request_ts + ts_base, label_ts=e.label_ts,
            candidate=e.candidate, labels=e.labels)
    return exs, uihs


def _run_client(chunks, spec, full, seed, emit_jagged):
    c = RebatchingClient(full, buffer_batches=1024, shuffle_seed=seed,
                         emit_jagged=emit_jagged)
    for e, u in chunks:
        c.put_jagged(featurize_jagged(e, u, spec))
    c.close()
    return list(c)


def _jagged_chunks(rng, n, spec, rows_hi=11):
    chunks = []
    for k in range(n):
        drop = (1,) if k == 1 else ()
        chunks.append(_synth_batch_ts(rng, int(rng.integers(1, rows_hi)),
                                      spec.seq_len, drop_trait_at=drop))
    return chunks


def test_jagged_emission_matches_dense_via_host_oracle():
    """emit_jagged=True must carry EXACTLY the dense path's rows: the compact
    payloads, scattered back on the host (densify_host), reproduce the dense
    client's batches byte-for-byte — including the reshuffle, a trait with
    schema-drift (own offsets), int64 timestamps past 2^31, and the
    remainder flush on close()."""
    from repro.dpp.device_mat import densify_host, is_jagged_batch

    rng = np.random.default_rng(20)
    chunks = _jagged_chunks(rng, 6, SPEC_TS)
    dense = _run_client(chunks, SPEC_TS, 8, seed=5, emit_jagged=False)
    jag = _run_client(chunks, SPEC_TS, 8, seed=5, emit_jagged=True)
    assert len(dense) == len(jag) and dense
    for d, jg in zip(dense, jag):
        assert is_jagged_batch(jg) and not is_jagged_batch(d)
        assert_batch_equal(densify_host(jg), d)
    # the drop-trait batch forced at least one own-offsets trait somewhere
    assert any(f"_offsets_flag" in jg for jg in jag)
    # exactness: timestamps stayed int64 through the compact payload
    assert all(jg["_arena_timestamp"].dtype == np.int64 for jg in jag)


def test_jagged_emission_device_parity_byte_identical():
    """The tentpole acceptance: DeviceMaterializer(payload) ==
    jax.device_put(host_dense_batch) — same keys (host insertion order; note
    device_put itself SORTS dict keys), same canonical dtypes, same bytes."""
    import jax

    from repro.dpp.device_mat import DeviceMaterializer

    rng = np.random.default_rng(21)
    chunks = _jagged_chunks(rng, 5, SPEC_TS)
    dense = _run_client(chunks, SPEC_TS, 8, seed=3, emit_jagged=False)
    jag = _run_client(chunks, SPEC_TS, 8, seed=3, emit_jagged=True)
    mat = DeviceMaterializer()
    for d, jg in zip(dense, jag):
        want = jax.device_put(d)
        got = mat(jg)
        assert list(got.keys()) == list(d.keys())
        assert mat.last_h2d_bytes > 0
        for k in d:
            assert got[k].dtype == want[k].dtype, k
            assert got[k].shape == want[k].shape, k
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(want[k]), err_msg=k)


def test_jagged_emission_all_empty_sequences():
    import jax

    from repro.dpp.device_mat import DeviceMaterializer, densify_host

    rng = np.random.default_rng(22)
    exs, uihs = _synth_batch_ts(rng, 5, SPEC_TS.seq_len)
    uihs = [{k: v[:0] for k, v in u.items()} for u in uihs]
    chunks = [(exs, uihs)]
    dense = _run_client(chunks, SPEC_TS, 8, seed=0, emit_jagged=False)
    jag = _run_client(chunks, SPEC_TS, 8, seed=0, emit_jagged=True)
    assert len(dense) == len(jag) == 1
    assert_batch_equal(densify_host(jag[0]), dense[0])
    got = DeviceMaterializer()(jag[0])
    want = jax.device_put(dense[0])
    for k in dense[0]:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)


def test_jagged_emission_rejects_dense_put():
    c = RebatchingClient(8, shuffle_seed=0, emit_jagged=True)
    with pytest.raises(TypeError, match="emit_jagged"):
        c.put({"a": np.arange(4)})


def test_device_prefetcher_materializes_jagged_payloads():
    """E2E through the transfer thread: prefetcher + DeviceMaterializer
    yields the same batches as the host-dense path, and ships strictly fewer
    bytes over the link (ClientStats.h2d_bytes)."""
    import jax

    from repro.dpp.device_mat import DeviceMaterializer

    rng = np.random.default_rng(23)
    chunks = _jagged_chunks(rng, 5, SPEC_TS)
    dense = _run_client(chunks, SPEC_TS, 8, seed=1, emit_jagged=False)
    dense_bytes = sum(v.nbytes for d in dense for v in d.values())

    cj = RebatchingClient(8, buffer_batches=1024, shuffle_seed=1,
                          emit_jagged=True)
    for e, u in chunks:
        cj.put_jagged(featurize_jagged(e, u, SPEC_TS))
    cj.close()
    pf = DevicePrefetcher(cj, depth=2, materialize=DeviceMaterializer())
    got = list(pf)
    assert len(got) == len(dense)
    for g, d in zip(got, dense):
        want = jax.device_put(d)
        assert set(g) == set(d)
        for k in d:
            np.testing.assert_array_equal(np.asarray(g[k]),
                                          np.asarray(want[k]), err_msg=k)
    assert 0 < cj.stats.h2d_bytes < dense_bytes
