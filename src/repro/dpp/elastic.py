"""Elastic DPP scaling + straggler mitigation (paper §4.2.1; fault tolerance).

The controller watches job-level GPU-starvation % (trainer idle) and worker
waste % (CPU idle) and adjusts the provisioned worker count so training stays
compute-bound. The pool re-dispatches work items whose worker exceeded the
straggler deadline (speculative execution), and survives worker crashes.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class ElasticConfig:
    min_workers: int = 1
    max_workers: int = 32
    target_starvation_pct: float = 2.0   # scale up above this
    target_waste_pct: float = 60.0       # scale down above this
    step: int = 1


class ElasticController:
    """Pure decision logic (separated from the pool so it is unit-testable)."""

    def __init__(self, cfg: ElasticConfig):
        self.cfg = cfg
        self.decisions: List[int] = []

    def decide(self, workers: int, starvation_pct: float, waste_pct: float) -> int:
        new = workers
        if starvation_pct > self.cfg.target_starvation_pct:
            new = min(self.cfg.max_workers, workers + self.cfg.step)
        elif waste_pct > self.cfg.target_waste_pct and starvation_pct == 0.0:
            new = max(self.cfg.min_workers, workers - self.cfg.step)
        self.decisions.append(new)
        return new


@dataclasses.dataclass
class PoolStats:
    completed: int = 0
    speculative_retries: int = 0
    worker_failures: int = 0


class StragglerAwarePool:
    """Thread pool with deadline-based speculative re-dispatch.

    Work items are idempotent (materialization is a pure read), so running a
    straggler's item twice is safe — first completion wins.
    """

    def __init__(
        self,
        work_fn: Callable[[object], object],
        n_workers: int = 2,
        straggler_deadline_s: float = 5.0,
    ):
        self.work_fn = work_fn
        self.straggler_deadline_s = straggler_deadline_s
        self._task_q: "queue.Queue" = queue.Queue()
        self._done: Dict[int, object] = {}
        self._done_cv = threading.Condition()
        self._inflight: Dict[int, float] = {}   # task id -> dispatch time
        self._retried: set = set()
        self._stop = threading.Event()
        self.stats = PoolStats()
        self._threads: List[threading.Thread] = []
        self.resize(n_workers)

    # -- worker loop -------------------------------------------------------------
    def _loop(self, me: int) -> None:
        while not self._stop.is_set():
            try:
                task_id, payload = self._task_q.get(timeout=0.05)
            except queue.Empty:
                continue
            with self._done_cv:
                if task_id in self._done:   # speculative duplicate already done
                    continue
                self._inflight[task_id] = time.perf_counter()
            try:
                result = self.work_fn(payload)
            except Exception:
                self.stats.worker_failures += 1
                # crash-equivalent: re-queue the item for another worker
                self._task_q.put((task_id, payload))
                continue
            with self._done_cv:
                if task_id not in self._done:
                    self._done[task_id] = result
                    self.stats.completed += 1
                self._inflight.pop(task_id, None)
                self._done_cv.notify_all()

    # -- API ---------------------------------------------------------------------
    def submit(self, task_id: int, payload: object) -> None:
        self._task_q.put((task_id, payload))

    def _respeculate(self, pending_payloads: Dict[int, object]) -> None:
        now = time.perf_counter()
        with self._done_cv:
            for tid, started in list(self._inflight.items()):
                if (
                    now - started > self.straggler_deadline_s
                    and tid not in self._retried
                    and tid in pending_payloads
                ):
                    self._retried.add(tid)
                    self.stats.speculative_retries += 1
                    self._task_q.put((tid, pending_payloads[tid]))

    def gather(self, task_ids, payloads: Dict[int, object], timeout_s: float = 60.0):
        """Wait for all task_ids, re-dispatching stragglers as needed."""
        deadline = time.perf_counter() + timeout_s
        while True:
            with self._done_cv:
                if all(t in self._done for t in task_ids):
                    return [self._done[t] for t in task_ids]
                self._done_cv.wait(timeout=0.05)
            self._respeculate(payloads)
            if time.perf_counter() > deadline:
                raise TimeoutError("pool gather timed out")

    def resize(self, n_workers: int) -> None:
        while len(self._threads) < n_workers:
            t = threading.Thread(target=self._loop, args=(len(self._threads),),
                                 daemon=True)
            t.start()
            self._threads.append(t)
        # shrink is cooperative: extra threads exit when stop is set; for the
        # simulation we only record the logical size
        self.n_workers = n_workers

    def shutdown(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=1.0)
