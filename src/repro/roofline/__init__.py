"""Roofline analysis: 3-term model (compute / HBM / ICI) from compiled
dry-run artifacts."""
