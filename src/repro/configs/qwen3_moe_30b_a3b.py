"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 48L d2048 32H GQA(kv=4),
MoE 128 experts top-8, per-expert d_ff 768, v151936, qk-norm."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=4, d_ff=768, vocab=151_936, head_dim=128, qk_norm=True,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=768, n_shared=0),
)

SMOKE = TransformerConfig(
    name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab=211, head_dim=16, qk_norm=True, rope_theta=1e6,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=0),
    compute_dtype=jnp.float32, q_chunk=16, loss_chunk=16,
)


def spec() -> ArchSpec:
    return ArchSpec("qwen3-moe-30b-a3b", "lm", FULL, SMOKE, LM_SHAPES)
