"""Version metadata + training-example records (paper §3.3).

The versioned late materialization protocol replaces the O(seq_len) UIH payload
of a Fat Row with O(1) *version metadata*: temporal boundaries
(start_ts, end_ts), the sequence length at snapshot time, an optional checksum
for reconstruction validation, and the immutable-store generation.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional

import msgpack
import numpy as np

from repro.core import events as ev
from repro.storage import columnar


@dataclasses.dataclass(frozen=True)
class VersionMetadata:
    """O(1) pointer to an immutable UIH window. ~40 bytes regardless of seq len."""

    start_ts: int       # inclusive lower temporal bound of the immutable window
    end_ts: int         # inclusive upper bound (== immutable watermark at T_request)
    seq_len: int        # immutable events inside the window at snapshot time
    checksum: int       # crc32 over (timestamp,item_id) of the window; 0 = absent
    generation: int     # immutable-store generation observed at snapshot time

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "VersionMetadata":
        return VersionMetadata(**d)


def window_checksum(batch: ev.EventBatch) -> int:
    """Checksum of the identity columns of an immutable window.

    Computed over (timestamp, item_id) only, so it is invariant to trait/
    feature-group projection of SideInfo columns but still pins the exact event
    set + order — which is what O2O consistency requires."""
    crc = zlib.crc32(np.ascontiguousarray(batch["timestamp"]).tobytes())
    crc = zlib.crc32(np.ascontiguousarray(batch["item_id"]).tobytes(), crc)
    return crc & 0xFFFFFFFF


@dataclasses.dataclass
class TrainingExample:
    """One logged ranking request joined with its late-arriving labels.

    Exactly one of (``version``, ``fat_uih``) is set:
      * VLM example: ``mutable_uih`` (small recent slice) + ``version`` metadata
      * Fat Row example: ``fat_uih`` holds the complete materialized UIH
    """

    request_id: int
    user_id: int
    request_ts: int
    label_ts: int
    candidate: Dict[str, int]           # e.g. {"item_id": ..., "category": ...}
    labels: Dict[str, float]            # e.g. {"click": 1.0, "watch_time": 3.2}
    mutable_uih: Optional[ev.EventBatch] = None
    version: Optional[VersionMetadata] = None
    fat_uih: Optional[ev.EventBatch] = None
    context: bytes = b""              # non-sequence features (opaque payload)

    @property
    def is_fat(self) -> bool:
        return self.fat_uih is not None

    # -- serialization (real bytes; used for bandwidth accounting) ----------
    def to_bytes(self, schema: ev.TraitSchema) -> bytes:
        head = {
            "request_id": self.request_id,
            "user_id": self.user_id,
            "request_ts": self.request_ts,
            "label_ts": self.label_ts,
            "candidate": self.candidate,
            "labels": self.labels,
            "version": self.version.to_dict() if self.version else None,
            "fat": self.is_fat,
        }
        parts = [msgpack.packb(head, use_bin_type=True), self.context]
        if self.mutable_uih is not None:
            parts.append(columnar.encode_stripe(self.mutable_uih, schema))
        else:
            parts.append(b"")
        if self.fat_uih is not None:
            parts.append(columnar.encode_stripe(self.fat_uih, schema))
        else:
            parts.append(b"")
        out = bytearray()
        for p in parts:
            out += len(p).to_bytes(4, "little")
            out += p
        return bytes(out)

    @staticmethod
    def from_bytes(blob: bytes, schema: ev.TraitSchema) -> "TrainingExample":
        parts = []
        off = 0
        for _ in range(4):
            ln = int.from_bytes(blob[off : off + 4], "little")
            off += 4
            parts.append(blob[off : off + ln])
            off += ln
        head = msgpack.unpackb(parts[0], raw=False, strict_map_key=False)
        context = parts[1]
        mutable = (
            columnar.decode_stripe(parts[2], schema) if parts[2] else None
        )
        fat = columnar.decode_stripe(parts[3], schema) if parts[3] else None
        return TrainingExample(
            request_id=head["request_id"],
            user_id=head["user_id"],
            request_ts=head["request_ts"],
            label_ts=head["label_ts"],
            candidate=head["candidate"],
            labels=head["labels"],
            mutable_uih=mutable,
            version=VersionMetadata.from_dict(head["version"]) if head["version"] else None,
            fat_uih=fat,
            context=context,
        )

    def payload_bytes(self, schema: ev.TraitSchema) -> int:
        return len(self.to_bytes(schema))
