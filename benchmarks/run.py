"""Benchmark aggregator: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes benchmarks/results.json."""
from __future__ import annotations

import json
import sys
import time
import traceback
from pathlib import Path

MODULES = [
    "benchmarks.fig2_cost_wall",
    "benchmarks.table1_system_efficiency",
    "benchmarks.bench_prefetch",
    "benchmarks.bench_affinity",
    "benchmarks.bench_scan_plan",
    "benchmarks.bench_rebatch",
    "benchmarks.bench_kernels",
    "benchmarks.fig4_ne_scaling",
]


def main() -> None:
    import importlib

    only = sys.argv[1] if len(sys.argv) > 1 else None
    all_results = []
    failures = []
    print("name,us_per_call,derived")
    for modname in MODULES:
        if only and only not in modname:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            results = mod.run()
        except Exception as e:
            failures.append(modname)
            print(f"{modname},ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            continue
        for r in results:
            print(r.csv(), flush=True)
            all_results.append({"name": r.name, "us_per_call": r.us_per_call,
                                "derived": r.derived})
        print(f"# {modname} done in {time.time() - t0:.1f}s", flush=True)

    out = Path(__file__).parent / "results.json"
    out.write_text(json.dumps(all_results, indent=1, default=str))
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
