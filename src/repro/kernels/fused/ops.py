"""Public wrappers + host helpers for the fused late-materialization path.

Layering (DESIGN §3): the host ships the **compact** jagged layout — one
stacked int32 arena per shared ScatterPlan, offsets, and (for timestamp
traits) window-relative int32 deltas + per-row bases. On device, ONE
``fused_densify`` kernel launch rebuilds every trait's right-aligned
[B, L] lanes and decodes timestamps in the same VMEM window; the dense id
lanes then feed ``embedding_bag`` straight from HBM (no host round trip).

dtype contract under jax's default x64-disabled config: the device batch is
*canonical* — int64 host traits arrive as wrapped int32 (exactly what
``jax.device_put`` of the host-dense batch produces), float32 rides the
arena bit-cast and is reconstructed bit-exact, float64 canonicalizes to
float32. Timestamps stay exact as int64 only on the host paths (see
delta_decode/ops.py); on device they are canonically wrapped like every
other int64 lane.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import runtime
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.fused.fused import fused_densify_kernel

_I32_MAX = np.int64(2**31 - 1)


# ---------------------------------------------------------------------------
# Host-side packing helpers (numpy; run in the prefetch thread)
# ---------------------------------------------------------------------------

def ts_delta_encode(arena: np.ndarray, offsets: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Window-relative delta encoding of an absolute int64 timestamp arena.

    Returns ``(deltas int32 [N], bases int64 [B])``: each row's first kept
    element becomes delta 0 and its absolute value the row base, so the
    device cumsum only ever carries within-window offsets. Raises if a
    within-window span exceeds int32 — the codec contract (stripes are
    bounded time windows) is broken and wrapping it would corrupt data."""
    arena = np.asarray(arena, dtype=np.int64)
    offsets = np.asarray(offsets, dtype=np.int64)
    lens = np.diff(offsets)
    b = len(lens)
    bases = np.zeros(b, np.int64)
    nz = lens > 0
    starts = offsets[:-1][nz]
    bases[nz] = arena[starts]
    if not len(arena):
        return np.zeros(0, np.int32), bases
    d = np.empty(len(arena), np.int64)
    d[0] = 0
    d[1:] = arena[1:] - arena[:-1]
    d[starts] = 0                      # row starts: relative to own base
    rel = arena - np.repeat(bases, lens)
    if (np.abs(d).max(initial=0) > _I32_MAX
            or np.abs(rel).max(initial=0) > _I32_MAX):
        raise ValueError(
            "timestamp window span exceeds int32: the stripe codec's "
            "bounded-window contract is broken (see delta_decode/ops.py)")
    return d.astype(np.int32), bases


def _to_i32_col(col: np.ndarray) -> np.ndarray:
    """One trait column -> its int32 arena representation (see module doc)."""
    if col.dtype == np.float64:
        col = col.astype(np.float32)
    if col.dtype == np.float32:
        return col.view(np.int32)
    return col.astype(np.int32)        # ints/bool: wrap == canonicalization


def pack_arena(values: Dict[str, np.ndarray]
               ) -> Tuple[np.ndarray, List[Tuple[str, np.dtype]]]:
    """Stack same-plan trait arenas into one (N, T) int32 arena + metas
    (trait name, original host dtype) in column order."""
    metas = [(trait, np.asarray(col).dtype) for trait, col in values.items()]
    cols = [_to_i32_col(np.asarray(col)) for col in values.values()]
    n = len(cols[0]) if cols else 0
    arena = np.empty((n, len(cols)), np.int32)
    for i, c in enumerate(cols):
        arena[:, i] = c
    return arena, metas


# ---------------------------------------------------------------------------
# Device-side ops
# ---------------------------------------------------------------------------

def fused_densify(arena: jax.Array, offsets: jax.Array, seq_len: int,
                  ts_bases: Optional[jax.Array] = None, ts_col: int = -1
                  ) -> jax.Array:
    """(N, T) int32 arena + (B+1,) offsets -> (B, L, T) int32, right-aligned,
    timestamp column (if any) delta-decoded in-window.

    Front-pads the arena by L zero rows so the kernel's fixed-size DMA
    window is always in-bounds; lane-pads T to a multiple of 128.
    ``ts_bases`` must already be int32 (host callers wrap int64 bases with
    ``.astype(np.int32)`` — canonicalization parity, see module doc)."""
    b = offsets.shape[0] - 1
    n, t = arena.shape
    if b == 0 or seq_len == 0 or t == 0:
        return jnp.zeros((b, seq_len, t), jnp.int32)
    tp = (128 - t % 128) % 128
    v = jnp.pad(jnp.asarray(arena), ((seq_len, 0), (0, tp)))
    bases = (jnp.zeros(b, jnp.int32) if ts_bases is None
             else jnp.asarray(ts_bases).astype(jnp.int32))
    out = fused_densify_kernel(
        v, jnp.asarray(offsets).astype(jnp.int32), bases,
        max_len=seq_len, ts_col=ts_col,
        interpret=runtime.interpret_default())
    return out[:, :, :t]


def unpack_dense(dense: jax.Array, metas: List[Tuple[str, np.dtype]]
                 ) -> Dict[str, jax.Array]:
    """Split a (B, L, T) int32 dense block back into per-trait [B, L] lanes
    with their canonical device dtypes restored (bit-exact for float32)."""
    out: Dict[str, jax.Array] = {}
    for i, (trait, dt) in enumerate(metas):
        col = dense[:, :, i]
        if dt in (np.float32, np.float64):
            out[trait] = jax.lax.bitcast_convert_type(col, jnp.float32)
        else:
            out[trait] = col.astype(jax.dtypes.canonicalize_dtype(dt))
    return out


def late_materialize(values: Dict[str, np.ndarray], offsets: np.ndarray,
                     seq_len: int, *, ts_trait: Optional[str] = None,
                     table: Optional[jax.Array] = None,
                     ids_trait: Optional[str] = None,
                     combiner: str = "sum") -> Dict[str, object]:
    """One-call fused pipeline: delta-decode + densify in a single kernel
    launch, then ``embedding_bag`` over the dense id lanes on-device.

    ``values`` are flat per-trait arenas (clipped tails) sharing ``offsets``;
    a ``ts_trait`` arena is given in ABSOLUTE int64 and is delta-encoded
    here (rows must be pre-clipped to ``seq_len`` — the featurizer contract —
    so the window base is the first KEPT element). Returns
    ``{"lens", "mask", "traits": {trait: [B, L]}, "pooled"?}``.

    The training feed uses ``fused_densify``/``unpack_dense`` directly and
    leaves the embedding lookup inside the jit'd step — the table is a
    trained parameter (fusion boundary, DESIGN §3); this composition is the
    bench/serving-style surface that exercises all three stages together."""
    offs = np.asarray(offsets, dtype=np.int64)
    vals = dict(values)
    ts_bases = None
    ts_col = -1
    if ts_trait is not None and ts_trait in vals:
        deltas, bases64 = ts_delta_encode(vals[ts_trait], offs)
        vals[ts_trait] = deltas
        ts_bases = bases64.astype(np.int32)
        ts_col = list(vals).index(ts_trait)
    arena, metas = pack_arena(vals)
    offs32 = jnp.asarray(offs.astype(np.int32))
    dense = fused_densify(jnp.asarray(arena), offs32, seq_len,
                          ts_bases=ts_bases, ts_col=ts_col)
    traits = unpack_dense(dense, metas)
    lens = jnp.minimum(jnp.diff(offs32), seq_len).astype(jnp.int32)
    j = jnp.arange(seq_len, dtype=jnp.int32)[None, :]
    mask = j >= (seq_len - lens[:, None])
    out: Dict[str, object] = {"lens": lens, "mask": mask, "traits": traits}
    if table is not None and ids_trait is not None:
        out["pooled"] = embedding_bag(jnp.asarray(table), traits[ids_trait],
                                      mask, combiner=combiner)
    return out
