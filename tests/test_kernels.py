"""Per-kernel allclose sweeps vs pure-jnp oracles (interpret mode on CPU).

Hypothesis sweeps cover ragged lengths, dtypes, and degenerate cases per the
assignment: 'for each Pallas kernel, sweep shapes/dtypes and assert_allclose
against the ref.py pure-jnp oracle'."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to a fixed-examples sweep (see the shim)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.delta_decode import ops as dd_ops
from repro.kernels.delta_decode import ref as dd_ref
from repro.kernels.embedding_bag import ops as eb_ops
from repro.kernels.embedding_bag import ref as eb_ref
from repro.kernels.jagged import ops as jg_ops
from repro.kernels.jagged import ref as jg_ref


# ---------------------------------------------------------------------------
# delta_decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,n", [(1, 16), (3, 100), (8, 128), (16, 384), (5, 7)])
def test_delta_decode_shapes(b, n):
    rng = np.random.default_rng(b * 1000 + n)
    deltas = rng.integers(0, 10_000, size=(b, n)).astype(np.int32)
    deltas[:, 0] = 0
    bases = rng.integers(0, 1 << 20, size=(b,)).astype(np.int32)
    got = dd_ops.delta_decode(jnp.asarray(deltas), jnp.asarray(bases))
    want = dd_ref.delta_decode(jnp.asarray(deltas), jnp.asarray(bases))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 12),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**16),
)
def test_delta_decode_property(b, n, seed):
    rng = np.random.default_rng(seed)
    deltas = rng.integers(0, 1 << 16, size=(b, n)).astype(np.int32)
    bases = rng.integers(-(1 << 20), 1 << 20, size=(b,)).astype(np.int32)
    got = dd_ops.delta_decode(jnp.asarray(deltas), jnp.asarray(bases))
    want = dd_ref.delta_decode(jnp.asarray(deltas), jnp.asarray(bases))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_delta_decode_matches_columnar_codec():
    """End-to-end: the kernel decodes what the storage codec encoded."""
    from repro.core import events as ev
    from repro.storage import columnar

    rng = np.random.default_rng(0)
    ts = np.sort(rng.integers(0, 1 << 30, size=200)).astype(np.int64)
    payload, meta = columnar.encode_column(ts, ev.DENSE_MONOTONE)
    inner = dict(meta); inner["codec"] = meta["inner"]
    deltas = columnar._unpack_unsigned(payload, inner, np.int64)
    got = dd_ops.delta_decode(
        jnp.asarray(deltas[None, :].astype(np.int32)),
        jnp.asarray(np.zeros(1, np.int32)),
    )
    np.testing.assert_array_equal(
        np.asarray(got)[0] + meta["base"], ts)


# ---------------------------------------------------------------------------
# jagged_to_padded
# ---------------------------------------------------------------------------

def _jagged_case(b, max_len, d, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, 2 * max_len, size=b)
    offsets = np.zeros(b + 1, np.int32)
    np.cumsum(lens, out=offsets[1:])
    values = rng.standard_normal((int(offsets[-1]), d)).astype(dtype)
    if values.shape[0] == 0:
        values = np.zeros((0, d), dtype)
    return jnp.asarray(values), jnp.asarray(offsets)


@pytest.mark.parametrize("b,max_len,d", [(4, 8, 16), (2, 32, 128), (7, 5, 64),
                                         (1, 16, 200), (8, 64, 32)])
def test_jagged_to_padded_shapes(b, max_len, d):
    values, offsets = _jagged_case(b, max_len, d, seed=b * 7 + d)
    got = jg_ops.jagged_to_padded(values, offsets, max_len)
    want = jg_ref.jagged_to_padded(values, offsets, max_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 10),
    max_len=st.integers(1, 48),
    d=st.sampled_from([1, 8, 64, 130]),
    seed=st.integers(0, 2**16),
    dtype=st.sampled_from([np.float32, np.int32]),
)
def test_jagged_to_padded_property(b, max_len, d, seed, dtype):
    values, offsets = _jagged_case(b, max_len, d, seed, dtype)
    got = jg_ops.jagged_to_padded(values, offsets, max_len)
    want = jg_ref.jagged_to_padded(values, offsets, max_len)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_jagged_matches_featurizer_contract():
    """Kernel output == host-side DPP featurizer padding (right-aligned)."""
    from repro.dpp.featurize import pad_sequences

    rng = np.random.default_rng(3)
    seqs = [rng.integers(0, 100, size=n).astype(np.int64)
            for n in [3, 0, 12, 7]]
    offsets = np.zeros(5, np.int32)
    np.cumsum([len(s) for s in seqs], out=offsets[1:])
    values = np.concatenate(seqs).astype(np.float32)[:, None]
    got = jg_ops.jagged_to_padded(jnp.asarray(values), jnp.asarray(offsets), 8)
    want = pad_sequences(seqs, 8).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(got)[:, :, 0], want)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,l", [(64, 16, 4, 8), (1000, 128, 8, 20),
                                     (37, 200, 3, 5), (256, 64, 16, 1)])
def test_embedding_bag_shapes(v, d, b, l):
    rng = np.random.default_rng(v + d)
    table = rng.standard_normal((v, d)).astype(np.float32)
    ids = rng.integers(0, v, size=(b, l)).astype(np.int32)
    mask = (rng.random((b, l)) < 0.8)
    got = eb_ops.embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                               jnp.asarray(mask))
    want = eb_ref.embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                                jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    v=st.integers(2, 500),
    d=st.sampled_from([4, 32, 128, 144]),
    b=st.integers(1, 8),
    l=st.integers(1, 24),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
    combiner=st.sampled_from(["sum", "mean"]),
)
def test_embedding_bag_property(v, d, b, l, density, seed, combiner):
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((v, d)).astype(np.float32)
    ids = rng.integers(0, v, size=(b, l)).astype(np.int32)
    mask = (rng.random((b, l)) < density)
    got = eb_ops.embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                               jnp.asarray(mask), combiner)
    want = eb_ref.embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                                jnp.asarray(mask), combiner)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_bf16():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((128, 64)), jnp.bfloat16)
    ids = jnp.asarray(rng.integers(0, 128, size=(4, 6)), jnp.int32)
    mask = jnp.ones((4, 6), bool)
    got = eb_ops.embedding_bag(table, ids, mask)
    want = eb_ref.embedding_bag(table, ids, mask)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2)
