"""Benchmark smoke: every module in benchmarks/run.py MODULES must execute
end-to-end at the --quick tiny config and yield well-formed BenchResults.

This is what keeps the benchmark suite from rotting: an API refactor that
breaks a benchmark module now fails tier-1 instead of surfacing months later
in a full benchmark run. Quick-mode numbers are NOT asserted — only that the
modules run and produce structurally valid output.
"""
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT) not in sys.path:  # make `benchmarks.*` importable
    sys.path.insert(0, str(REPO_ROOT))

from benchmarks import run as bench_run  # noqa: E402
from benchmarks.common import BenchResult  # noqa: E402


def test_modules_list_complete():
    listed = {m.rsplit(".", 1)[1] for m in bench_run.MODULES}
    on_disk = {p.stem for p in (REPO_ROOT / "benchmarks").glob("*.py")
               if p.stem not in ("run", "common", "__init__",
                                 "roofline_report")}
    assert on_disk <= listed, f"benchmark modules not in MODULES: {on_disk - listed}"


@pytest.mark.parametrize("modname", bench_run.MODULES,
                         ids=[m.rsplit(".", 1)[1] for m in bench_run.MODULES])
def test_benchmark_quick(modname):
    results = bench_run.run_module(modname, quick=True)
    assert isinstance(results, list) and results, modname
    for r in results:
        assert isinstance(r, BenchResult)
        assert r.name and isinstance(r.derived, dict)
        r.csv()  # the CSV line must render
