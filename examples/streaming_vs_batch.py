"""Bifurcated protocol demo (paper §3.2): ONE pipeline serves both training
paradigms.

The same logged traffic is consumed (a) as a live stream by a streaming
trainer, and (b) replayed days later from hourly warehouse partitions by a
batch trainer — the versioned reconstruction yields bit-identical UIH features
and therefore identical losses, with zero Fat Row duplication.

Run:  PYTHONPATH=src python examples/streaming_vs_batch.py
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core.projection import TenantProjection
from repro.core.simulation import ProductionSim, SimConfig
from repro.dpp.featurize import FeatureSpec
from repro.dpp.worker import DPPWorker
from repro.models import recsys as R

SEQ_LEN = 32
BATCH = 16


def make_worker(sim):
    tenant = TenantProjection("t", seq_len=SEQ_LEN,
                              feature_groups=("core", "sideinfo"),
                              traits_per_group={
                                  "core": ("timestamp", "item_id", "action_type"),
                                  "sideinfo": ("category",)})
    spec = FeatureSpec(seq_len=SEQ_LEN,
                       uih_traits=("item_id", "action_type", "category"))
    return DPPWorker(sim.materializer(validate_checksum=True), tenant, spec,
                     sim.schema)


def main() -> None:
    sim = ProductionSim(SimConfig(
        stream=ev.StreamConfig(n_users=16, n_items=2_000, days=4,
                               events_per_user_day_mean=40.0, seed=3),
        stripe_len=32, requests_per_user_day=4, seed=3))

    # --- streaming side: consume the live stream as days unfold ---
    stream_batches = []
    worker_s = make_worker(sim)

    def consume():
        buf = []
        while True:
            exm = sim.stream.consume()
            if exm is None:
                break
            buf.append(exm)
            if len(buf) == BATCH:
                stream_batches.append(worker_s.process(buf))
                buf = []

    consumer = threading.Thread(target=consume)
    consumer.start()
    sim.run_days(3, capture_reference=False)
    sim.stream.close()
    consumer.join()
    print(f"streaming trainer consumed {len(stream_batches)} batches "
          f"within seconds of logging")

    # --- batch side: replay from the warehouse later (after more compactions) ---
    worker_b = make_worker(sim)
    by_id = {}
    for hour in sim.warehouse.hours():
        for exm in sim.warehouse.read_partition(hour):
            by_id[exm.request_id] = exm

    cfg = R.BERT4RecConfig(name="demo", embed_dim=16, n_blocks=2, n_heads=2,
                           seq_len=SEQ_LEN, item_vocab=2_000,
                           compute_dtype=jnp.float32)
    params = R.init_bert4rec(jax.random.PRNGKey(0), cfg)
    fwd = jax.jit(lambda p, b: R.bert4rec_forward(p, b, cfg))

    mismatches = 0
    for sb in stream_batches[:8]:
        ids = [int(r) for r in sb["request_ts"]]
        # find the same examples in the warehouse by (user, ts)
        keys = list(zip(sb["user_id"].tolist(), sb["request_ts"].tolist()))
        replay = [next(e for e in by_id.values()
                       if (e.user_id, e.request_ts) == k) for k in keys]
        bb = worker_b.process(replay)
        same = all(np.array_equal(sb[k], bb[k]) for k in sb)
        mismatches += 0 if same else 1
        batch = {"uih_item_id": jnp.asarray(sb["uih_item_id"], jnp.int32),
                 "uih_mask": jnp.asarray(sb["uih_mask"]),
                 "cand_item_id": jnp.asarray(sb["cand_item_id"], jnp.int32)}
        batch2 = {k: jnp.asarray(bb[{"uih_item_id": "uih_item_id",
                                     "uih_mask": "uih_mask",
                                     "cand_item_id": "cand_item_id"}[k]],
                                 v.dtype) for k, v in batch.items()}
        s1, s2 = fwd(params, batch), fwd(params, batch2)
        assert jnp.allclose(s1, s2), "scores diverged between paradigms"
    print(f"batch replay vs streaming: {mismatches} feature mismatches "
          f"across {min(8, len(stream_batches))} batches (expect 0)")
    print(f"checksum validations: streaming={worker_s.materializer.stats.checksum_validated},"
          f" batch={worker_b.materializer.stats.checksum_validated}, "
          f"failures={worker_s.materializer.stats.checksum_failures + worker_b.materializer.stats.checksum_failures}")


if __name__ == "__main__":
    main()
