"""Device-side late materialization (DESIGN §3): compact jagged payloads vs
host-dense batches.

Two claims, both ASSERTED (not just reported):

1. **byte identity** — the jagged-emission client + ``DeviceMaterializer``
   produce exactly the batches the host-dense path produces after
   ``jax.device_put`` (same keys, dtypes, values);
2. **the host featurize stage shrinks toward pure I/O** — with the [B, L]
   zero-scatter moved on-device, the client's host-side cost per batch
   (arena slicing + concat) is strictly below the host-densify baseline, and
   the H2D payload is strictly smaller (bytes scale with kept elements, not
   B*L*T).

The transfer-stage time is reported but NOT asserted: the fused kernel runs
in interpret mode on CPU here, which is orders of magnitude off real Pallas
lowering — the roofline model (``materialization_roofline``) carries the
device-time argument instead.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import BenchResult
from repro.core.versioning import TrainingExample
from repro.dpp.client import RebatchingClient
from repro.dpp.device_mat import DeviceMaterializer, jagged_batch_nbytes
from repro.dpp.featurize import FeatureSpec, featurize_jagged
from repro.roofline.analysis import materialization_roofline

TS0 = 3_000_000_000  # > 2^31: exercises the windowed delta-decode path


def _synth_features(n_batches: int, rows: int, seq_len: int, mean_len: int,
                    seed: int = 7):
    rng = np.random.default_rng(seed)
    spec = FeatureSpec(seq_len=seq_len,
                       uih_traits=("item_id", "action", "timestamp"),
                       candidate_fields=("item_id",), label_fields=("click",))
    feats = []
    for k in range(n_batches):
        exs, uihs = [], []
        for i in range(rows):
            ln = int(rng.integers(1, 2 * mean_len))
            uihs.append({
                "item_id": rng.integers(0, 50_000, ln).astype(np.int64),
                "action": rng.integers(0, 8, ln).astype(np.int32),
                "timestamp": TS0 + np.sort(
                    rng.integers(0, 10**6, ln)).astype(np.int64),
            })
            exs.append(TrainingExample(
                request_id=f"r{k}-{i}", user_id=i, request_ts=TS0 + i,
                label_ts=TS0 + i + 1,
                candidate={"item_id": np.int64(rng.integers(0, 50_000))},
                labels={"click": np.float32(rng.integers(0, 2))}))
        feats.append(featurize_jagged(exs, uihs, spec))
    return feats


def _client_path(feats, full_batch: int, emit_jagged: bool):
    """Push every base batch through a rebatching client; return the emitted
    full batches and the host-stage wall time (the featurize-tail cost the
    device path is meant to shrink)."""
    c = RebatchingClient(full_batch_size=full_batch, shuffle_seed=0,
                         emit_jagged=emit_jagged)
    t0 = time.perf_counter()
    for jf in feats:
        c.put_jagged(jf)
    c.close()
    out = []
    while True:
        b = c.get_full_batch()
        if b is None:
            break
        out.append(b)
    return out, time.perf_counter() - t0


def run(quick: bool = False) -> List[BenchResult]:
    import jax

    if quick:
        n_batches, rows, seq_len, mean_len, full_b = 12, 8, 1024, 32, 16
    else:
        n_batches, rows, seq_len, mean_len, full_b = 48, 16, 2048, 96, 64
    feats = _synth_features(n_batches, rows, seq_len, mean_len)

    # median-of-3: the host-stage gap is the headline, keep it noise-robust
    host_dense_s, host_jag_s = [], []
    for _ in range(3):
        dense, td = _client_path(feats, full_b, emit_jagged=False)
        jag, tj = _client_path(feats, full_b, emit_jagged=True)
        host_dense_s.append(td)
        host_jag_s.append(tj)
    host_dense_s.sort()
    host_jag_s.sort()
    t_dense, t_jag = host_dense_s[1], host_jag_s[1]
    assert len(dense) == len(jag) and dense

    mat = DeviceMaterializer()
    dense_bytes = jag_bytes = 0
    t_xfer_dense = t_xfer_jag = 0.0
    arena_rows = 0
    for d, jg in zip(dense, jag):
        t0 = time.perf_counter()
        want = jax.device_put(d)
        jax.block_until_ready(want)
        t_xfer_dense += time.perf_counter() - t0
        dense_bytes += sum(v.nbytes for v in d.values())
        t0 = time.perf_counter()
        got = mat(jg)
        jax.block_until_ready(got)
        t_xfer_jag += time.perf_counter() - t0
        jag_bytes += jagged_batch_nbytes(jg)
        arena_rows += int(np.sum(np.minimum(jg["uih_len"], seq_len)))
        # byte identity: the device path IS the host path, just materialized
        # on the other side of the link (device_put sorts dict keys; the
        # materializer mirrors host insertion order, so compare per key)
        assert set(got) == set(d), (sorted(got), sorted(d))
        for k in d:
            assert got[k].dtype == want[k].dtype, (k, got[k].dtype)
            assert np.array_equal(np.asarray(got[k]), np.asarray(want[k])), k
    n = len(dense)

    # the two asserted claims: strictly less host featurize-stage time AND
    # strictly fewer H2D bytes per batch than the host-densify baseline
    assert jag_bytes < dense_bytes, (jag_bytes, dense_bytes)
    assert t_jag < t_dense, (t_jag, t_dense)

    roof = materialization_roofline(
        batch=full_b, seq_len=seq_len, n_traits=3,
        arena_rows=arena_rows // n, itemsize=4)
    return [BenchResult(
        "device_mat/late_materialization",
        1e6 * t_jag / n,
        {"host_dense_us_per_batch": round(1e6 * t_dense / n, 1),
         "host_jagged_us_per_batch": round(1e6 * t_jag / n, 1),
         "host_stage_speedup": round(t_dense / t_jag, 2),
         "h2d_dense_bytes_per_batch": dense_bytes // n,
         "h2d_compact_bytes_per_batch": jag_bytes // n,
         "h2d_savings_pct": round(100.0 * (1 - jag_bytes / dense_bytes), 1),
         "fill_pct": round(100.0 * roof.fill, 1),
         "xfer_dense_us_per_batch": round(1e6 * t_xfer_dense / n, 1),
         "xfer_jagged_interp_us_per_batch": round(1e6 * t_xfer_jag / n, 1),
         "roofline_t_host_us": round(1e6 * roof.t_host_path, 2),
         "roofline_t_device_us": round(1e6 * roof.t_device_path, 2),
         "roofline_device_wins": roof.device_wins},
    )]


if __name__ == "__main__":
    for r in run():
        print(r.csv())
