"""``open_feed``: compile a declarative ``DatasetSpec`` into the data plane.

One compiler replaces the two hand-wired pipelines that used to live in
``launch.steps`` (``make_device_feed`` for batch, ``make_streaming_feed`` for
streaming — both now thin deprecated shims):

  batch  spec --> work items (warehouse buckets | affinity-planned sim epochs)
                 --> DPPWorkerPool(WorkerPlan) --> RebatchingClient
  stream spec --> StreamingSession (micro-batching, backfill handoff,
                 generation-lease release, freshness)
  either --> optional DevicePrefetcher stage (cell-sharded device batches)
  --> Feed  (one protocol, consumed identically by the Trainer)

The ``sim`` argument is the data-platform handle: a ``ProductionSim`` or any
object exposing ``schema``, ``immutable`` (the store), plus ``warehouse`` /
``stream`` / ``examples`` for the matching source kinds.
"""
from __future__ import annotations

from typing import Any, List, Optional

import numpy as np

from repro.core.backoff import Backoff
from repro.core.materialize import Materializer
from repro.data.feed import Feed
from repro.data.spec import (
    DatasetSpec,
    SimSource,
    StreamSource,
    WarehouseSource,
    resume_fingerprint,
)
from repro.dpp.affinity import plan_affine
from repro.dpp.client import RebatchingClient
from repro.dpp.elastic import DPPWorkerPool
from repro.dpp.worker import WorkerPlan


def compile_worker_plan(spec: DatasetSpec, sim: Any) -> WorkerPlan:
    """The per-worker slice of a spec: projection + features + a thread-local
    materializer factory carrying the spec's consistency/generation policy."""
    schema = sim.schema
    store = sim.immutable
    features = spec.resolve_features(schema)

    def make_materializer() -> Materializer:
        return Materializer(
            store, schema,
            validate_checksum=spec.validate_checksum,
            pin_generations=spec.pin_generations,
            window_cache_size=spec.window_cache_size,
        )

    return WorkerPlan(projection=spec.tenant, feature_spec=features,
                      schema=schema, make_materializer=make_materializer)


def _retry_backoff(spec: DatasetSpec) -> Optional[Backoff]:
    """Seeded deterministic backoff between a work item's crash-recovery
    retries (the same shared helper the store failover executor uses): short
    enough not to stall a healthy pool, long enough that the second retry of
    a node-outage item usually lands after the flap, and a pure function of
    the spec seed so chaos runs stay reproducible."""
    if spec.max_item_retries <= 0:
        return None
    return Backoff(base_s=0.005, multiplier=2.0, max_s=0.1, jitter=0.5,
                   seed=spec.reshuffle_seed or 0)


def _batch_items(spec: DatasetSpec, sim: Any) -> List[list]:
    """The batch work list a spec describes (each item = one worker unit)."""
    src = spec.source
    bb = spec.base_batch_size
    if isinstance(src, WarehouseSource):
        hours = (list(src.hours) if src.hours is not None
                 else sim.warehouse.hours())
        items: List[list] = []
        for _ in range(src.epochs):
            for hour in hours:
                # buckets ARE the affinity plan: user-clustered at ingestion,
                # bucket key == storage shard key (§4.2.3)
                for bucket in sim.warehouse.iter_bucketed(hour):
                    for lo in range(0, len(bucket), bb):
                        items.append(bucket[lo:lo + bb])
        return items
    assert isinstance(src, SimSource)
    examples = list(sim.examples)
    if not examples:
        return []
    n_shards = sim.immutable.n_shards
    # honor the live generation's placement map (heavy-tail overrides): with a
    # sharded store, work items then stay NODE-local, not just shard-local
    placement = sim.immutable.live_placement()
    rng = np.random.default_rng(spec.reshuffle_seed or 0)
    items = []
    rows, epoch_i = 0, 0
    while True:
        epoch = ([examples[i] for i in rng.permutation(len(examples))]
                 if src.shuffle else list(examples))
        items.extend(plan_affine(epoch, n_shards, bb, placement=placement).items)
        rows += len(epoch)
        epoch_i += 1
        if src.min_rows is not None:
            if rows >= src.min_rows:
                break
        elif epoch_i >= src.epochs:
            break
    return items


def _skip_rows(items: List[list], n: int) -> List[list]:
    """Drop the first ``n`` example rows of a work-item list (crash resume):
    whole items that fall inside the trained prefix disappear, the boundary
    item is trimmed. Row ORDER is untouched, so an ordered feed over the
    result continues the uninterrupted run's batch sequence exactly."""
    if n <= 0:
        return items
    out: List[list] = []
    remaining = n
    for item in items:
        if remaining <= 0:
            out.append(item)
        elif len(item) <= remaining:
            remaining -= len(item)
        else:
            out.append(item[remaining:])
            remaining = 0
    return out


def _warehouse_hour_rows(spec: DatasetSpec, sim: Any) -> List[tuple]:
    """(hour, rows) pairs in replay order (epochs repeated) — the metadata
    behind the checkpoint's observability cursor (hour + intra-hour offset)."""
    src = spec.source
    hours = (list(src.hours) if src.hours is not None
             else sim.warehouse.hours())
    per_hour = [(h, sim.warehouse.hour_rows(h)) for h in hours]
    return per_hour * src.epochs


def _check_resume(spec: DatasetSpec, resume_from: dict) -> tuple:
    """Validate a checkpoint against the spec; returns (rows, batches)."""
    fp = resume_fingerprint(spec)
    got = resume_from.get("fingerprint")
    if got is not None and got != fp:
        raise ValueError(
            "resume_from was checkpointed by a different DatasetSpec "
            f"(fingerprint mismatch):\n  checkpoint: {got}\n  spec:       {fp}")
    want_kind = "stream" if isinstance(spec.source, StreamSource) else "batch"
    kind = resume_from.get("kind", want_kind)
    if kind != want_kind:
        raise ValueError(
            f"resume_from is a {kind!r} checkpoint but the spec compiles a "
            f"{want_kind!r} feed")
    if not spec.ordered:
        raise ValueError("resume requires DatasetSpec.ordered=True "
                         "(deterministic in-order placement)")
    return (int(resume_from.get("trained_rows", 0)),
            int(resume_from.get("trained_batches", 0)))


def cell_input_sharding(cell: Any, mesh: Any):
    """NamedSharding tree for a cell's batch argument (device feed target)."""
    if cell is None or mesh is None:
        return None
    import jax
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    batch_spec = cell.in_shardings[-1]
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        batch_spec, is_leaf=lambda x: isinstance(x, P))


def open_feed(
    spec: DatasetSpec,
    sim: Any,
    *,
    cell: Any = None,
    mesh: Any = None,
    prep_fn=None,
    controller: Any = None,
    resume_from: Optional[dict] = None,
) -> Feed:
    """Compile ``spec`` against ``sim``'s data platform and start the feed.

    * ``cell``/``mesh`` (optional) — target the device-prefetch stage at a
      ``launch.steps.Cell``'s batch shardings (device batches land laid out
      exactly as the jit'd step expects);
    * ``prep_fn`` — model-specific host transform; runs inside the prefetch
      thread when there is one, else on the consumer's ``get``;
    * ``controller`` — optional ``ElasticController`` for live pool resizing;
    * ``resume_from`` — a ``Feed.checkpoint()`` dict (saved by the
      ``CheckpointManager`` as the model checkpoint's ``feed_state`` sidecar):
      the compiled feed produces exactly the examples the killed run had NOT
      yet trained — batch feeds skip the trained row prefix of the canonical
      item order and resume the reshuffle emit counter; streaming feeds apply
      the checkpoint's ``ReplayFilter`` chain to the warehouse re-replay and
      dedupe live ids below the watermark (exactly-once, §10).

    Returns a started ``Feed``; batch and streaming specs yield the same
    protocol. The caller owns shutdown: ``close()`` (or iterate to
    exhaustion + ``join()``).
    """
    plan = compile_worker_plan(spec, sim)
    tel = spec.telemetry
    if tel is not None:
        # attach to the store tier FIRST (generation flips / lease events /
        # breaker listeners / RTT histogram re-home); reaches the real store
        # through fault-injection wrappers, whose __setattr__ delegates
        sim.immutable.telemetry = tel
    # prefetch_depth=None means auto (device stage iff a cell is targeted);
    # an explicit 0 FORCES the host feed even with a cell
    depth = (spec.prefetch_depth if spec.prefetch_depth is not None
             else (2 if cell is not None else 0))
    sharding = cell_input_sharding(cell, mesh)
    base_rows, base_batches = (
        _check_resume(spec, resume_from) if resume_from else (0, 0))

    if isinstance(spec.source, StreamSource):
        from repro.streaming.backfill import ReplayFilter
        from repro.streaming.session import StreamingSession
        from repro.streaming.source import MicroBatchConfig

        filters = []
        if resume_from:
            stream_state = resume_from.get("stream") or {}
            filters = [ReplayFilter.from_state(d)
                       for d in stream_state.get("filters", [])]
            if not spec.source.backfill:
                raise ValueError(
                    "streaming resume requires StreamSource(backfill=True): "
                    "the warehouse leg is the durable replay source")
        session = StreamingSession(
            sim.stream, plan,
            full_batch_size=spec.batch_size,
            micro_batch=MicroBatchConfig(
                max_examples=spec.source.micro_batch_examples,
                max_delay_s=spec.source.micro_batch_delay_s),
            n_workers=spec.n_workers,
            controller=controller,
            shuffle_seed=spec.reshuffle_seed,
            buffer_batches=spec.buffer_batches,
            backfill_from=sim.warehouse if spec.source.backfill else None,
            ordered=spec.ordered,
            max_item_retries=spec.max_item_retries,
            retry_backoff=_retry_backoff(spec),
            emit_seq_start=base_batches,
            resume_filters=filters,
            backfill_start_hour=spec.source.backfill_start_hour,
            backfill_end_hour=spec.source.backfill_end_hour,
        )
        if spec.ordered and session.coordinator is not None:
            # BEFORE start, and only when the feed will actually be
            # checkpointable (the Feed's pops are what bound this FIFO): the
            # resume cursor reads every emitted batch's row count from it
            # (prep_fn may reshape batches)
            session.client.track_emitted_rows = True
        if tel is not None:
            session.telemetry = tel    # before start(): spans ride the FIFOs
        session.start()
        prefetcher = None
        inner: Any = session
        if depth > 0:
            from repro.dpp.prefetch import DevicePrefetcher

            prefetcher = DevicePrefetcher(session, depth=depth,
                                          sharding=sharding, prep_fn=prep_fn)
            if tel is not None:
                prefetcher.telemetry = tel
            inner = prefetcher
        resume_meta = None
        if spec.ordered and session.coordinator is not None:
            resume_meta = {"fingerprint": resume_fingerprint(spec),
                           "base_rows": base_rows,
                           "base_batches": base_batches}
        return Feed(inner, session=session, prefetcher=prefetcher,
                    prep_fn=prep_fn, spec=spec, resume_meta=resume_meta,
                    telemetry=tel, store=sim.immutable)

    # device-side late materialization: only when a device-prefetch stage
    # exists to run the fused kernel and no prep_fn expects dense host
    # batches — otherwise fall back to host densify (DESIGN §3 fallback
    # rules; streaming sessions above always take the host path for now)
    dev_mat = bool(spec.device_materialize) and depth > 0 and prep_fn is None
    client = RebatchingClient(spec.batch_size,
                              buffer_batches=spec.buffer_batches,
                              shuffle_seed=spec.reshuffle_seed,
                              emit_seq_start=base_batches,
                              emit_jagged=dev_mat)
    # BEFORE the pool starts: the Feed's resume cursor reads every emitted
    # batch's row count from this FIFO (prep_fn may reshape batches)
    client.track_emitted_rows = spec.ordered
    client.telemetry = tel
    pool = DPPWorkerPool.from_plan(plan, client, n_workers=spec.n_workers,
                                   controller=controller,
                                   ordered=spec.ordered,
                                   max_item_retries=spec.max_item_retries,
                                   retry_backoff=_retry_backoff(spec))
    if tel is not None:
        pool.telemetry = tel           # before start(): items mint spans
    pool.start(_skip_rows(_batch_items(spec, sim), base_rows))
    prefetcher = None
    inner = client
    if depth > 0:
        from repro.dpp.prefetch import DevicePrefetcher

        materialize = None
        if dev_mat:
            from repro.dpp.device_mat import DeviceMaterializer

            materialize = DeviceMaterializer(sharding=sharding)
        prefetcher = DevicePrefetcher(client, depth=depth, sharding=sharding,
                                      prep_fn=prep_fn,
                                      materialize=materialize)
        if tel is not None:
            prefetcher.telemetry = tel
        inner = prefetcher
    resume_meta = None
    if spec.ordered:
        resume_meta = {"fingerprint": resume_fingerprint(spec),
                       "base_rows": base_rows,
                       "base_batches": base_batches}
        if isinstance(spec.source, WarehouseSource):
            resume_meta["hour_rows"] = _warehouse_hour_rows(spec, sim)
    return Feed(inner, client=client, pool=pool, prefetcher=prefetcher,
                prep_fn=prep_fn, spec=spec, resume_meta=resume_meta,
                telemetry=tel, store=sim.immutable)
