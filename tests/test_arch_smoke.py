"""Per-architecture smoke tests: instantiate the REDUCED config of every
assigned arch (+ the paper's own), run one step per shape kind on CPU via the
same cell builders the dry-run uses, assert output shapes + finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.launch.mesh import make_test_mesh, set_mesh
from repro.launch.sampling import sample_args
from repro.launch.steps import build_cell

ARCHS = list_archs()


def _finite(tree) -> bool:
    return all(
        bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
        for x in jax.tree.leaves(tree)
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)
    )


def _run(arch_id: str, shape_name: str):
    spec = get_arch(arch_id)
    mesh = make_test_mesh(1)
    cell = build_cell(spec, shape_name, mesh, use_full=False)
    args = sample_args(cell, spec.family, seed=0)
    with set_mesh(mesh):
        out = jax.jit(cell.step_fn)(*args)
    return cell, out


# -- one train-shape test per arch (all 11) -----------------------------------

TRAIN_SHAPE = {
    "lm": "train_4k",
    "gnn": "full_graph_sm",
    "recsys": "train_batch",
}


@pytest.mark.parametrize("arch_id", ARCHS)
def test_train_step_smoke(arch_id):
    spec = get_arch(arch_id)
    cell, out = _run(arch_id, TRAIN_SHAPE[spec.family])
    params, opt_state, metrics = out
    assert float(metrics["loss"]) > 0
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert _finite(params), "non-finite params after update"
    assert int(opt_state.step) == 1


# -- serving kinds -------------------------------------------------------------

LM_ARCHS = [a for a in ARCHS if get_arch(a).family == "lm"]
RECSYS_ARCHS = [a for a in ARCHS if get_arch(a).family == "recsys"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_prefill_smoke(arch_id):
    cell, (logits, cache) = _run(arch_id, "prefill_32k")
    cfg = cell.meta["cfg"]
    assert logits.shape[-1] == cfg.vocab
    assert _finite(logits)
    assert _finite(cache)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_lm_decode_smoke(arch_id, shape):
    cell, (logits, cache) = _run(arch_id, shape)
    cfg = cell.meta["cfg"]
    assert logits.shape[-1] == cfg.vocab
    assert _finite(logits)


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_recsys_serve_smoke(arch_id):
    cell, out = _run(arch_id, "serve_p99")
    assert _finite(out)
    b = cell.meta["batch"]
    assert out.shape[0] == b


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_recsys_retrieval_smoke(arch_id):
    cell, out = _run(arch_id, "retrieval_cand")
    assert _finite(out)
    n = cell.meta["n_candidates"]
    assert out.shape[-1] == n or out.shape[0] == n


def test_gnn_all_shapes_smoke():
    for shape in ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"]:
        cell, (params, opt, metrics) = _run("meshgraphnet", shape)
        assert np.isfinite(float(metrics["loss"])), shape


def test_gnn_neighbor_sampler_real():
    """minibatch_lg path: sample a real subgraph from a random parent graph and
    run a train step on it."""
    from repro.models.gnn import CSRGraph, sample_subgraph

    rng = np.random.default_rng(0)
    n_parent, e_parent = 500, 4000
    senders = rng.integers(0, n_parent, e_parent)
    receivers = rng.integers(0, n_parent, e_parent)
    g = CSRGraph(n_parent, senders, receivers)
    seeds = rng.choice(n_parent, size=16, replace=False)
    sub = sample_subgraph(g, seeds, fanouts=(3, 2), rng=rng)
    assert len(sub["senders"]) == len(sub["receivers"]) == 16 * 3 + 16 * 3 * 2
    assert sub["senders"].max() < len(sub["nodes"])
    # all sampled edges exist in the parent graph
    parent_edges = set(zip(senders.tolist(), receivers.tolist()))
    ns = sub["nodes"]
    for s, r, ok in zip(sub["senders"], sub["receivers"], sub["edge_mask"]):
        if ok:
            assert (int(ns[s]), int(ns[r])) in parent_edges
