"""Declarative read path (DatasetSpec -> open_feed -> Feed) + multi-tenant
co-scan planning.

Covers:
  * canonical trait ordering/dedup in ``TenantProjection.traits_for`` (the
    override vs schema-default asymmetry regression);
  * ``ScanRequest`` construction-time validation (with the legitimate
    pre-first-compaction empty-window sentinel);
  * store-level union-projection planning: containment subsumption in
    ``plan()``/``execute_plan()`` and the metadata-exact ``estimate_scan``;
  * co-scan equivalence: ``MultiTenantPlanner``/``materialize_multi`` output
    is byte-identical to per-tenant solo materialization, across pinned vs
    live generation policies and under a concurrent compaction flip (the
    PR 3 stress-churn harness);
  * ``open_feed`` compiling batch (sim + warehouse) AND streaming specs into
    the ONE ``Feed`` protocol, consumed end-to-end by the ``Trainer``;
  * the deprecated ``make_device_feed``/``make_streaming_feed`` shims keep
    working (DeprecationWarning + the same Feed protocol).
"""
import threading
import time

import numpy as np
import pytest

from repro.core import events as ev
from repro.core.materialize import Materializer, TenantShareStats
from repro.core.projection import TenantProjection, project_view
from repro.core.simulation import ProductionSim, SimConfig
from repro.data import (
    DatasetSpec,
    Feed,
    MultiTenantPlanner,
    SimSource,
    StreamSource,
    WarehouseSource,
    open_feed,
)
from repro.dpp.featurize import FeatureSpec
from repro.storage.immutable_store import ScanRequest

from conftest import make_sim

SCHEMA = ev.default_schema()


def _sim(users=6, days=2, seed=0, req=3, pin=True):
    # shared fixture builder (tests/conftest.py); this file never audits, so
    # references are skipped
    return make_sim(users=users, days=days, seed=seed, req=req, pin=pin,
                    capture_reference=False)


# ---------------------------------------------------------------------------
# satellite: canonical trait ordering (override vs schema-default asymmetry)
# ---------------------------------------------------------------------------

def test_traits_for_canonical_ordering_and_dedupe():
    default = TenantProjection("a", 8, ("core",))
    # same trait SET, pathologically ordered + duplicated override
    override = TenantProjection(
        "b", 8, ("core",),
        traits_per_group={"core": ("item_id", "timestamp", "item_id",
                                   "action_type")})
    canonical = ("timestamp", "item_id", "action_type")
    assert default.traits_for(SCHEMA, "core") == canonical
    assert override.traits_for(SCHEMA, "core") == canonical
    # the regression: equivalent projections must order all_traits identically
    assert default.all_traits(SCHEMA) == override.all_traits(SCHEMA)
    # timestamp is injected for overrides that omit it, first
    no_ts = TenantProjection("c", 8, ("core",),
                             traits_per_group={"core": ("item_id",)})
    assert no_ts.traits_for(SCHEMA, "core") == ("timestamp", "item_id")
    # non-schema extras keep declaration order, after schema-ordered traits
    extra = TenantProjection("d", 8, ("core",),
                             traits_per_group={"core": ("zz", "item_id")})
    assert extra.traits_for(SCHEMA, "core") == ("timestamp", "item_id", "zz")


def test_projection_hashable_and_union():
    a = TenantProjection("a", 8, ["core"],
                         traits_per_group={"core": ["timestamp", "item_id"]})
    b = TenantProjection("a", 8, ("core",),
                         traits_per_group={"core": ("timestamp", "item_id")})
    assert a == b and hash(a) == hash(b)     # list inputs normalized
    assert len({a, b}) == 1
    long = TenantProjection("long", 64, ("core", "sideinfo"))
    short = TenantProjection("short", 8, ("core",),
                             traits_per_group={"core": ("timestamp",
                                                        "item_id")})
    u = TenantProjection.union([long, short], SCHEMA)
    assert u.seq_len == 64
    assert u.feature_groups == ("core", "sideinfo")
    # per-group union covers every tenant's traits, canonically ordered
    assert u.traits_for(SCHEMA, "core") == ("timestamp", "item_id",
                                            "action_type")
    assert set(short.traits_for(SCHEMA, "core")) <= set(
        u.traits_for(SCHEMA, "core"))
    # union of one tenant is that tenant
    assert TenantProjection.union([short], SCHEMA) is short
    # a hashable projection must be mutation-proof: its mapping is read-only
    with pytest.raises(TypeError):
        a.traits_per_group["core"] = ("timestamp",)


# ---------------------------------------------------------------------------
# satellite: ScanRequest validates at the API boundary
# ---------------------------------------------------------------------------

def test_scan_request_validates_on_construction():
    with pytest.raises(ValueError, match="max_events"):
        ScanRequest(0, "core", 0, 10, max_events=-2)
    with pytest.raises(ValueError, match="generation"):
        ScanRequest(0, "core", 0, 10, generation=-3)
    # the legitimate empty-window sentinel: end_ts < 0 means "no immutable
    # watermark yet" (examples logged before the first compaction)
    ScanRequest(0, "core", start_ts=5, end_ts=-1)
    ScanRequest(0, "core", 0, 10, max_events=-1, generation=-1)


def test_inverted_bounds_scan_empty_not_raise():
    # start_ts > end_ts is a legitimate empty-window request, NOT an error:
    # the snapshotter emits it whenever a user's immutable watermark is older
    # than request_ts - lookback (a user returning after a long idle).
    sim = _sim(days=2, pin=False)
    store = sim.immutable
    uid = sim.examples[-1].user_id
    wm = store.watermark(uid)
    assert wm >= 0
    got = store.scan(ScanRequest(uid, "core", start_ts=wm + 1_000, end_ts=wm))
    assert ev.batch_len(got) == 0


def test_snapshotter_survives_watermark_older_than_lookback():
    # Regression: with a 1-day lookback, day-2 requests put start_ts
    # (request_ts - lookback) past the day-1 consolidation watermark, so
    # _fetch_both_tiers builds ScanRequests with start_ts > end_ts >= 0.
    # This used to raise ValueError("inverted scan bounds") from
    # ScanRequest.__post_init__; it must yield an empty immutable window.
    cfg = SimConfig(
        stream=ev.StreamConfig(n_users=4, n_items=500, days=4,
                               events_per_user_day_mean=10.0, seed=1),
        stripe_len=16,
        requests_per_user_day=2,
        lookback_ms=1 * ev.MS_PER_DAY,
        seed=1,
        pin_generations=False,
    )
    sim = ProductionSim(cfg)
    sim.run_days(2, capture_reference=False)
    assert sim.examples
    # and the lookback contract holds: the mutable read is clamped to the
    # window start, so the returning-idle user's UIH never contains events
    # older than request_ts - lookback (which an unclamped (watermark,
    # request_ts] read would feed it)
    for exm in sim.examples:
        mut = exm.mutable_uih
        if mut and ev.batch_len(mut):
            assert int(mut["timestamp"].min()) >= exm.request_ts - cfg.lookback_ms


# ---------------------------------------------------------------------------
# store: union-projection planning (subsumption) + metadata-exact estimates
# ---------------------------------------------------------------------------

def test_plan_subsumes_contained_requests_byte_identically():
    sim = _sim(days=2, pin=False)
    store = sim.immutable
    uid = sim.examples[-1].user_id
    end = store.watermark(uid)
    wide = ScanRequest(uid, "core", 0, end)                       # unbounded
    narrow = ScanRequest(uid, "core", 0, end, max_events=4,
                         traits=("timestamp", "item_id"))
    plan = store.plan([wide, narrow])
    assert plan.subsumed == 1 and len(plan.shard_groups) == 1
    before = store.stats.snapshot()
    got_wide, got_narrow = store.execute_plan(plan)
    d = store.stats.delta(before)
    assert d.subsumed_hits == 1
    assert d.requests == 1            # only the covering request scanned
    # byte-identical to executing each request alone
    solo_narrow = store.scan(narrow)
    assert list(got_narrow.keys()) == list(solo_narrow.keys())
    for k in solo_narrow:
        assert got_narrow[k].dtype == solo_narrow[k].dtype
        assert np.array_equal(got_narrow[k], solo_narrow[k])
    solo_wide = store.scan(wide)
    for k in solo_wide:
        assert np.array_equal(got_wide[k], solo_wide[k])
    # non-contained requests (disjoint traits) are NOT subsumed
    other = ScanRequest(uid, "core", 0, end, max_events=4,
                        traits=("timestamp", "action_type"))
    p2 = store.plan([narrow, other])
    assert p2.subsumed == 0


def test_estimate_scan_matches_actual_io():
    sim = _sim(days=2, pin=False)
    store = sim.immutable
    store.decode_cache = None
    for exm in sim.examples[-6:]:
        v = exm.version
        req = ScanRequest(exm.user_id, "core", v.start_ts, v.end_ts,
                          max_events=32)
        est_stripes, est_bytes = store.estimate_scan(req)
        before = store.stats.snapshot()
        store.scan(req)
        d = store.stats.delta(before)
        assert (d.stripes_read, d.bytes_scanned) == (est_stripes, est_bytes)


# ---------------------------------------------------------------------------
# co-scan equivalence: byte-identical to solo, pinned vs live, under churn
# ---------------------------------------------------------------------------

def _tenants():
    return [
        TenantProjection("wide", 48, ("core", "engagement", "sideinfo")),
        TenantProjection("mid", 16, ("core", "engagement")),
        TenantProjection("narrow", 6, ("core",),
                         traits_per_group={"core": ("timestamp", "item_id")}),
    ]


def _assert_views_equal(a, b, ctx):
    assert list(a.keys()) == list(b.keys()), (ctx, sorted(a), sorted(b))
    for k in a:
        assert a[k].dtype == b[k].dtype, (ctx, k)
        assert np.array_equal(a[k], b[k]), (ctx, k)


@pytest.mark.parametrize("pin", [True, False], ids=["pinned", "live"])
def test_coscan_byte_identical_to_solo_under_compaction_flip(pin):
    """Property: every tenant's co-scan output == its solo materialization,
    for pinned AND live generation policies, while compaction churns NEW
    generations concurrently (the PR 3 stress harness: re-compactions at the
    established watermark — identical windows, fresh generation ids)."""
    sim = _sim(users=6, days=2, seed=13, req=4, pin=True)
    tenants = _tenants()
    wm_box = [sim.compaction_watermark]
    stop = threading.Event()

    def churn():
        while not stop.is_set():
            sim.run_compaction(wm_box[0], evict=False)
            time.sleep(0.003)

    th = threading.Thread(target=churn, daemon=True)
    th.start()
    try:
        multi = Materializer(sim.immutable, sim.schema, pin_generations=pin)
        solos = {t.name: Materializer(sim.immutable, sim.schema,
                                      pin_generations=pin) for t in tenants}
        share = TenantShareStats()
        for lo in range(0, len(sim.examples), 8):
            batch = sim.examples[lo:lo + 8]
            got = multi.materialize_multi(batch, tenants, share_stats=share)
            for t in tenants:
                want = solos[t.name].materialize_batch(batch, t)
                for i, (a, b) in enumerate(zip(got[t.name], want)):
                    _assert_views_equal(a, b, (t.name, pin, lo + i))
        assert share.co_scan_windows > 0
        assert share.bytes_saved_vs_solo > 0   # nested tenants => real saving
        if pin:
            # leases held by the publisher => the pinned path really served
            assert multi.stats.pinned_windows > 0
    finally:
        stop.set()
        th.join()
    # generations actually flipped during the run
    assert sim.immutable.generation >= 2


def test_project_view_carves_solo_fetch():
    sim = _sim(days=2, pin=False)
    tenants = _tenants()
    union = TenantProjection.union(tenants, SCHEMA)
    mat = Materializer(sim.immutable, sim.schema)
    exm = max(sim.examples, key=lambda e: e.version.seq_len)
    wide = mat._fetch_immutable(exm, union)
    for t in tenants:
        carved = project_view(wide, t, SCHEMA)
        solo = mat._fetch_immutable(exm, t)
        _assert_views_equal(
            ev.project_traits(solo, [c for c in t.all_traits(SCHEMA)
                                     if c in solo]),
            carved, t.name)


# ---------------------------------------------------------------------------
# DatasetSpec: frozen, hashable, validated
# ---------------------------------------------------------------------------

def test_dataset_spec_validation_and_hash():
    t = TenantProjection("t", 8, ("core",))
    a = DatasetSpec(tenant=t, source=SimSource(), batch_size=8)
    b = DatasetSpec(tenant=t, source=SimSource(), batch_size=8)
    assert a == b and len({a, b}) == 1
    with pytest.raises(ValueError, match="consistency"):
        DatasetSpec(tenant=t, consistency="sometimes")
    with pytest.raises(ValueError, match="generations"):
        DatasetSpec(tenant=t, generations="latest")
    with pytest.raises(ValueError, match="batch sizes"):
        DatasetSpec(tenant=t, batch_size=0)
    # derived featurization: every non-timestamp projected trait
    fs = a.resolve_features(SCHEMA)
    assert fs.seq_len == 8
    assert fs.uih_traits == ("item_id", "action_type")
    assert a.validate_checksum is False and a.pin_generations is False
    audit = DatasetSpec(tenant=t, consistency="audit", generations="pinned")
    assert audit.validate_checksum and audit.pin_generations
    with pytest.raises(ValueError, match="prefetch_depth"):
        DatasetSpec(tenant=t, prefetch_depth=-1)


def test_open_feed_honors_explicit_prefetch_depth_zero():
    """prefetch_depth=0 forces the host feed even when a cell is targeted
    (None means auto)."""
    sim = _sim(users=4, days=1, pin=False)
    feed = open_feed(_tiny_spec(SimSource(), prefetch_depth=0), sim)
    assert feed.prefetcher is None
    for b in feed:
        feed.recycle(b)
    feed.join()


def test_open_feed_device_materialize_byte_identical():
    """DESIGN §3 acceptance at the open_feed level: the SAME spec with
    ``device_materialize=True`` (jagged emission + on-device fused densify in
    the prefetch stage) yields batch-for-batch identical device batches to
    the host-densify path, while shipping fewer H2D bytes."""
    import jax

    host_feed = open_feed(
        _tiny_spec(SimSource(), prefetch_depth=2), _sim(pin=False))
    want = [b for b in host_feed]
    host_feed.close(timeout=10.0)
    host_bytes = host_feed.stats().client.h2d_bytes
    assert want and host_bytes > 0

    dev_feed = open_feed(
        _tiny_spec(SimSource(), prefetch_depth=2, device_materialize=True),
        _sim(pin=False))
    got = [b for b in dev_feed]
    dev_feed.close(timeout=10.0)
    dev_bytes = dev_feed.stats().client.h2d_bytes

    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert set(g) == set(w)          # device_put sorts dict keys
        for k in w:
            assert g[k].dtype == w[k].dtype, k
            np.testing.assert_array_equal(np.asarray(g[k]), np.asarray(w[k]),
                                          err_msg=k)
    # the flag is operational, not dataset identity: same resume fingerprint
    from repro.data.spec import resume_fingerprint
    assert (resume_fingerprint(_tiny_spec(SimSource(), prefetch_depth=2))
            == resume_fingerprint(_tiny_spec(SimSource(), prefetch_depth=2,
                                             device_materialize=True)))
    assert 0 < dev_bytes < host_bytes


def test_multitenant_planner_rejects_mixed_policies():
    t1 = TenantProjection("a", 8, ("core",))
    t2 = TenantProjection("b", 8, ("core",))
    sim = _sim(days=1, pin=False)
    with pytest.raises(ValueError, match="policy"):
        MultiTenantPlanner(
            [DatasetSpec(tenant=t1, consistency="audit"),
             DatasetSpec(tenant=t2, consistency="off")],
            sim.immutable, sim.schema)
    with pytest.raises(ValueError, match="unique"):
        MultiTenantPlanner([t1, t1], sim.immutable, sim.schema)


# ---------------------------------------------------------------------------
# open_feed: batch + warehouse + streaming through the ONE Feed protocol
# ---------------------------------------------------------------------------

def _tiny_spec(source, **kw):
    tenant = TenantProjection(
        "t", 16, ("core",),
        traits_per_group={"core": ("timestamp", "item_id", "action_type")})
    features = FeatureSpec(seq_len=16, uih_traits=("item_id", "action_type"))
    kw.setdefault("batch_size", 8)
    kw.setdefault("base_batch_size", 4)
    kw.setdefault("n_workers", 2)
    return DatasetSpec(tenant=tenant, source=source, features=features, **kw)


def test_open_feed_warehouse_replay_covers_all_examples():
    sim = _sim(users=6, days=2, pin=False)
    feed = open_feed(_tiny_spec(WarehouseSource()), sim)
    assert isinstance(feed, Feed)
    rows = 0
    users = []
    for b in feed:
        rows += len(b["uih_len"])
        users.extend(b["user_id"].tolist())
        feed.recycle(b)
    feed.join()
    assert feed.drained
    total = len(sim.examples)
    assert rows == total
    assert sorted(users) == sorted(e.user_id for e in sim.examples)
    st = feed.stats()
    assert st.workers.examples == total
    assert st.client.full_batches > 0


def test_open_feed_close_drains_early_exit():
    sim = _sim(users=6, days=2, pin=False)
    feed = open_feed(_tiny_spec(SimSource(epochs=2)), sim)
    first = feed.get(timeout=10.0)
    assert first is not None
    feed.close(timeout=10.0)   # walk away after one batch: must not hang
    assert feed._joiner is not None and not feed._joiner.is_alive()


def test_trainer_runs_batch_and_stream_through_one_feed_protocol():
    """Acceptance: the Trainer consumes batch AND streaming feeds through the
    single Feed protocol returned by open_feed."""
    import jax.numpy as jnp

    from repro.train.train_loop import Trainer, TrainerConfig

    def loss_fn(params, b):
        score = jnp.sum(b["uih_item_id"] * params["w"], axis=1)
        return jnp.mean((score - b["label_click"]) ** 2)

    params = {"w": jnp.zeros((16,), jnp.float32)}

    # batch: host feed (no device prefetch stage)
    sim = _sim(users=6, days=2, pin=False)
    feed = open_feed(_tiny_spec(SimSource(min_rows=64)), sim)
    tr = Trainer(loss_fn, params, TrainerConfig(log_every=1000))
    tr.fit(feed, max_steps=3)
    assert tr.step == 3
    feed.close(timeout=10.0)

    # streaming: pinned generations + device prefetch stage, same protocol
    sim2 = _sim(users=6, days=2, pin=True)
    sim2.stream.close()   # backlog only: the feed drains it and ends
    feed2 = open_feed(
        _tiny_spec(StreamSource(backfill=False), consistency="audit",
                   generations="pinned", prefetch_depth=2),
        sim2)
    tr2 = Trainer(loss_fn, params, TrainerConfig(log_every=1000))
    tr2.fit(feed2)        # runs until the stream drains
    assert tr2.step >= 1
    assert feed2.drained
    feed2.close()
    st = feed2.stats()
    assert st.freshness is not None         # streaming-only counters surfaced
    assert st.workers.examples == len(sim2.examples)
    # every lease released once the stream drained
    assert sim2.stream.pending_leases() == 0


# ---------------------------------------------------------------------------
# satellite: deprecated make_*_feed shims keep working
# ---------------------------------------------------------------------------

def test_make_device_feed_shim_warns_and_returns_feed_protocol():
    from repro.launch.steps import make_device_feed

    host = [{"x": np.arange(4, dtype=np.int32)} for _ in range(3)]
    with pytest.warns(DeprecationWarning, match="open_feed"):
        feed = make_device_feed(None, host, mesh=None, depth=1)
    assert isinstance(feed, Feed)
    out = list(feed)
    assert len(out) == 3
    assert feed.drained
    feed.record_train_step(0.001)           # protocol surface intact
    assert feed.stats().client.full_batches == 3
    # legacy contract: `.stats` also reads as the live ClientStats attribute
    # (old DevicePrefetcher call sites did `feed.stats.starvation_pct`)
    assert feed.stats.full_batches == 3
    assert feed.stats.starvation_pct >= 0.0
    feed.stats.starved_time_s += 0.0        # legacy in-place mutation works
    feed.close()


def test_shim_feed_close_drains_caller_owned_pool():
    # Regression: a shim Feed wraps a BARE client (pool owned by the caller,
    # as at legacy call sites mid-migration). close() must still drain the
    # host pipeline so workers parked on the bounded slot queues exit —
    # otherwise the caller's own pool.join() hangs.
    from repro.data.compile import _batch_items, compile_worker_plan
    from repro.dpp.client import RebatchingClient
    from repro.dpp.elastic import DPPWorkerPool
    from repro.launch.steps import make_device_feed

    sim = _sim(users=6, days=2, pin=False)
    spec = _tiny_spec(WarehouseSource(), buffer_batches=1)
    client = RebatchingClient(spec.batch_size, buffer_batches=1)
    pool = DPPWorkerPool.from_plan(compile_worker_plan(spec, sim), client,
                                   n_workers=2)
    pool.start(_batch_items(spec, sim))
    with pytest.warns(DeprecationWarning, match="open_feed"):
        feed = make_device_feed(None, client, mesh=None, depth=1)
    assert feed.client is client and feed.pool is None
    assert feed.get(timeout=10.0) is not None   # consume one, walk away early
    feed.close(timeout=30.0)                    # must unpark the workers
    joined = threading.Event()

    def _join():
        pool.join()
        joined.set()

    threading.Thread(target=_join, daemon=True).start()
    assert joined.wait(timeout=30.0), "caller-owned pool.join() hung"


def test_make_streaming_feed_shim_warns_and_returns_feed_protocol():
    from repro.launch.steps import make_streaming_feed
    from repro.streaming.session import StreamingSession
    from repro.streaming.source import MicroBatchConfig

    sim = _sim(users=4, days=1, pin=True)
    sim.stream.close()
    spec = _tiny_spec(StreamSource())
    from repro.data import compile_worker_plan

    session = StreamingSession(
        sim.stream, compile_worker_plan(spec, sim), full_batch_size=8,
        micro_batch=MicroBatchConfig(max_examples=4, max_delay_s=0.02),
        n_workers=1)
    with pytest.warns(DeprecationWarning, match="open_feed"):
        feed = make_streaming_feed(None, session, mesh=None, depth=1)
    assert isinstance(feed, Feed)
    rows = sum(len(b["uih_len"]) for b in feed)
    assert rows == len(sim.examples)
    assert feed.drained
    feed.close()
    assert sim.stream.pending_leases() == 0
