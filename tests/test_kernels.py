"""Per-kernel allclose sweeps vs pure-jnp oracles (interpret mode on CPU).

Hypothesis sweeps cover ragged lengths, dtypes, and degenerate cases per the
assignment: 'for each Pallas kernel, sweep shapes/dtypes and assert_allclose
against the ref.py pure-jnp oracle'."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to a fixed-examples sweep (see the shim)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.delta_decode import ops as dd_ops
from repro.kernels.delta_decode import ref as dd_ref
from repro.kernels.embedding_bag import ops as eb_ops
from repro.kernels.embedding_bag import ref as eb_ref
from repro.kernels.jagged import ops as jg_ops
from repro.kernels.jagged import ref as jg_ref


# ---------------------------------------------------------------------------
# delta_decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,n", [(1, 16), (3, 100), (8, 128), (16, 384), (5, 7)])
def test_delta_decode_shapes(b, n):
    rng = np.random.default_rng(b * 1000 + n)
    deltas = rng.integers(0, 10_000, size=(b, n)).astype(np.int32)
    deltas[:, 0] = 0
    bases = rng.integers(0, 1 << 20, size=(b,)).astype(np.int32)
    got = dd_ops.delta_decode(jnp.asarray(deltas), jnp.asarray(bases))
    want = dd_ref.delta_decode(jnp.asarray(deltas), jnp.asarray(bases))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 12),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**16),
)
def test_delta_decode_property(b, n, seed):
    rng = np.random.default_rng(seed)
    deltas = rng.integers(0, 1 << 16, size=(b, n)).astype(np.int32)
    bases = rng.integers(-(1 << 20), 1 << 20, size=(b,)).astype(np.int32)
    got = dd_ops.delta_decode(jnp.asarray(deltas), jnp.asarray(bases))
    want = dd_ref.delta_decode(jnp.asarray(deltas), jnp.asarray(bases))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_delta_decode_matches_columnar_codec():
    """End-to-end: the kernel decodes what the storage codec encoded."""
    from repro.core import events as ev
    from repro.storage import columnar

    rng = np.random.default_rng(0)
    ts = np.sort(rng.integers(0, 1 << 30, size=200)).astype(np.int64)
    payload, meta = columnar.encode_column(ts, ev.DENSE_MONOTONE)
    inner = dict(meta); inner["codec"] = meta["inner"]
    deltas = columnar._unpack_unsigned(payload, inner, np.int64)
    got = dd_ops.delta_decode(
        jnp.asarray(deltas[None, :].astype(np.int32)),
        jnp.asarray(np.zeros(1, np.int32)),
    )
    np.testing.assert_array_equal(
        np.asarray(got)[0] + meta["base"], ts)


# ---------------------------------------------------------------------------
# jagged_to_padded
# ---------------------------------------------------------------------------

def _jagged_case(b, max_len, d, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, 2 * max_len, size=b)
    offsets = np.zeros(b + 1, np.int32)
    np.cumsum(lens, out=offsets[1:])
    values = rng.standard_normal((int(offsets[-1]), d)).astype(dtype)
    if values.shape[0] == 0:
        values = np.zeros((0, d), dtype)
    return jnp.asarray(values), jnp.asarray(offsets)


@pytest.mark.parametrize("b,max_len,d", [(4, 8, 16), (2, 32, 128), (7, 5, 64),
                                         (1, 16, 200), (8, 64, 32)])
def test_jagged_to_padded_shapes(b, max_len, d):
    values, offsets = _jagged_case(b, max_len, d, seed=b * 7 + d)
    got = jg_ops.jagged_to_padded(values, offsets, max_len)
    want = jg_ref.jagged_to_padded(values, offsets, max_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 10),
    max_len=st.integers(1, 48),
    d=st.sampled_from([1, 8, 64, 130]),
    seed=st.integers(0, 2**16),
    dtype=st.sampled_from([np.float32, np.int32]),
)
def test_jagged_to_padded_property(b, max_len, d, seed, dtype):
    values, offsets = _jagged_case(b, max_len, d, seed, dtype)
    got = jg_ops.jagged_to_padded(values, offsets, max_len)
    want = jg_ref.jagged_to_padded(values, offsets, max_len)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_jagged_matches_featurizer_contract():
    """Kernel output == host-side DPP featurizer padding (right-aligned)."""
    from repro.dpp.featurize import pad_sequences

    rng = np.random.default_rng(3)
    seqs = [rng.integers(0, 100, size=n).astype(np.int64)
            for n in [3, 0, 12, 7]]
    offsets = np.zeros(5, np.int32)
    np.cumsum([len(s) for s in seqs], out=offsets[1:])
    values = np.concatenate(seqs).astype(np.float32)[:, None]
    got = jg_ops.jagged_to_padded(jnp.asarray(values), jnp.asarray(offsets), 8)
    want = pad_sequences(seqs, 8).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(got)[:, :, 0], want)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,l", [(64, 16, 4, 8), (1000, 128, 8, 20),
                                     (37, 200, 3, 5), (256, 64, 16, 1)])
def test_embedding_bag_shapes(v, d, b, l):
    rng = np.random.default_rng(v + d)
    table = rng.standard_normal((v, d)).astype(np.float32)
    ids = rng.integers(0, v, size=(b, l)).astype(np.int32)
    mask = (rng.random((b, l)) < 0.8)
    got = eb_ops.embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                               jnp.asarray(mask))
    want = eb_ref.embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                                jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    v=st.integers(2, 500),
    d=st.sampled_from([4, 32, 128, 144]),
    b=st.integers(1, 8),
    l=st.integers(1, 24),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
    combiner=st.sampled_from(["sum", "mean"]),
)
def test_embedding_bag_property(v, d, b, l, density, seed, combiner):
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((v, d)).astype(np.float32)
    ids = rng.integers(0, v, size=(b, l)).astype(np.int32)
    mask = (rng.random((b, l)) < density)
    got = eb_ops.embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                               jnp.asarray(mask), combiner)
    want = eb_ref.embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                                jnp.asarray(mask), combiner)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_bf16():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((128, 64)), jnp.bfloat16)
    ids = jnp.asarray(rng.integers(0, 128, size=(4, 6)), jnp.int32)
    mask = jnp.ones((4, 6), bool)
    got = eb_ops.embedding_bag(table, ids, mask)
    want = eb_ref.embedding_bag(table, ids, mask)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2)


def test_embedding_bag_poisoned_padded_ids():
    """Masked-off lanes carry GARBAGE ids (out-of-range, negative): the
    kernel gathers ``table[id]`` via DMA BEFORE the mask applies, so an
    unclamped id is an out-of-bounds read (regression: satellite #4). The
    result must match the same bag with benign padded ids."""
    rng = np.random.default_rng(11)
    v, d, b, l = 64, 32, 5, 9
    table = rng.standard_normal((v, d)).astype(np.float32)
    ids = rng.integers(0, v, size=(b, l)).astype(np.int32)
    mask = rng.random((b, l)) < 0.6
    mask[2] = False                              # fully-masked row
    poisoned = ids.copy()
    poisoned[~mask] = v + 1000                   # way past the table
    poisoned[0, 0] = -7 if not mask[0, 0] else poisoned[0, 0]
    for combiner in ("sum", "mean"):
        got = eb_ops.embedding_bag(jnp.asarray(table), jnp.asarray(poisoned),
                                   jnp.asarray(mask), combiner)
        want = eb_ref.embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                                    jnp.asarray(mask), combiner)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# int64 carry width (satellite #1): timestamps past 2^31
# ---------------------------------------------------------------------------

def test_delta_decode_int64_base_beyond_int32():
    """Regression: epoch-millisecond bases (> 2^31) decoded through an int32
    kernel carry used to wrap. int64 inputs must come back EXACT — the kernel
    carries window-relative spans only, the int64 base is re-added host-side."""
    rng = np.random.default_rng(0)
    b, n = 4, 50
    base0 = np.int64(3_000_000_000)              # > 2^31 - 1
    deltas = rng.integers(0, 10_000, size=(b, n)).astype(np.int64)
    deltas[:, 0] = 0
    bases = base0 + rng.integers(0, 10**9, size=(b,)).astype(np.int64)
    got = dd_ops.delta_decode(deltas, bases)
    want = np.cumsum(deltas, axis=1) + bases[:, None]
    assert got.dtype == np.int64
    np.testing.assert_array_equal(np.asarray(got), want)
    assert want.max() > np.iinfo(np.int32).max   # the case that used to wrap


def test_delta_decode_int64_wide_window_host_exact():
    """A window whose RELATIVE span exceeds int32 cannot go through the
    kernel at all — the wrapper must fall back to the exact host decode."""
    deltas = np.array([[0, 2**33, 5]], dtype=np.int64)
    bases = np.array([7], dtype=np.int64)
    got = dd_ops.delta_decode(deltas, bases)
    want = np.cumsum(deltas, axis=1) + bases[:, None]
    assert got.dtype == np.int64
    np.testing.assert_array_equal(np.asarray(got), want)


def test_delta_decode_int32_stays_device_typed():
    deltas = np.array([[0, 1, 2]], np.int32)
    bases = np.array([5], np.int32)
    got = dd_ops.delta_decode(jnp.asarray(deltas), jnp.asarray(bases))
    np.testing.assert_array_equal(np.asarray(got), [[5, 6, 8]])


# ---------------------------------------------------------------------------
# ragged / empty shapes (satellite #2): wrappers pad and slice back
# ---------------------------------------------------------------------------

def test_kernels_empty_and_ragged_shapes():
    # delta_decode: zero rows / zero cols
    for shape in [(0, 8), (3, 0), (0, 0)]:
        d = np.zeros(shape, np.int32)
        out = dd_ops.delta_decode(d, np.zeros(shape[0], np.int32))
        assert out.shape == shape
    # jagged: empty batch, zero max_len
    vals = jnp.zeros((0, 4), jnp.float32)
    offs = jnp.zeros(1, jnp.int32)
    assert jg_ops.jagged_to_padded(vals, offs, 5).shape == (0, 5, 4)
    vals2, offs2 = _jagged_case(3, 8, 4, seed=0)
    assert jg_ops.jagged_to_padded(vals2, offs2, 0).shape == (3, 0, 4)
    # embedding_bag: empty batch / empty bag
    table = jnp.zeros((8, 4), jnp.float32)
    out = eb_ops.embedding_bag(table, jnp.zeros((0, 3), jnp.int32),
                               jnp.zeros((0, 3), bool))
    assert out.shape == (0, 4)
    out = eb_ops.embedding_bag(table, jnp.zeros((2, 0), jnp.int32),
                               jnp.zeros((2, 0), bool))
    assert out.shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# no silent numpy fallback (satellite #3): the Pallas kernels THEMSELVES run
# ---------------------------------------------------------------------------

def test_ops_never_route_through_ref_oracles(monkeypatch):
    """Break every ref oracle, then run all three kernels + the fused op:
    correct answers prove tier-1 executes the actual kernel bodies (Pallas
    interpreter off-TPU), not a reference fallback."""
    from repro.kernels.fused import ops as fu_ops

    def boom(*a, **k):
        raise AssertionError("ref oracle called from a kernel wrapper")

    monkeypatch.setattr(dd_ref, "delta_decode", boom)
    monkeypatch.setattr(jg_ref, "jagged_to_padded", boom)
    monkeypatch.setattr(eb_ref, "embedding_bag", boom)

    got = dd_ops.delta_decode(jnp.asarray([[0, 1, 2]], jnp.int32),
                              jnp.asarray([5], jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), [[5, 6, 8]])

    vals = jnp.asarray(np.arange(1.0, 4.0, dtype=np.float32)[:, None])
    got = jg_ops.jagged_to_padded(vals, jnp.asarray([0, 1, 3], jnp.int32), 2)
    np.testing.assert_array_equal(
        np.asarray(got)[:, :, 0], [[0.0, 1.0], [2.0, 3.0]])

    table = jnp.asarray(np.eye(4, dtype=np.float32))
    got = eb_ops.embedding_bag(table, jnp.asarray([[0, 2]], jnp.int32),
                               jnp.ones((1, 2), bool))
    np.testing.assert_array_equal(np.asarray(got), [[1.0, 0.0, 1.0, 0.0]])

    dense = fu_ops.fused_densify(
        jnp.asarray(np.array([[1], [2], [3]], np.int32)),
        jnp.asarray([0, 1, 3], jnp.int32), 2)
    np.testing.assert_array_equal(
        np.asarray(dense)[:, :, 0], [[0, 1], [2, 3]])


# ---------------------------------------------------------------------------
# fused decode -> densify -> embed (the tentpole op)
# ---------------------------------------------------------------------------

def _fused_oracle(vals, offs, seq_len):
    """Host numpy scatter with jax canonicalization (x64 off)."""
    lens = np.minimum(np.diff(offs), seq_len)
    b = len(lens)
    j = np.arange(seq_len)
    out = {}
    for t, col in vals.items():
        col = np.asarray(col)
        dt = jax.dtypes.canonicalize_dtype(col.dtype)
        dense = np.zeros((b, seq_len), dt)
        kept = np.concatenate(
            [col[offs[i + 1] - lens[i]:offs[i + 1]] for i in range(b)]
        ) if b else col[:0]
        dense[j >= (seq_len - lens)[:, None]] = kept.astype(dt)
        out[t] = dense
    return out


def _fused_case(rng, b, seq_len, over_length=False, with_ts=True):
    hi = 3 * seq_len if over_length else seq_len
    lens = rng.integers(0, hi + 1, size=b)
    offs = np.zeros(b + 1, np.int64)
    np.cumsum(lens, out=offs[1:])
    n = int(offs[-1])
    vals = {
        "item_id": rng.integers(0, 10**12, n).astype(np.int64),
        "action": rng.integers(-5, 5, n).astype(np.int32),
        "flag": rng.integers(0, 2, n).astype(np.int8),
        "score": rng.standard_normal(n).astype(np.float32),
        "weight": rng.standard_normal(n).astype(np.float64),
    }
    if with_ts:
        ts = np.sort(rng.integers(0, 10**6, n)).astype(np.int64)
        # per-row re-sort so each window is monotone from its own base
        vals["timestamp"] = np.concatenate(
            [np.sort(ts[offs[i]:offs[i + 1]]) for i in range(b)]
        ) if n else ts
    return vals, offs


@pytest.mark.parametrize("b,seq_len", [(1, 4), (5, 16), (8, 7), (3, 130)])
def test_fused_densify_multi_trait_parity(b, seq_len):
    from repro.kernels.fused import ops as fu_ops

    rng = np.random.default_rng(b * 31 + seq_len)
    vals, offs = _fused_case(rng, b, seq_len, with_ts=False)
    arena, metas = fu_ops.pack_arena(vals)
    dense = fu_ops.fused_densify(jnp.asarray(arena),
                                 jnp.asarray(offs.astype(np.int32)), seq_len)
    got = fu_ops.unpack_dense(dense, metas)
    want = _fused_oracle(vals, offs, seq_len)
    assert list(got) == list(want)
    for k in want:
        assert got[k].dtype == want[k].dtype, k
        np.testing.assert_array_equal(np.asarray(got[k]), want[k], err_msg=k)


def test_fused_densify_over_length_rows_keep_tail():
    """Rows longer than seq_len (non-timestamp traits) must right-align the
    LAST seq_len elements — the featurizer's truncation rule."""
    from repro.kernels.fused import ops as fu_ops

    rng = np.random.default_rng(2)
    vals, offs = _fused_case(rng, 6, 8, over_length=True, with_ts=False)
    arena, metas = fu_ops.pack_arena(vals)
    dense = fu_ops.fused_densify(jnp.asarray(arena),
                                 jnp.asarray(offs.astype(np.int32)), 8)
    got = fu_ops.unpack_dense(dense, metas)
    want = _fused_oracle(vals, offs, 8)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]), want[k], err_msg=k)


def test_fused_densify_empty_batch_and_all_empty_rows():
    from repro.kernels.fused import ops as fu_ops

    for b in (0, 4):
        offs = np.zeros(b + 1, np.int64)
        vals = {"item_id": np.zeros(0, np.int64),
                "score": np.zeros(0, np.float32)}
        arena, metas = fu_ops.pack_arena(vals)
        dense = fu_ops.fused_densify(jnp.asarray(arena),
                                     jnp.asarray(offs.astype(np.int32)), 5)
        got = fu_ops.unpack_dense(dense, metas)
        for k, v in got.items():
            assert v.shape == (b, 5)
            np.testing.assert_array_equal(np.asarray(v), 0)


def test_fused_float32_bitcast_is_bit_exact():
    """float32 rides the int32 arena as a BITCAST: -0.0, inf, nan, and
    denormals must survive the round trip bit-for-bit."""
    from repro.kernels.fused import ops as fu_ops

    special = np.array([-0.0, np.inf, -np.inf, np.nan, np.float32(1e-42),
                        -np.float32(1e-42), 3.14], np.float32)
    offs = np.array([0, 3, 7], np.int64)
    arena, metas = fu_ops.pack_arena({"score": special})
    dense = fu_ops.fused_densify(jnp.asarray(arena),
                                 jnp.asarray(offs.astype(np.int32)), 4)
    got = np.asarray(fu_ops.unpack_dense(dense, metas)["score"])
    want = _fused_oracle({"score": special}, offs, 4)["score"]
    np.testing.assert_array_equal(got.view(np.int32), want.view(np.int32))


def test_ts_delta_encode_roundtrip_and_overflow():
    from repro.kernels.fused import ops as fu_ops

    rng = np.random.default_rng(3)
    offs = np.array([0, 5, 5, 12], np.int64)
    base0 = np.int64(3_000_000_000)
    ts = base0 + np.concatenate(
        [np.sort(rng.integers(0, 10**6, int(n))) for n in np.diff(offs)]
    ).astype(np.int64)
    deltas, bases = fu_ops.ts_delta_encode(ts, offs)
    assert deltas.dtype == np.int32 and bases.dtype == np.int64
    assert bases[1] == 0                       # empty row: no base
    # exact int64 reconstruction from window-relative deltas
    rec = np.empty_like(ts)
    for i in range(3):
        lo, hi = offs[i], offs[i + 1]
        rec[lo:hi] = np.cumsum(deltas[lo:hi], dtype=np.int64) + bases[i]
    np.testing.assert_array_equal(rec, ts)
    # a window spanning more than int32 is a broken codec contract
    with pytest.raises(ValueError, match="int32"):
        fu_ops.ts_delta_encode(np.array([0, 2**32], np.int64),
                               np.array([0, 2], np.int64))


def test_late_materialize_full_pipeline_with_embed():
    """decode -> densify -> embedding_bag in one composition: timestamps past
    2^31 decode to the canonical wrapped-int32 lanes, ids pool through the
    clamped embedding_bag, mask/lens match the featurizer contract."""
    from repro.kernels.fused import ops as fu_ops

    rng = np.random.default_rng(4)
    seq_len, v, d = 9, 50, 16
    vals, offs = _fused_case(rng, 6, seq_len, with_ts=True)
    vals["item_id"] = (vals["item_id"] % v).astype(np.int64)
    ts_abs = vals["timestamp"] + np.int64(3_000_000_000)
    vals["timestamp"] = ts_abs
    table = rng.standard_normal((v, d)).astype(np.float32)

    out = fu_ops.late_materialize(vals, offs, seq_len, ts_trait="timestamp",
                                  table=jnp.asarray(table),
                                  ids_trait="item_id", combiner="mean")
    want = _fused_oracle(vals, offs, seq_len)
    lens = np.minimum(np.diff(offs), seq_len)
    mask = np.arange(seq_len) >= (seq_len - lens)[:, None]
    np.testing.assert_array_equal(np.asarray(out["lens"]), lens)
    np.testing.assert_array_equal(np.asarray(out["mask"]), mask)
    for k in vals:
        np.testing.assert_array_equal(
            np.asarray(out["traits"][k]), want[k], err_msg=k)
    pooled_want = eb_ref.embedding_bag(
        jnp.asarray(table), jnp.asarray(want["item_id"].astype(np.int32)),
        jnp.asarray(mask), combiner="mean")
    np.testing.assert_allclose(np.asarray(out["pooled"]),
                               np.asarray(pooled_want), rtol=1e-6, atol=1e-6)
