import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

Lowers + compiles every (architecture × input-shape × mesh) cell against the
production meshes (16x16 single pod, 2x16x16 multi-pod) using ShapeDtypeStruct
inputs only (no allocation), then records memory_analysis / cost_analysis /
collective-byte accounting for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
Results accumulate in dryrun_results.json (one entry per cell; idempotent).
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_arch, list_archs
from repro.compat import as_shardings
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch.steps import build_cell
from repro.roofline.analysis import from_compiled
from repro.roofline.hlo import parse_collectives

RESULTS = Path(__file__).resolve().parents[3] / "dryrun_results.json"


def run_cell(arch_id: str, shape_name: str, mesh_name: str,
             keep_hlo: bool = False) -> dict:
    spec = get_arch(arch_id)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = int(np.prod(list(mesh.shape.values())))
    cell = build_cell(spec, shape_name, mesh, use_full=True)

    t0 = time.time()
    with set_mesh(mesh):
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=as_shardings(mesh, cell.in_shardings),
            out_shardings=as_shardings(mesh, cell.out_shardings),
        )
        lowered = jitted.lower(*cell.args_spec)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # -- memory ---------------------------------------------------------------
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)
    # logical per-chip bytes from shardings (backend-independent)
    mem["args_logical_bytes_per_chip"] = _logical_bytes(cell, mesh)

    # -- cost + collectives ----------------------------------------------------
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "utilization operand 0 {}", "optimal_seconds")}
    except Exception as e:
        cost = {"error": str(e)}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    roof = from_compiled(
        arch_id, shape_name, mesh_name, chips,
        cost if "error" not in cost else None,
        coll.link_bytes, coll.counts, cell.model_flops,
    )
    out = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "chips": chips,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": mem, "cost": cost,
        "collectives": coll.to_dict(),
        "model_flops": cell.model_flops,
        "meta": cell.meta,
        "roofline": roof.to_dict(),
        "ok": True,
    }
    if keep_hlo:
        hdir = RESULTS.parent / "hlo"
        hdir.mkdir(exist_ok=True)
        (hdir / f"{arch_id}__{shape_name}__{mesh_name}.txt").write_text(hlo)
    return out


def _measure(cell, mesh) -> dict:
    """Lower+compile a (calibration) cell and return flops/bytes/collectives."""
    with set_mesh(mesh):
        jitted = jax.jit(cell.step_fn,
                         in_shardings=as_shardings(mesh, cell.in_shardings),
                         out_shardings=as_shardings(mesh, cell.out_shardings))
        compiled = jitted.lower(*cell.args_spec).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "link_bytes": coll.link_bytes,
        "counts": coll.counts,
    }


def calibrate_cell(arch_id: str, shape_name: str, mesh_name: str) -> dict:
    """Exact per-step flops/bytes/collective accounting.

    ``cost_analysis`` does not multiply while-loop bodies by trip count, so the
    production lowering (scan-over-layers + chunked attention/loss) undercounts.
    We re-lower with all scans unrolled: recsys/GNN-small exactly; LM and GNN
    via depth-{1,2} unrolled lowerings and linear extrapolation in layers
    (every layer is identical, so v(L) = v1 + (L-1)(v2-v1) is exact)."""
    import dataclasses as dc

    spec = get_arch(arch_id)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    full = spec.full

    # anchors at L=2,3: the L=1 lowering triggers anomalous SPMD resharding
    # copies that break linearity (verified empirically: L in {2,3,...} is
    # linear per layer to <2%)
    if spec.family == "lm":
        def mk(L):
            return dc.replace(full, n_layers=L, scan_layers=False,
                              unroll_scans=True)
        m1 = _measure(build_cell(spec, shape_name, mesh, cfg_override=mk(2)), mesh)
        m2 = _measure(build_cell(spec, shape_name, mesh, cfg_override=mk(3)), mesh)
        return _extrapolate(m1, m2, full.n_layers, anchors=(2, 3))
    if spec.family == "gnn":
        def mk(L):
            return dc.replace(full, n_layers=L, scan_blocks=False)
        m1 = _measure(build_cell(spec, shape_name, mesh, cfg_override=mk(2)), mesh)
        m2 = _measure(build_cell(spec, shape_name, mesh, cfg_override=mk(3)), mesh)
        return _extrapolate(m1, m2, full.n_layers, anchors=(2, 3))
    # recsys: unroll everything (models are shallow) -> exact
    if arch_id in ("dien", "bert4rec", "dlrm-uih"):
        cfg = dc.replace(full, unroll_scans=True)
        return _measure(build_cell(spec, shape_name, mesh, cfg_override=cfg), mesh)
    # two-tower / dcn-v2 have no scans: production lowering is already exact
    return _measure(build_cell(spec, shape_name, mesh), mesh)


def _extrapolate(m1: dict, m2: dict, n_layers: int,
                 anchors=(1, 2)) -> dict:
    a1, a2 = anchors
    out = {}
    for k in ("flops", "bytes", "link_bytes"):
        slope = max(0.0, (m2[k] - m1[k]) / (a2 - a1))
        out[k] = m1[k] + (n_layers - a1) * slope
    counts = {}
    for op in set(m1["counts"]) | set(m2["counts"]):
        c1, c2 = m1["counts"].get(op, 0), m2["counts"].get(op, 0)
        counts[op] = c1 + (n_layers - a1) * max(0, (c2 - c1) // (a2 - a1))
    out["counts"] = counts
    out["extrapolated_from"] = list(anchors)
    return out


def run_calibration(arch_id: str, shape_name: str, mesh_name: str) -> dict:
    cal = calibrate_cell(arch_id, shape_name, mesh_name)
    spec = get_arch(arch_id)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = int(np.prod(list(mesh.shape.values())))
    cell = build_cell(spec, shape_name, mesh)
    roof = from_compiled(
        arch_id, shape_name, mesh_name, chips,
        {"flops": cal["flops"], "bytes accessed": cal["bytes"]},
        cal["link_bytes"], cal["counts"], cell.model_flops,
    )
    return {"calibration": cal, "roofline_calibrated": roof.to_dict(),
            "model_flops": cell.model_flops}


def _logical_bytes(cell, mesh) -> int:
    """Per-chip bytes of all step inputs under their PartitionSpecs."""
    chips = int(np.prod(list(mesh.shape.values())))
    total = 0

    def leaf_bytes(leaf, spec):
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        shard = 1
        entries = list(spec) if spec is not None else []
        for e in entries:
            for ax in (e if isinstance(e, tuple) else (e,)):
                if ax is not None:
                    shard *= mesh.shape[ax]
        return n // max(shard, 1)

    from jax.sharding import PartitionSpec as P
    for args, shs in zip(cell.args_spec, cell.in_shardings):
        leaves, _ = jax.tree_util.tree_flatten(args)
        specs, _ = jax.tree_util.tree_flatten(
            shs, is_leaf=lambda x: isinstance(x, P) or x is None)
        if len(leaves) == len(specs):
            total += sum(leaf_bytes(l, s) for l, s in zip(leaves, specs))
        else:
            total += sum(int(np.prod(l.shape)) * l.dtype.itemsize // chips
                         for l in leaves)
    return total


def load_results() -> dict:
    if RESULTS.exists():
        return json.loads(RESULTS.read_text())
    return {}


def save_result(key: str, entry: dict) -> None:
    res = load_results()
    res[key] = entry
    RESULTS.write_text(json.dumps(res, indent=1, default=str))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="add exact (unrolled/extrapolated) roofline terms")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        f"dry-run needs 512 host devices, got {jax.device_count()} — "
        "XLA_FLAGS must be set before jax import")

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    done = load_results()
    failures = []
    for arch_id in archs:
        spec = get_arch(arch_id)
        shapes = [args.shape] if args.shape else list(spec.shapes)
        for shape_name in shapes:
            for mesh_name in meshes:
                key = f"{arch_id}|{shape_name}|{mesh_name}"
                if args.calibrate:
                    entry = done.get(key)
                    if not (entry and entry.get("ok")):
                        print(f"[skip] {key} (no baseline)")
                        continue
                    if "roofline_calibrated" in entry and not args.force:
                        print(f"[skip] {key} (calibrated)")
                        continue
                    print(f"[cal ] {key} ...", flush=True)
                    try:
                        entry.update(run_calibration(arch_id, shape_name,
                                                     mesh_name))
                        r = entry["roofline_calibrated"]
                        print(f"[ ok ] {key}: bottleneck={r['bottleneck']} "
                              f"frac={r['roofline_fraction']:.3f} "
                              f"useful={r['model_flops_ratio']:.2f}", flush=True)
                    except Exception as e:
                        failures.append(key)
                        entry["calibration_error"] = f"{type(e).__name__}: {e}"
                        print(f"[FAIL] {key}: {type(e).__name__}: {e}",
                              flush=True)
                    save_result(key, entry)
                    continue
                if key in done and done[key].get("ok") and not args.force:
                    print(f"[skip] {key}")
                    continue
                print(f"[run ] {key} ...", flush=True)
                try:
                    entry = run_cell(arch_id, shape_name, mesh_name,
                                     keep_hlo=args.keep_hlo)
                    r = entry["roofline"]
                    print(f"[ ok ] {key}: compile={entry['t_compile_s']}s "
                          f"bottleneck={r['bottleneck']} "
                          f"frac={r['roofline_fraction']:.3f}", flush=True)
                except Exception as e:
                    entry = {"arch": arch_id, "shape": shape_name,
                             "mesh": mesh_name, "ok": False,
                             "error": f"{type(e).__name__}: {e}",
                             "traceback": traceback.format_exc()[-3000:]}
                    failures.append(key)
                    print(f"[FAIL] {key}: {type(e).__name__}: {e}", flush=True)
                save_result(key, entry)
    if failures:
        print(f"\n{len(failures)} failures: {failures}")
        raise SystemExit(1)
    print("\nall cells compiled")


if __name__ == "__main__":
    main()
