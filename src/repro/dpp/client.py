"""Trainer-side DPP client (paper §4.2.1): slot-based zero-copy rebatching.

DPP workers emit *base batches* sized to their memory budget; the trainer-side
client asynchronously buffers, merges, and reshuffles them into the model's
full batch. This decouples worker memory pressure from the GPU's large-batch
requirement and raises worker thread concurrency.

The seed implementation merged pending base batches with an ``np.concatenate``
copy and then applied the reshuffle permutation with a second full-batch
gather copy. This version preallocates full-batch arrays as reusable *slots*
and writes each base batch's rows directly into the slot at **write-time
permuted offsets** — the reshuffle is fused into placement, so each row is
copied exactly once (base batch -> slot) and slot storage is recycled via
``recycle()`` instead of reallocated. Reproducibility: the permutation for
the k-th emitted full batch is keyed on the producer-side emit counter k
(``shuffle_seed + k``), which makes the output byte-identical to the seed
``merge_base_batches`` + ``reshuffle`` path (proven in tests/test_feed.py).

Also hosts the GPU-starvation accounting the elastic controller consumes.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Deque, Dict, Iterator, List, Optional

import numpy as np

from repro.dpp.featurize import JaggedFeatures, merge_base_batches, reshuffle
from repro.obs.spans import current_span


@dataclasses.dataclass
class ClientStats:
    full_batches: int = 0
    starved_time_s: float = 0.0    # trainer waited on data (GPU idle)
    train_time_s: float = 0.0      # trainer consumed data (GPU busy)
    # split of starved_time_s by what the feed was doing while the trainer
    # waited (populated by DevicePrefetcher; without one, waits are host waits)
    starved_host_s: float = 0.0    # waiting on host-side data production
    starved_h2d_s: float = 0.0     # waiting on the host->device copy
    h2d_time_s: float = 0.0        # total device_put time (overlapped or not)
    h2d_bytes: int = 0             # bytes actually shipped host->device
    slot_reuses: int = 0           # full batches served from a recycled slot

    @property
    def starvation_pct(self) -> float:
        total = self.starved_time_s + self.train_time_s
        if total <= 0:
            return 0.0
        return 100.0 * self.starved_time_s / total


class _Slot:
    """One in-flight full batch: preallocated arrays + fill bookkeeping.

    ``filled`` counts RESERVED rows (bumped under the client lock);
    ``writers`` counts producer threads still copying into their reserved
    span — the slot is emitted when it is fully reserved AND all copies
    landed, so the memory-bandwidth work itself runs outside the lock.
    """

    __slots__ = ("arrays", "filled", "writers", "emitted", "inv", "emit_seq",
                 "spans")

    def __init__(self, arrays: Dict[str, np.ndarray], inv: Optional[np.ndarray],
                 emit_seq: int):
        self.arrays = arrays
        self.filled = 0
        self.writers = 0
        self.emitted = False
        self.inv = inv          # arrival row -> slot row (None = identity)
        self.emit_seq = emit_seq
        # item spans whose rows landed here (telemetry only; see DESIGN §13)
        self.spans: List = []


class RebatchingClient:
    """Merges base batches of size b into full batches of size B = k*b.

    ``put`` is called by DPP worker threads; ``get_full_batch`` by the trainer.
    The consumer may hand a finished batch's storage back via ``recycle`` —
    the arrays are then reused for a future slot instead of reallocated
    (callers that retain references must skip recycling, which is always safe:
    the client simply allocates fresh storage).
    """

    def __init__(
        self,
        full_batch_size: int,
        buffer_batches: int = 8,
        shuffle_seed: Optional[int] = 0,
        emit_seq_start: int = 0,
        emit_jagged: bool = False,
    ):
        self.full_batch_size = full_batch_size
        # jagged-emission mode (device-side late materialization, DESIGN §3):
        # slots hold per-row arena VIEWS instead of dense [B, L] storage and
        # each emitted full batch is a COMPACT payload (flat arena + offsets
        # per trait) for the DevicePrefetcher's materializer — the [B, L]
        # zero-padded grids are never built on the host
        self.emit_jagged = emit_jagged
        self._jagged_meta: Optional[dict] = None
        self._q: "queue.Queue" = queue.Queue(maxsize=buffer_batches)
        self._lock = threading.Lock()
        self._closed = threading.Event()
        self.shuffle_seed = shuffle_seed
        # producer-side emit counter: the reshuffle seed must NOT depend on
        # stats.full_batches (incremented by the CONSUMER), else the shuffle
        # of batch k varies with trainer timing and runs aren't reproducible.
        # ``emit_seq_start`` resumes the counter after a crash (Feed
        # checkpoint/resume): batch k of the resumed run reshuffles exactly
        # like batch ``start + k`` of the uninterrupted run would have.
        self._emit_seq = emit_seq_start
        self._slot: Optional[_Slot] = None      # the single partially-filled slot
        self._free: List[Dict[str, np.ndarray]] = []   # recycled slot storage
        self._max_free = buffer_batches
        self.stats = ClientStats()
        # row count of each emitted batch, in emission order (opt-in): the
        # Feed's crash-safe cursor reads delivered-batch sizes from here
        # instead of inspecting batch arrays (a prep_fn may reshape them).
        # Exact under single-emitter ordering (the pool's placer / close());
        # consumers that bypass the Feed (shutdown drains) leave stale
        # entries behind, which is fine — checkpoints are never taken after
        # training stopped. Off by default so feeds without a checkpointing
        # consumer never accrete it.
        self.track_emitted_rows = False
        self.emitted_rows: Deque[int] = collections.deque()
        # optional per-run telemetry (repro.obs.Telemetry): the emit point —
        # each committed slot's contributing item spans become a BatchSpan
        # riding a FIFO parallel to the output queue
        self.telemetry = None
        # end-of-stream sentinel observed by the consumer: lets a wall-clock-
        # bounded trainer distinguish "stream over" from "get timed out"
        self.ended = False

    # -- slot machinery ----------------------------------------------------------
    def _perm_inv(self, emit_seq: int, n: int) -> Optional[np.ndarray]:
        """Inverse permutation for the k-th emitted batch: arrival row r lands
        at slot row inv[r], equivalent to ``reshuffle(batch, seed + k)``."""
        if self.shuffle_seed is None:
            return None
        perm = np.random.default_rng(self.shuffle_seed + emit_seq).permutation(n)
        inv = np.empty(n, np.int64)
        inv[perm] = np.arange(n, dtype=np.int64)
        return inv

    def _new_slot(self, template: Dict[str, np.ndarray]) -> _Slot:
        """Allocate (or recycle) full-batch storage shaped like ``template``."""
        b = self.full_batch_size
        arrays: Optional[Dict[str, np.ndarray]] = None
        if self._free:
            cand = self._free.pop()
            if (cand.keys() == template.keys() and all(
                    cand[k].shape[1:] == template[k].shape[1:]
                    and cand[k].dtype == template[k].dtype
                    for k in template)):
                arrays = cand
                self.stats.slot_reuses += 1
            # else: shape/schema changed mid-stream — drop and reallocate
        if arrays is None:
            arrays = {k: np.empty((b,) + v.shape[1:], v.dtype)
                      for k, v in template.items()}
        slot = _Slot(arrays, self._perm_inv(self._emit_seq, b), self._emit_seq)
        self._emit_seq += 1
        return slot

    def _write_rows(self, slot: _Slot, base: Dict[str, np.ndarray],
                    src_lo: int, src_hi: int, lo: int) -> None:
        """Copy base rows [src_lo, src_hi) into slot span [lo, ...) at
        permuted offsets. Runs OUTSIDE the client lock (disjoint spans)."""
        if base.keys() != slot.arrays.keys():
            # a short-keyed batch would otherwise fill its span PARTIALLY and
            # leave stale slot data in the missing columns (the seed concat
            # path raised here too)
            raise KeyError(
                f"base batch keys {sorted(base)} != slot keys "
                f"{sorted(slot.arrays)}")
        n = src_hi - src_lo
        if slot.inv is None:
            for k, v in base.items():
                slot.arrays[k][lo : lo + n] = v[src_lo:src_hi]
        else:
            dest = slot.inv[lo : lo + n]
            for k, v in base.items():
                slot.arrays[k][dest] = v[src_lo:src_hi]

    def _commit(self, slot: _Slot, ok: bool) -> None:
        """Mark a reserved span done; emit the slot once complete. A failed
        span poisons the slot — half-written batches must never reach the
        trainer (the producer's exception propagates regardless)."""
        with self._lock:
            slot.writers -= 1
            if not ok:
                slot.emitted = True   # poison: complete but never queued
                if self._slot is slot:
                    self._slot = None   # later puts start a fresh slot
                return
            done = (slot.filled == self.full_batch_size
                    and slot.writers == 0 and not slot.emitted)
            if done:
                slot.emitted = True
        if done:
            # emit OUTSIDE the lock: the bounded queue may block on a slow
            # consumer and producers must not hold the slot lock meanwhile
            if self.track_emitted_rows:
                self.emitted_rows.append(self.full_batch_size)
            if self.telemetry is not None:
                # slot.spans is frozen here: the slot is fully reserved and
                # its last writer just committed
                self.telemetry.spans.emit_batch(
                    slot.emit_seq, slot.spans, self.full_batch_size)
            self._q.put(self._pack_jagged(slot.arrays)
                        if self.emit_jagged else slot.arrays)

    def _place(self, rows: int, template_fn, write_fn) -> None:
        """Shared reservation loop for ``put``/``put_jagged``: reserve a span
        under the lock, copy it OUTSIDE the lock (spans are disjoint, so N
        workers place rows concurrently instead of serializing the batch's
        memory-bandwidth work), and commit in a ``finally`` so a failed write
        cannot leak ``writers`` and hang ``close()``."""
        src = 0
        while src < rows:
            with self._lock:
                if self._slot is None:
                    self._slot = self._new_slot(template_fn())
                slot = self._slot
                lo = slot.filled
                take = min(rows - src, self.full_batch_size - lo)
                slot.filled += take
                slot.writers += 1
                if self.telemetry is not None:
                    sp = current_span()
                    if sp is not None and (
                            not slot.spans or slot.spans[-1] is not sp):
                        slot.spans.append(sp)
                if slot.filled == self.full_batch_size:
                    self._slot = None   # fully reserved; next put starts fresh
            ok = False
            try:
                write_fn(slot, src, src + take, lo)
                ok = True
            finally:
                self._commit(slot, ok)
            src += take

    # -- producer side (DPP workers) --------------------------------------------
    def put(self, base_batch: Dict[str, np.ndarray]) -> None:
        if self.emit_jagged:
            raise TypeError(
                "client is in jagged-emission mode (emit_jagged=True): dense "
                "base batches would force the host densify the mode exists "
                "to eliminate — produce JaggedFeatures and use put_jagged")
        rows = len(next(iter(base_batch.values())))
        self._place(
            rows, lambda: base_batch,
            lambda slot, a, b, lo: self._write_rows(slot, base_batch, a, b, lo))

    # -- fused jagged placement ---------------------------------------------------
    def _jagged_template(self, jf: JaggedFeatures) -> Dict[str, np.ndarray]:
        """Zero-row template describing the full-batch arrays a JaggedFeatures
        base batch densifies into (same keys/dtypes/orders as ``to_padded``)."""
        p = jf.plan
        t: Dict[str, np.ndarray] = {"uih_len": np.zeros((0,), np.int32)}
        for trait, arena in jf.values.items():
            t[f"uih_{trait}"] = np.zeros((0, p.seq_len), arena.dtype)
        t["uih_mask"] = np.zeros((0, p.seq_len), np.bool_)
        for k, v in jf.scalars.items():
            t[k] = np.zeros((0,) + v.shape[1:], v.dtype)
        return t

    def _write_jagged(self, slot: _Slot, jf: JaggedFeatures,
                      src_lo: int, src_hi: int, lo: int) -> None:
        """Scatter arena elements of arrival rows [src_lo, src_hi) straight
        into slot span [lo, ...) at write-time-permuted offsets —
        densification, pad, mask, and reshuffle fused into ONE pass (no
        intermediate base batch). Runs OUTSIDE the client lock.
        """
        n = src_hi - src_lo
        L = jf.plan.seq_len
        if slot.inv is None:
            dest = np.arange(lo, lo + n, dtype=np.int64)
        else:
            dest = slot.inv[lo : lo + n]
        # per-(plan, span) flat destination indices, shared across traits:
        # element j of arrival row r lands at dest[r]*L + (L - len[r]) + j
        flat_cache: Dict[int, np.ndarray] = {}

        def flat_for(plan) -> np.ndarray:
            key = id(plan)
            hit = flat_cache.get(key)
            if hit is not None:
                return hit
            seg = plan.lens[src_lo:src_hi]
            base = plan.offsets[src_lo:src_hi] - plan.offsets[src_lo]
            shift = dest * L + (L - seg) - base
            flat = np.arange(int(seg.sum()), dtype=np.int64) \
                + np.repeat(shift, seg)
            flat_cache[key] = flat
            return flat

        # padding must read as zeros: wipe the destination rows (row-wise
        # memset), then scatter only the valid elements
        slot.arrays["uih_len"][dest] = jf.plan.lens[src_lo:src_hi].astype(np.int32)
        for trait, arena in jf.values.items():
            plan = jf.plan_for(trait)
            arr = slot.arrays[f"uih_{trait}"]
            arr[dest] = 0
            span = arena[plan.offsets[src_lo] : plan.offsets[src_hi]]
            if len(span):
                arr.reshape(-1)[flat_for(plan)] = span
        m = slot.arrays["uih_mask"]
        m[dest] = False
        mf = flat_for(jf.plan)
        if len(mf):
            m.reshape(-1)[mf] = True
        for k, v in jf.scalars.items():
            slot.arrays[k][dest] = v[src_lo:src_hi]

    def put_jagged(self, jf: JaggedFeatures) -> None:
        """Place a jagged (arena + offsets) base batch without densifying it
        first: one fused scatter per trait, reshuffle folded into placement.
        Byte-identical to ``put(jf.to_padded())`` (tests/test_feed.py).

        In jagged-EMISSION mode the densify is skipped entirely: slots store
        per-row arena views and the full batch leaves as a compact payload
        (see ``_pack_jagged``) for the device-side fused kernel."""
        if self.emit_jagged:
            self._place(
                jf.plan.b, lambda: self._jagged_emit_template(jf),
                lambda slot, a, b, lo: self._write_jagged_rows(
                    slot, jf, a, b, lo))
            return
        self._place(
            jf.plan.b, lambda: self._jagged_template(jf),
            lambda slot, a, b, lo: self._write_jagged(slot, jf, a, b, lo))

    # -- jagged emission (device-side late materialization) -----------------------
    def _jagged_emit_template(self, jf: JaggedFeatures) -> Dict[str, np.ndarray]:
        """Slot template for jagged-emission mode: one object column of row
        views per trait plus the [B] scalar columns — no [B, L] storage."""
        if self._jagged_meta is None:
            self._jagged_meta = {
                "seq_len": jf.plan.seq_len,
                "traits": [(t, np.asarray(a).dtype)
                           for t, a in jf.values.items()],
                "scalar_keys": list(jf.scalars),
            }
        t: Dict[str, np.ndarray] = {"uih_len": np.zeros((0,), np.int32)}
        for trait, _ in self._jagged_meta["traits"]:
            t[f"_rows_{trait}"] = np.zeros((0,), object)
        for k in self._jagged_meta["scalar_keys"]:
            v = jf.scalars[k]
            t[k] = np.zeros((0,) + v.shape[1:], v.dtype)
        return t

    def _write_jagged_rows(self, slot: _Slot, jf: JaggedFeatures,
                           src_lo: int, src_hi: int, lo: int) -> None:
        """Jagged-emission placement: store each arrival row's clipped-tail
        arena VIEW at its write-time-permuted slot position — zero row copies
        until emit concatenates the full batch's arena. Runs OUTSIDE the
        client lock."""
        meta = self._jagged_meta
        if ([t for t, _ in meta["traits"]] != list(jf.values)
                or meta["scalar_keys"] != list(jf.scalars)):
            # same contract as the dense path: a schema-drifting base batch
            # must fail loudly, not leave stale columns behind
            raise KeyError(
                f"base batch schema {sorted(jf.values)}/{sorted(jf.scalars)} "
                f"!= slot schema {sorted(t for t, _ in meta['traits'])}/"
                f"{sorted(meta['scalar_keys'])}")
        n = src_hi - src_lo
        if slot.inv is None:
            dest = np.arange(lo, lo + n, dtype=np.int64)
        else:
            dest = slot.inv[lo : lo + n]
        slot.arrays["uih_len"][dest] = \
            jf.plan.lens[src_lo:src_hi].astype(np.int32)
        for trait, arena in jf.values.items():
            offs = jf.plan_for(trait).offsets
            cells = np.empty(n, object)
            cells[:] = [arena[offs[src_lo + i]:offs[src_lo + i + 1]]
                        for i in range(n)]
            slot.arrays[f"_rows_{trait}"][dest] = cells
        for k, v in jf.scalars.items():
            slot.arrays[k][dest] = v[src_lo:src_hi]

    def _pack_jagged(self, arrays: Dict[str, np.ndarray],
                     idx: Optional[np.ndarray] = None) -> Dict[str, np.ndarray]:
        """A completed jagged slot -> the compact emitted payload:

        ``uih_len`` [B] int32, one flat ``_arena_<trait>`` per trait (its
        per-row offsets are the cumsum of ``uih_len`` clipped lens; traits
        with their OWN plan — schema evolution — add ``_offsets_<trait>``),
        the scalar columns, and ``_seq_len``. The DevicePrefetcher's
        materializer turns this into the dense device batch; the layout
        contract lives in DESIGN §3."""
        meta = self._jagged_meta
        lens = arrays["uih_len"] if idx is None else arrays["uih_len"][idx]
        b = len(lens)
        shared = np.zeros(b + 1, np.int64)
        shared[1:] = np.cumsum(lens, dtype=np.int64)
        out: Dict[str, np.ndarray] = {"uih_len": lens}
        for trait, dtype in meta["traits"]:
            rows = arrays[f"_rows_{trait}"]
            if idx is not None:
                rows = rows[idx]
            tl = np.fromiter((r.shape[0] for r in rows), np.int64, count=b)
            offs = np.zeros(b + 1, np.int64)
            offs[1:] = np.cumsum(tl)
            arena = (np.concatenate(list(rows)) if offs[-1]
                     else np.zeros(0, dtype))
            if arena.dtype != dtype:
                arena = arena.astype(dtype)
            out[f"_arena_{trait}"] = arena
            if not np.array_equal(offs, shared):
                out[f"_offsets_{trait}"] = offs
        out["_seq_len"] = np.int64(meta["seq_len"])
        for k in meta["scalar_keys"]:
            out[k] = arrays[k] if idx is None else arrays[k][idx]
        return out

    def recycle(self, batch: Dict[str, np.ndarray]) -> None:
        """Return a consumed full batch's storage to the slot pool."""
        if self.emit_jagged:
            return   # payloads are packed fresh at emit; slots hold views
        with self._lock:
            if len(self._free) < self._max_free:
                self._free.append(batch)

    def close(self) -> None:
        """Flush the pending remainder as a final short batch, then signal end
        of stream (the tail of an epoch must not be silently dropped).

        Call AFTER all producers finished their ``put``s; any straggler still
        copying its reserved span is waited out before the tail is read."""
        self._closed.set()
        with self._lock:
            slot, self._slot = self._slot, None
        if slot is not None and slot.filled:
            while True:
                with self._lock:
                    if slot.writers == 0:
                        poisoned = slot.emitted
                        break
                time.sleep(0.001)
            if poisoned:   # a failed span: drop the tail, do not emit garbage
                self._q.put(None)
                return
            n = slot.filled
            # the tail was written at full-batch permuted offsets; recover
            # arrival order, then reshuffle over the ACTUAL length n exactly
            # like the seed path's close() did
            if slot.inv is None:
                if self.emit_jagged:
                    tail = self._pack_jagged(
                        slot.arrays, np.arange(n, dtype=np.int64))
                else:
                    tail = {k: v[:n] for k, v in slot.arrays.items()}
            else:
                order = slot.inv[:n]
                if self.emit_jagged:
                    # same semantics as the dense tail below: recover arrival
                    # order, then reshuffle over the ACTUAL length n
                    perm = np.random.default_rng(
                        self.shuffle_seed + slot.emit_seq).permutation(n)
                    tail = self._pack_jagged(slot.arrays, order[perm])
                else:
                    tail = {k: v[order] for k, v in slot.arrays.items()}
                    tail = reshuffle(tail, self.shuffle_seed + slot.emit_seq)
            if self.track_emitted_rows:
                self.emitted_rows.append(n)
            if self.telemetry is not None:
                self.telemetry.spans.emit_batch(slot.emit_seq, slot.spans, n)
            self._q.put(tail)
        self._q.put(None)

    # -- consumer side (trainer loop) --------------------------------------------
    def get_full_batch(self, timeout: Optional[float] = None, record: bool = True):
        t0 = time.perf_counter()
        try:
            out = self._q.get(timeout=timeout)
            if out is None:
                self.ended = True
        except queue.Empty:
            out = None
        if out is not None and record:
            # only waits that END IN A DELIVERED BATCH are GPU starvation: a
            # timeout or the end-of-stream sentinel would otherwise inflate
            # starvation_pct after the stream is drained
            dt = time.perf_counter() - t0
            self.stats.starved_time_s += dt
            self.stats.starved_host_s += dt
            self.stats.full_batches += 1
        return out

    def record_train_step(self, seconds: float) -> None:
        self.stats.train_time_s += seconds

    def stats_snapshot(self) -> ClientStats:
        """Consistent point-in-time copy of the counters (Feed.snapshot())."""
        with self._lock:
            return dataclasses.replace(self.stats)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.get_full_batch()
            if b is None:
                return
            yield b
