"""Training substrate: AdamW, LR schedules, microbatch accumulation,
gradient compression, distributed train step."""
