"""Pallas TPU kernel: EmbeddingBag — fused gather + masked bag reduction.

The recsys hot path (kernel_taxonomy §B.6 / §B.11): the table is far larger
than VMEM, so it stays in HBM (pl.ANY) and rows are fetched by **double-
buffered async DMA** — while row l is being accumulated, the DMA for row l+1
is already in flight, hiding HBM gather latency behind the VPU adds. ids live
in SMEM for scalar control flow; the (1, D) accumulator and the two row slots
live in VMEM.

(On real v5e hardware this op belongs to SparseCore; this is the TensorCore-
resident formulation, which is also what one uses when embedding output feeds
straight into MXU matmuls.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, mask_ref, table_ref, out_ref, acc, slots, sems, *,
            bag_len, vocab):
    def dma(l, slot):
        # clamp BEFORE the DMA is issued: padded/sentinel lanes carry
        # arbitrary ids under mask==0, and an async copy from table[id] reads
        # HBM unconditionally — an out-of-range id must never leave [0, V)
        # even though its row is multiplied by zero afterwards
        idx = jnp.clip(ids_ref[0, l], 0, vocab - 1)
        return pltpu.make_async_copy(
            table_ref.at[pl.ds(idx, 1), :], slots.at[slot], sems.at[slot]
        )

    dma(0, 0).start()

    def body(l, _):
        slot = jax.lax.rem(l, 2)
        nxt = jax.lax.rem(l + 1, 2)

        @pl.when(l + 1 < bag_len)
        def _prefetch():
            dma(l + 1, nxt).start()

        dma(l, slot).wait()
        w = mask_ref[0, l].astype(acc.dtype)
        acc[...] += slots[slot] * w
        return 0

    acc[...] = jnp.zeros_like(acc)
    jax.lax.fori_loop(0, bag_len, body, 0)
    out_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("bag_len", "interpret"))
def embedding_bag_kernel(
    table: jax.Array,      # (V, D) — HBM resident
    ids: jax.Array,        # (B, L) int32
    mask: jax.Array,       # (B, L) float (0/1)
    bag_len: int,
    interpret: bool = False,
) -> jax.Array:
    b, l = ids.shape
    v, d = table.shape
    assert l == bag_len, (l, bag_len)   # ops.py owns ragged-shape padding
    return pl.pallas_call(
        functools.partial(_kernel, bag_len=bag_len, vocab=v),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, l), lambda i: (i, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), table.dtype),
            pltpu.VMEM((2, 1, d), table.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(ids, mask, table)
