"""Device-side late materialization: the host<->device handover adapter.

``RebatchingClient(emit_jagged=True)`` emits compact payloads (flat arena +
offsets per trait — DESIGN §3 layout contract) instead of dense [B, L]
batches. ``DeviceMaterializer`` sits inside the DevicePrefetcher's transfer
thread: it uploads ONLY the compact arrays (the zero padding never crosses
the PCIe/ICI link), then runs the ``kernels/fused`` densify+decode kernel on
device and rebuilds exactly the batch dict the host-dense path would have
produced after ``jax.device_put`` — same keys, same order, same canonical
dtypes, same bytes (tests/test_feed.py asserts identity in interpret mode).

The embedding lookup deliberately stays OUT of this adapter for training:
the table is a trained parameter living inside the jit'd step, so the
fusion boundary is decode+densify (see ``kernels/fused/ops.late_materialize``
for the fully fused decode->densify->embed composition used by serving-style
consumers, and ``roofline.analysis.materialization_roofline`` for why the
boundary costs nothing — the dense id lanes must transit HBM for the model
either way).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from repro.kernels.fused.ops import (
    fused_densify,
    pack_arena,
    ts_delta_encode,
    unpack_dense,
)

HostBatch = Dict[str, np.ndarray]


def is_jagged_batch(batch: Any) -> bool:
    """True for compact payloads from a jagged-emission client."""
    return isinstance(batch, dict) and "_seq_len" in batch


def jagged_batch_nbytes(batch: HostBatch) -> int:
    """Bytes this payload ships over H2D (arena/offsets/scalars; the metadata
    scalar ``_seq_len`` stays host-side)."""
    total = 0
    for k, v in batch.items():
        if k == "_seq_len":
            continue
        a = np.asarray(v)
        if k.startswith("_arena_") and a.dtype == np.int64:
            # int64 arenas upload as int32 (canonicalization / delta packing)
            total += a.size * 4
        else:
            total += a.nbytes
    return total


def densify_host(batch: HostBatch) -> HostBatch:
    """Host-side fallback densify of a compact payload (numpy scatter) —
    the oracle the device path is tested against, and the escape hatch for
    consumers that receive a payload without a device stage."""
    seq_len = int(batch["_seq_len"])
    lens = np.asarray(batch["uih_len"])
    b = len(lens)
    shared = np.zeros(b + 1, np.int64)
    shared[1:] = np.cumsum(lens, dtype=np.int64)
    j = np.arange(seq_len)
    out: HostBatch = {"uih_len": lens}
    for k, v in batch.items():
        if not k.startswith("_arena_"):
            continue
        trait = k[len("_arena_"):]
        offs = np.asarray(batch.get(f"_offsets_{trait}", shared))
        tl = np.minimum(np.diff(offs), seq_len)
        dense = np.zeros((b, seq_len), v.dtype)
        dense[j >= (seq_len - tl)[:, None]] = v
        out[f"uih_{trait}"] = dense
    out["uih_mask"] = j >= (seq_len - lens)[:, None]
    for k, v in batch.items():
        if k == "_seq_len" or k == "uih_len" or k.startswith(("_arena_",
                                                              "_offsets_")):
            continue
        out[k] = v
    return out


class DeviceMaterializer:
    """Upload a compact jagged payload + run the fused kernel on device.

    Stateless per batch except ``last_h2d_bytes`` (read by the prefetcher
    right after each call for the ``ClientStats.h2d_bytes`` counter)."""

    def __init__(self, ts_trait: str = "timestamp", device: Any = None,
                 sharding: Any = None):
        self.ts_trait = ts_trait
        self.device = device
        self.sharding = sharding
        self.last_h2d_bytes = 0

    def _put(self, x: np.ndarray):
        import jax

        self.last_h2d_bytes += x.nbytes
        if self.device is not None:
            return jax.device_put(x, self.device)
        return jax.device_put(x)

    def _group(self, batch: HostBatch, traits: List[str], offs: np.ndarray,
               seq_len: int) -> Dict[str, Any]:
        """Materialize one shared-plan trait group with ONE kernel launch."""
        vals: Dict[str, np.ndarray] = {}
        ts_bases = None
        ts_col = -1
        for t in traits:
            col = np.asarray(batch[f"_arena_{t}"])
            if t == self.ts_trait and col.dtype == np.int64:
                deltas, bases64 = ts_delta_encode(col, offs)
                vals[t] = deltas
                # wrapped int32 base: decoded lanes match what device_put of
                # the host-dense int64 timestamps canonicalizes to
                ts_bases = self._put(bases64.astype(np.int32))
                ts_col = len(vals) - 1
            else:
                vals[t] = col
        arena, metas = pack_arena(vals)
        dense = fused_densify(self._put(arena),
                              self._put(offs.astype(np.int32)),
                              seq_len, ts_bases=ts_bases, ts_col=ts_col)
        return unpack_dense(dense, metas)

    def __call__(self, batch: HostBatch):
        import jax
        import jax.numpy as jnp

        self.last_h2d_bytes = 0
        seq_len = int(batch["_seq_len"])
        lens_h = np.asarray(batch["uih_len"])
        b = len(lens_h)
        shared = np.zeros(b + 1, np.int64)
        shared[1:] = np.cumsum(lens_h, dtype=np.int64)
        traits = [k[len("_arena_"):] for k in batch if k.startswith("_arena_")]
        shared_group = [t for t in traits if f"_offsets_{t}" not in batch]
        dense_traits: Dict[str, Any] = {}
        if shared_group:
            dense_traits.update(
                self._group(batch, shared_group, shared, seq_len))
        for t in traits:
            if f"_offsets_{t}" not in batch:
                continue
            # schema-evolution trait with its own jagged structure: its own
            # (1-column) kernel launch over its own offsets
            dense_traits.update(self._group(
                batch, [t], np.asarray(batch[f"_offsets_{t}"]), seq_len))
        lens = self._put(lens_h)
        j = jnp.arange(seq_len, dtype=jnp.int32)[None, :]
        mask = j >= (seq_len - lens[:, None])
        # key order mirrors JaggedFeatures.to_padded exactly — consumers and
        # parity tests see the SAME dict shape as the host-dense path
        out: Dict[str, Any] = {"uih_len": lens}
        for t in traits:
            out[f"uih_{t}"] = dense_traits[t]
        out["uih_mask"] = mask
        for k, v in batch.items():
            if k in ("_seq_len", "uih_len") or k.startswith(("_arena_",
                                                             "_offsets_")):
                continue
            out[k] = self._put(np.asarray(v))
        if self.sharding is not None:
            out = jax.device_put(out, self.sharding)
        return out
