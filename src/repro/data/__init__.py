"""Declarative read path (paper §2.3, §4.2): ``DatasetSpec`` → ``Feed``.

The data-access front door: describe WHAT a tenant consumes in a frozen,
hashable ``DatasetSpec`` (source, ``TenantProjection``, consistency mode,
generation policy, feed knobs); ``open_feed`` compiles it into the existing
data plane (materialization → DPP workers → rebatching → optional device
prefetch) and hands back ONE uniform ``Feed`` protocol, consumed identically
by the ``Trainer`` for batch and streaming. ``MultiTenantPlanner`` co-plans N
specs over the same store into one union co-scan with per-tenant carved views
(``TenantShareStats`` proves the amplification win). The legacy
``launch.steps.make_device_feed`` / ``make_streaming_feed`` helpers are
deprecated shims over this package.
"""
from repro.core.materialize import TenantShareStats
from repro.data.compile import (
    cell_input_sharding,
    compile_worker_plan,
    open_feed,
)
from repro.data.feed import Feed, FeedStats
from repro.data.planner import MultiTenantPlanner
from repro.data.spec import (
    DatasetSpec,
    SimSource,
    StreamSource,
    WarehouseSource,
    resume_fingerprint,
)

__all__ = [
    "DatasetSpec",
    "Feed",
    "FeedStats",
    "MultiTenantPlanner",
    "SimSource",
    "StreamSource",
    "TenantShareStats",
    "WarehouseSource",
    "cell_input_sharding",
    "compile_worker_plan",
    "open_feed",
    "resume_fingerprint",
]
